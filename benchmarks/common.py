"""Shared benchmark utilities: CSV emission, timing, default budgets."""
from __future__ import annotations

import os
import time
from typing import Dict, Iterable, List

RESULTS_DIR = os.environ.get("BENCH_RESULTS", "results/bench")

# "quick" mode keeps the full sweep per figure but with smaller search
# budgets so `python -m benchmarks.run` completes on one CPU core.
QUICK = os.environ.get("BENCH_QUICK", "1") == "1"

# base RNG seed benchmarks fold into their generators so repeated runs
# can sample different workloads (``benchmarks.run --seed N``); 0 keeps
# the historical fixed-seed behaviour bit-for-bit
SEED = int(os.environ.get("BENCH_SEED", "0"))


def emit(name: str, rows: List[Dict]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    if not rows:
        print(f"[{name}] no rows")
        return
    cols = list(rows[0].keys())
    path = os.path.join(RESULTS_DIR, name + ".csv")
    with open(path, "w") as f:
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r.get(c, "")) for c in cols) + "\n")
    print(f"[{name}] wrote {len(rows)} rows -> {path}")
    header = " | ".join(f"{c:>14s}" for c in cols)
    print(header)
    for r in rows:
        print(" | ".join(f"{_fmt(r.get(c, '')):>14s}" for c in cols))


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.3f}"
    return str(v)


class timer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        self.seconds = time.monotonic() - self.t0
