"""Figure 6: small-scale settings — (a) search efficiency on a 24-GPU
infrastructure; (b) ILP time-to-optimal vs #GPUs; SHA-EA gap to the ILP
optimum (paper: within 1%, ILP < 3 min for <= 24 GPUs)."""
from __future__ import annotations

from repro.core import topology, workflow
from repro.core.ilp import ilp_scheduler
from repro.core.sha import HybridScheduler

from benchmarks.common import QUICK, emit, timer


def run(quick: bool = QUICK):
    rows = []
    gpu_counts = [6, 8, 12] if quick else [6, 8, 12, 16, 24]
    max_seconds = 240 if quick else 900
    for n in gpu_counts:
        counts = {"A100": n // 2, "L4": n - n // 2}
        topo = topology.build_testbed("single_region", counts=counts)
        wf = workflow.make_grpo(workflow.QWEN_1_7B, global_batch=64)
        with timer() as t_ilp:
            r_ilp = ilp_scheduler(topo, wf, max_seconds=max_seconds,
                                  max_nodes=5_000_000)
        complete = t_ilp.seconds < 0.98 * max_seconds \
            and r_ilp.evals < 5_000_000
        sched = HybridScheduler(topo, wf, max_groupings=15,
                                max_sizes_per_grouping=6, seed=0)
        with timer() as t_sha:
            r_sha = sched.search(budget=1500)
        gap = (r_sha.cost / r_ilp.cost - 1.0) * 100
        rows.append({
            "n_gpus": n,
            "ilp_s": round(r_ilp.cost, 2),
            "ilp_complete": complete,
            "ilp_wall_s": round(t_ilp.seconds, 1),
            "ilp_nodes": r_ilp.evals,
            "sha_ea_s": round(r_sha.cost, 2),
            "sha_wall_s": round(t_sha.seconds, 1),
            "gap_pct": round(gap, 2) if complete else "n/a",
        })
    emit("fig6_small_scale_ilp", rows)
    print("[fig6] paper: ILP optimal <3 min for <=24 GPUs; SHA-EA gap <=1%")
    return rows


if __name__ == "__main__":
    run()
