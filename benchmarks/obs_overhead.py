"""Observability acceptance: tracing overhead + cost-model calibration.

Two axes, each an acceptance gate for the ``repro.obs`` layer:

Overhead axis — the span tracer must be cheap enough to leave on.  The
genserve engine runs the same serving workload with tracing disabled
and enabled in alternating repetitions (so slow container phases hit
both arms equally); the median wall-time inflation is gated at < 5%.
The spans on this path are per host round (``gen.round`` + one
admission or decode child), i.e. a few hundred nanoseconds of
``perf_counter_ns`` bookkeeping against a jitted device step — the gate
verifies the bookkeeping never grew a device sync.

Calibration axis — the cost model prices the *planned* testbed (A100 /
L4 specs), while the smoke engine folds execution onto the local CPU
host, so the raw measured-vs-predicted iteration ratio sits around
10^4–10^6.  ``obs.calibrate.fit_from_engine`` fits per-device-class
scale factors from the measured Event timeline; rerunning the simulator
with the ``CalibratedCostModel`` must bring the ratio within 10x of
unity (paper Fig. 7's usable regime).  The raw and corrected ratios are
both reported so the correction factor itself is visible in the
summary.

Writes ``results/obs_overhead.json``.
"""
from __future__ import annotations

import json
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.genserve import adapter as genserve
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.rl import rollout

from benchmarks.common import QUICK, emit


def _overhead_axis(quick: bool):
    cfg = ModelConfig(name="obs-bench", n_layers=2, d_model=256,
                      n_heads=4, n_kv_heads=2, head_dim=64, d_ff=512,
                      vocab_size=128, dtype="float32")
    wave, B = 8, 32
    N = 24 if quick else 48
    P = 16
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size, jnp.int32)
    rng = np.random.default_rng(3)
    gen_lens = np.minimum(rng.geometric(3.0 / N, B), N)
    sampler = rollout.SamplerConfig(max_new_tokens=N, greedy=True)

    def serve():
        ro, _ = genserve.generate(params, cfg, prompts,
                                  jax.random.PRNGKey(2), sampler,
                                  wave=wave, decode_chunk=1,
                                  gen_lens=gen_lens, fast_path=False)
        jax.block_until_ready(ro["sequences"])

    was_enabled = obs_trace.is_enabled()
    reps = 6 if quick else 12
    times = {"off": [], "on": []}
    try:
        serve()                       # compile once, outside both arms
        for _ in range(reps):
            for arm in ("off", "on"):
                if arm == "on":
                    obs_trace.enable()
                else:
                    obs_trace.disable()
                t0 = time.monotonic()
                serve()
                times[arm].append(time.monotonic() - t0)
            obs_trace.reset()         # bound the span buffer growth
    finally:
        obs_trace.disable()
        obs_trace.reset()
        if was_enabled:
            obs_trace.enable()
    off = statistics.median(times["off"])
    on = statistics.median(times["on"])
    return {"reps": reps, "wall_off_s": off, "wall_on_s": on,
            "overhead_pct": (on / off - 1.0) * 100.0}


def _calibration_axis(quick: bool):
    from repro.data.synthetic import AdditionTask, VOCAB_SIZE
    from repro.obs import calibrate as obs_cal
    from repro.rl.trainer import RLConfig, RLTrainer

    cfg = ModelConfig(name="obs-cal", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=VOCAB_SIZE,
                      dtype="float32")
    task = AdditionTask(max_operand=9)
    trainer = RLTrainer(cfg, RLConfig(algorithm="grpo", n_rollouts=2,
                                      max_new_tokens=task.max_answer_len),
                        task, jax.random.PRNGKey(0))
    iters = 4 if quick else 8
    key = jax.random.PRNGKey(42)
    for i in range(iters):
        prompts, answers = task.sample_batch(np.random.default_rng(i), 2)
        key, k = jax.random.split(key)
        trainer.iteration(prompts, answers, k)

    cal = obs_cal.fit_from_engine(trainer.engine, skip_iterations=1)
    raw = trainer.engine.compare_with_simulator()
    corrected = trainer.engine.compare_with_simulator(
        cost_model=cal.cost_model(trainer.engine.topo, trainer.wf))
    return {"iterations": iters,
            "raw_ratio": raw["ratio"],
            "calibrated_ratio": corrected["ratio"],
            "correction": raw["ratio"] / corrected["ratio"],
            "calibration": cal.to_dict()}


def run(quick: bool = QUICK):
    obs_metrics.reset()
    ov = _overhead_axis(quick)
    print(f"[obs_overhead] tracing off {ov['wall_off_s'] * 1e3:.1f}ms vs "
          f"on {ov['wall_on_s'] * 1e3:.1f}ms "
          f"({ov['overhead_pct']:+.2f}%)")
    cal = _calibration_axis(quick)
    print(f"[obs_overhead] measured/predicted ratio "
          f"{cal['raw_ratio']:.3g} raw -> "
          f"{cal['calibrated_ratio']:.3g} calibrated "
          f"(x{cal['correction']:.3g} correction)")

    # acceptance: tracing must stay under 5% wall-time inflation, and
    # calibration must land the iteration ratio within 10x of unity
    assert ov["overhead_pct"] < 5.0, ov
    assert 0.1 < cal["calibrated_ratio"] < 10.0, cal

    emit("obs_overhead", [
        {"axis": "tracing", "off_s": ov["wall_off_s"],
         "on_s": ov["wall_on_s"], "overhead_pct": ov["overhead_pct"]},
        {"axis": "calibration", "off_s": cal["raw_ratio"],
         "on_s": cal["calibrated_ratio"],
         "overhead_pct": cal["correction"]},
    ])
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "obs_overhead.json")
    with open(path, "w") as f:
        json.dump({"overhead": ov, "calibration": cal}, f, indent=2)
    print(f"[obs_overhead] wrote {path}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    run()
