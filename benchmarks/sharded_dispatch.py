"""Sharded plan execution benchmark (8 forced host devices).

Two axes, both on the gen|rest DP=2/TP=2 plan from
``examples/sharded_exec.py``'s setup:

1. train-step dispatch: replicated single-device step vs the DP=2/TP=2
   sharded step on the training group's mesh (reported, not gated — on a
   single physical core GSPMD partitioning adds overhead; on real
   multi-core/multi-chip hosts it is the win the plan prices);
2. gen/train overlap: the async engine with disjoint folded groups runs
   the GEN lane wall-clock concurrent with the training stages.  Two
   ratios are reported:
   - ``overlap_ratio_concurrency`` (gated > 1.0): per steady iteration,
     summed task wall durations / the iteration's wall span from the
     events' ``t_wall`` stamps.  Exceeds 1.0 exactly when the GEN lane
     really ran concurrently with the training stages (lanes interleave
     even on one core); stays <= ~1.0 under the serialized walk.
     Honest only because folding is injective (zero collisions), which
     the run asserts.  (The *replay* clock cannot show this: it prices
     the workflow's within-iteration dependency chain, which is serial
     by construction.)
   - ``overlap_ratio_wall`` serialized vs overlapped measured seconds
     per iteration (gated > 1.0 only when the host has > 2 usable
     cores; a single-core container interleaves the lanes and the
     wall clock shows no gain).

The multi-device run happens in a child interpreter: the parent's jax is
already initialized with the host's real device count, and
``--xla_force_host_platform_device_count`` only applies at first import.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

OUT = os.path.join("results", "sharded_dispatch.json")


# ---------------------------------------------------------------------------
# child: the actual multi-device measurement
# ---------------------------------------------------------------------------

def _child() -> dict:
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import enumerate as enum_mod, topology, workflow
    from repro.core.plan import check_constraints
    from repro.data.synthetic import AdditionTask, VOCAB_SIZE
    from repro.models.config import ModelConfig
    from repro.rl.trainer import RLConfig, RLTrainer

    quick = os.environ.get("BENCH_QUICK", "1") == "1"
    iters = 5 if quick else 8
    step_reps = 10 if quick else 30

    cfg = ModelConfig(name="dispatch-tiny", n_layers=2, d_model=64,
                      n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                      vocab_size=VOCAB_SIZE, dtype="float32")
    task = AdditionTask(max_operand=9)

    def make(devices=None, overlap=None):
        rl = RLConfig(algorithm="grpo", n_rollouts=4, max_new_tokens=4,
                      asynchronous=True, whiten_advantages=False)
        wf = workflow.make_workflow(
            "grpo", workflow.LLMSpec.from_model_config(cfg),
            synchronous=False, n_rollouts=rl.n_rollouts,
            seq_in=task.prompt_len, seq_out=rl.max_new_tokens,
            global_batch=1)
        topo = topology.build_testbed("single_region",
                                      counts={"A100": 4, "L4": 4})
        grouping = next(g for g in enum_mod.priority_groupings(wf)
                        if len(g) == 2 and any(
                            wf.task(t).kind == workflow.TaskKind.GEN
                            for t in min(g, key=len)))
        parallel = {t: (2, 1, 2)
                    if wf.task(t).kind in (workflow.TaskKind.GEN,
                                           workflow.TaskKind.TRAIN)
                    else (4, 1, 1) for t in range(wf.n_tasks)}
        plan = enum_mod.build_plan(topo, wf, grouping, [4, 4],
                                   list(range(8)), parallel=parallel)
        ok, msg = check_constraints(topo, wf, plan)
        assert ok, msg
        return RLTrainer(cfg, rl, task, jax.random.PRNGKey(0), plan=plan,
                         topo=topo, wf=wf, devices=devices,
                         overlap=overlap)

    # -- axis 1: replicated vs sharded train step ----------------------
    def fake_batch(B=16, N=4):
        P_len = task.prompt_len
        rng = np.random.default_rng(0)
        return {
            "sequences": jnp.asarray(
                rng.integers(0, VOCAB_SIZE, (B, P_len + N)), jnp.int32),
            "logp_old": jnp.asarray(rng.normal(size=(B, N)) - 2.0,
                                    jnp.float32),
            "advantages": jnp.asarray(rng.normal(size=(B, N)),
                                      jnp.float32),
            "mask": jnp.ones((B, N), jnp.float32),
        }, P_len

    def bench(fn, *args):
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.monotonic()
        for _ in range(step_reps):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.monotonic() - t0) / step_reps

    sharded = make()
    eng = sharded.engine
    train_pl = eng.placements[eng.ctx.actor_train]
    gen_pl = eng.placements[eng.ctx.gen_task]
    assert eng.ctx.folding.n_collisions == 0
    assert {d.id for d in gen_pl.local_devices}.isdisjoint(
        {d.id for d in train_pl.local_devices})
    assert train_pl.mesh_shape == (2, 2)

    batch, gen_start = fake_batch()
    with train_pl.mesh:
        bsh = train_pl.shard_batch(batch)
        step_sh = sharded.sharded_actor_step(train_pl, bsh)
        t_sharded = bench(step_sh, sharded.actor, sharded.actor_opt,
                          bsh, gen_start)

    baseline = make(devices=[jax.devices()[0]])
    t_replicated = bench(
        lambda: baseline._actor_step(baseline.actor, baseline.actor_opt,
                                     batch, gen_start=gen_start))

    # -- axis 2: gen/train overlap -------------------------------------
    def drive(trainer, n):
        rng = np.random.default_rng(0)
        key = jax.random.PRNGKey(7)
        walls = []
        for _ in range(n):
            prompts, answers = task.sample_batch(rng, 4)
            key, k = jax.random.split(key)
            t0 = time.monotonic()
            trainer.iteration(prompts, answers, k)
            walls.append(time.monotonic() - t0)
        return walls

    overlapped = make()
    assert overlapped.engine.overlap_active()
    w_over = drive(overlapped, iters)

    serialized = make(overlap=False)
    assert not serialized.engine.overlap_active()
    w_ser = drive(serialized, iters)

    # steady state: drop the fill iteration and the first trained one
    # (jit compilation)
    steady = slice(2, None)
    wall_over = float(np.mean(w_over[steady]))
    wall_ser = float(np.mean(w_ser[steady]))

    # measured concurrency from the events' host wall-clock stamps:
    # per steady iteration, summed task wall durations over the
    # iteration's wall span — > 1.0 iff lanes genuinely ran concurrently
    def concurrency(trainer):
        spans: dict = {}
        for e in trainer.engine.measured_result().timeline:
            if e.task < 0 or e.iteration < 2 or e.t_wall is None:
                continue
            ival = spans.setdefault(e.iteration, {}).setdefault(
                e.task, [None, None])
            ival[0 if e.kind == "start" else 1] = e.t_wall
        ratios = []
        for tasks in spans.values():
            ivs = [v for v in tasks.values() if None not in v]
            if len(ivs) < 2:
                continue
            busy = sum(t1 - t0 for t0, t1 in ivs)
            span = max(t1 for _, t1 in ivs) - min(t0 for t0, _ in ivs)
            ratios.append(busy / max(span, 1e-12))
        return float(np.mean(ratios)) if ratios else 0.0

    ratio_conc = concurrency(overlapped)
    ratio_conc_ser = concurrency(serialized)

    cores = len(os.sched_getaffinity(0))
    ratio_wall = wall_ser / max(wall_over, 1e-12)

    # gates: measured lane concurrency must be real (honest because
    # zero collisions); wall-clock speedup only provable with spare
    # cores
    assert ratio_conc > 1.0, \
        f"measured concurrency ratio {ratio_conc:.3f} <= 1.0"
    if cores > 2:
        assert ratio_wall > 1.0, \
            f"wall overlap ratio {ratio_wall:.3f} <= 1.0 on {cores} cores"

    return {
        "devices": jax.device_count(),
        "cores": cores,
        "train_mesh": list(train_pl.mesh_shape),
        "gen_devices": sorted(d.id for d in gen_pl.local_devices),
        "train_devices": sorted(d.id for d in train_pl.local_devices),
        "folding_collisions": eng.ctx.folding.n_collisions,
        "overlap_active": True,
        "train_step_replicated_s": t_replicated,
        "train_step_sharded_dp2tp2_s": t_sharded,
        "train_step_speedup": t_replicated / max(t_sharded, 1e-12),
        "iter_wall_serialized_s": wall_ser,
        "iter_wall_overlapped_s": wall_over,
        "overlap_ratio_wall": ratio_wall,
        "overlap_ratio_wall_gated": cores > 2,
        "overlap_ratio_concurrency": ratio_conc,
        "overlap_ratio_concurrency_serialized": ratio_conc_ser,
        "iters": iters,
    }


# ---------------------------------------------------------------------------
# parent: subprocess trampoline + results file
# ---------------------------------------------------------------------------

def run() -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.sharded_dispatch", "--child"],
        capture_output=True, text=True, timeout=1800, env=env)
    if r.returncode != 0:
        raise RuntimeError(
            f"sharded_dispatch child failed:\n{r.stdout}\n{r.stderr}")
    payload = json.loads(r.stdout.strip().splitlines()[-1])
    os.makedirs("results", exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[sharded_dispatch] wrote {OUT}")
    for k in ("train_step_replicated_s", "train_step_sharded_dp2tp2_s",
              "train_step_speedup", "iter_wall_serialized_s",
              "iter_wall_overlapped_s", "overlap_ratio_wall",
              "overlap_ratio_concurrency",
              "overlap_ratio_concurrency_serialized",
              "folding_collisions"):
        print(f"  {k:>28s}: {payload[k]}")
    if not payload["overlap_ratio_wall_gated"]:
        print(f"  (wall ratio not gated: only {payload['cores']} usable "
              f"cores — lanes interleave on one core)")


if __name__ == "__main__":
    if "--child" in sys.argv:
        sys.path.insert(0, "src")
        print(json.dumps(_child()))
    else:
        run()
