"""Run every benchmark (one per paper figure) + the roofline table.

    PYTHONPATH=src python -m benchmarks.run            # quick budgets
    BENCH_QUICK=0 PYTHONPATH=src python -m benchmarks.run   # full sweep
    PYTHONPATH=src python -m benchmarks.run --only genserve_throughput

Every run (filtered or not) rewrites ``results/summary.json``: per-bench
status/timing for the benchmarks that ran, merged over the previous
summary, plus a copy of every per-bench ``results/*.json`` payload — one
file from which CI and local runs can diff the whole perf trajectory.
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys
import time
import traceback


def run_metadata() -> dict:
    """Provenance stamped onto every summary entry: git SHA, UTC
    timestamp, JAX backend and device kind.  Each probe degrades to a
    placeholder rather than failing the run (results must be writable
    from a detached checkout or a backend-less box)."""
    meta = {"git_sha": "unknown", "timestamp":
            datetime.datetime.now(datetime.timezone.utc)
            .isoformat(timespec="seconds")}
    try:
        meta["git_sha"] = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        pass
    try:
        import jax
        meta["backend"] = jax.default_backend()
        devs = jax.devices()
        meta["device_kind"] = devs[0].device_kind if devs else "none"
        meta["device_count"] = len(devs)
    except Exception:
        meta["backend"] = "unavailable"
    return meta


def write_summary(statuses: dict) -> str:
    """Merge `statuses` into results/summary.json together with every
    per-bench results/*.json payload."""
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "summary.json")
    summary = {"benchmarks": {}, "results": {}}
    if os.path.exists(path):
        try:
            with open(path) as f:
                summary = json.load(f)
        except (json.JSONDecodeError, OSError):
            pass
    summary.setdefault("benchmarks", {}).update(statuses)
    results = {}
    for fname in sorted(os.listdir("results")):
        if fname == "summary.json" or not fname.endswith(".json"):
            continue
        try:
            with open(os.path.join("results", fname)) as f:
                results[fname[:-len(".json")]] = json.load(f)
        except (json.JSONDecodeError, OSError):
            continue
    summary["results"] = results
    with open(path, "w") as f:
        json.dump(summary, f, indent=2)
    return path


def main() -> None:
    sys.path.insert(0, "src")
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, metavar="NAME",
                    help="run only benchmarks whose name contains NAME "
                         "(e.g. genserve_throughput, fig3)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base RNG seed benchmarks fold into their "
                         "generators (exported as BENCH_SEED; stamped "
                         "into every summary entry)")
    args = ap.parse_args()
    # must land before the benchmark modules import benchmarks.common
    os.environ["BENCH_SEED"] = str(args.seed)

    from benchmarks import (elastic_redeploy, engine_throughput,
                            fault_recovery, fig3_e2e,
                            fig4_loadbalance, fig5_search_efficiency,
                            fig6_small_scale_ilp, fig7_costmodel_validation,
                            fig8_training_quality, fig10_heterogeneity,
                            genserve_throughput, obs_overhead,
                            sharded_dispatch)
    benches = [
        ("engine_throughput", "plan-driven engine, measured vs predicted",
         engine_throughput.run),
        ("elastic_redeploy", "§6 throughput recovery vs degraded incumbent",
         elastic_redeploy.run),
        ("fault_recovery",
         "injected faults: detection latency, recovery time, goodput",
         fault_recovery.run),
        ("obs_overhead", "span-tracing overhead + cost-model calibration",
         obs_overhead.run),
        ("genserve_throughput",
         "continuous batching vs single-wave decode; chunked admission; "
         "paged KV + prefix reuse; speculative decoding",
         genserve_throughput.run),
        ("sharded_dispatch",
         "sharded DP=2/TP=2 train step + gen/train overlap on 8 forced "
         "host devices (subprocess)",
         sharded_dispatch.run),
        ("fig3_e2e", "Figure 3: end-to-end throughput", fig3_e2e.run),
        ("fig4_loadbalance", "Figure 4: LB ablation", fig4_loadbalance.run),
        ("fig5_search_efficiency", "Figure 5", fig5_search_efficiency.run),
        ("fig6_small_scale_ilp", "Figure 6", fig6_small_scale_ilp.run),
        ("fig7_costmodel_validation", "Figure 7",
         fig7_costmodel_validation.run),
        ("fig8_training_quality", "Figures 8/9: sync vs async quality",
         fig8_training_quality.run),
        ("fig10_heterogeneity", "Figure 10", fig10_heterogeneity.run),
    ]
    if args.only:
        benches = [b for b in benches if args.only in b[0]]
        if not benches:
            raise SystemExit(f"--only {args.only!r} matches no benchmark")

    meta = run_metadata()
    meta["seed"] = args.seed
    failures = []
    statuses = {}
    for name, desc, fn in benches:
        print(f"\n==== {name} ({desc}) ====", flush=True)
        t0 = time.monotonic()
        try:
            fn()
            statuses[name] = {"ok": True}
        except Exception:
            traceback.print_exc()
            failures.append(name)
            statuses[name] = {"ok": False}
        statuses[name]["seconds"] = round(time.monotonic() - t0, 2)
        statuses[name].update(meta)
        print(f"({statuses[name]['seconds']:.0f}s)", flush=True)

    if not args.only:
        # roofline table from whatever dry-run results exist so far
        print("\n==== roofline (from results/dryrun) ====", flush=True)
        try:
            from repro.launch.roofline import table
            if os.path.isdir("results/dryrun"):
                print(table("results/dryrun"))
            else:
                print("no dry-run results yet; run repro.launch.dryrun_all")
        except Exception:
            traceback.print_exc()
            failures.append("roofline")

    path = write_summary(statuses)
    print(f"\nwrote {path}")
    if failures:
        print(f"FAILED: {failures}")
        raise SystemExit(1)
    print("all benchmarks complete")


if __name__ == "__main__":
    main()
