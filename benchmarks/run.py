"""Run every benchmark (one per paper figure) + the roofline table.

    PYTHONPATH=src python -m benchmarks.run            # quick budgets
    BENCH_QUICK=0 PYTHONPATH=src python -m benchmarks.run   # full sweep
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import (elastic_redeploy, engine_throughput, fig3_e2e,
                            fig4_loadbalance, fig5_search_efficiency,
                            fig6_small_scale_ilp, fig7_costmodel_validation,
                            fig8_training_quality, fig10_heterogeneity,
                            genserve_throughput)
    benches = [
        ("engine_throughput (plan-driven engine, measured vs predicted)",
         engine_throughput.run),
        ("elastic_redeploy (§6 throughput recovery vs degraded incumbent)",
         elastic_redeploy.run),
        ("genserve_throughput (continuous batching vs single-wave decode)",
         genserve_throughput.run),
        ("fig3_e2e (Figure 3: end-to-end throughput)", fig3_e2e.run),
        ("fig4_loadbalance (Figure 4: LB ablation)", fig4_loadbalance.run),
        ("fig5_search_efficiency (Figure 5)", fig5_search_efficiency.run),
        ("fig6_small_scale_ilp (Figure 6)", fig6_small_scale_ilp.run),
        ("fig7_costmodel_validation (Figure 7)",
         fig7_costmodel_validation.run),
        ("fig8_training_quality (Figures 8/9: sync vs async quality)",
         fig8_training_quality.run),
        ("fig10_heterogeneity (Figure 10)", fig10_heterogeneity.run),
    ]
    failures = []
    for name, fn in benches:
        print(f"\n==== {name} ====", flush=True)
        t0 = time.monotonic()
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"({time.monotonic() - t0:.0f}s)", flush=True)

    # roofline table from whatever dry-run results exist so far
    print("\n==== roofline (from results/dryrun) ====", flush=True)
    try:
        from repro.launch.roofline import table
        if os.path.isdir("results/dryrun"):
            print(table("results/dryrun"))
        else:
            print("no dry-run results yet; run repro.launch.dryrun_all")
    except Exception:
        traceback.print_exc()
        failures.append("roofline")

    if failures:
        print(f"\nFAILED: {failures}")
        raise SystemExit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
