"""Continuous batching vs single-wave decode across gen-length skews.

The acceptance axis of the genserve subsystem: with a rollout batch of
4 x MAX_DECODE_WAVE requests and skewed output-length distributions
(uniform / bimodal / long-tail), the wave-recycling engine must beat the
single-wave GEN executor on useful tokens/sec — the single-wave path
decodes every sequence for the full budget whether finished or not,
while genserve retires finished slots and back-fills them from the
queue.  Also reports measured mean wave occupancy next to the ideal
continuous-batching occupancy from ``core.plan.predicted_occupancy``.

Admission axis (chunked prefill): one-shot whole-prompt admission vs
chunked admission (mixed wave-steps) over prompt-length mixes —
``long-uniform`` (every prompt long: each one-shot admission stalls the
whole wave for a full prefill) and ``bimodal-prompt`` (short/long mix:
one-shot additionally pays padded prefill for the short prompts, while
chunked ingests each request's real length).  Reports useful tok/s,
time-to-first-token p50/p95 (the headline metric chunked prefill
moves), and measured busy occupancy next to
``predicted_occupancy(..., prefill_rounds=...)`` — the honest
comparison that prices admission instead of assuming it free.

Prefix axis (paged KV + radix reuse): the paged page-pool cache with
radix prefix matching vs the PR-5 chunked baseline over three traces —
a GRPO k-samples mix (every prompt decoded k times: the k-1 later
samples skip nearly the whole prefill, gated at >= 50% token hit rate),
a multi-tenant mix (hot shared system prompt, distinct user tails) and
a no-sharing mix where the paged cache runs through the identity block
table and must cost ~0 (<= 5% tok/s) over the contiguous layout.
Reports useful tok/s, TTFT p50/p95 and the prefix-cache token hit rate.

Decode-path axis: the jitted wave-step latency per execution path —
``vmapped-per-slot`` (the legacy W-way vmap of a B=1 decode_step),
``batched-jnp`` (one natively batched decode_step with per-slot cache
positions — the fast path this repo now defaults to) and
``batched-pallas-interpret`` (the same batched step routed through the
Sq == 1 flash-decode Pallas kernel in interpreter mode — a lowering /
parity axis on CPU; the compiled-TPU configuration is the perf target).
Alternating A/B repetitions with a median cut through container timing
noise; the per-slot cache positions are drawn from each distribution so
recycled-slot raggedness is represented.

Writes both the benchmark CSV and ``results/genserve_throughput.json``.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import MAX_DECODE_WAVE, predicted_occupancy
from repro.genserve import adapter as genserve
from repro.genserve import decoder as gs_decoder
from repro.genserve.adapter import ttft_quantiles
from repro.models import attention as attn_mod
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.rl import rollout

from benchmarks.common import QUICK, SEED, emit


def _cfg():
    # large enough that decode-step FLOPs dominate dispatch overhead —
    # below ~d_model 256 on CPU the single-wave fused scan wins on
    # per-step cost and the wave-recycling advantage is buried
    return ModelConfig(name="genserve-bench", n_layers=2, d_model=256,
                       n_heads=4, n_kv_heads=2, head_dim=64, d_ff=512,
                       vocab_size=128, dtype="float32")


def _lengths(dist: str, B: int, N: int, rng: np.random.Generator):
    if dist == "uniform":
        return rng.integers(1, N + 1, B)
    if dist == "bimodal":
        return rng.choice([max(N // 8, 1), N], size=B, p=[0.5, 0.5])
    if dist == "long-tail":
        return np.minimum(rng.geometric(3.0 / N, B), N)
    raise ValueError(dist)


def _decode_path_axis(cfg, params, wave, P, N, lens, *, quick):
    """Median jitted wave-step latency per decode path (alternating A/B).

    The wave state is mid-stream: every slot occupied, per-slot cache
    positions spread per the imposed length distribution (recycled-slot
    raggedness included).  Paths share the state, so the axis isolates
    the decode-step program itself."""
    gcfg0 = gs_decoder.GenServeConfig(wave=wave, max_new_tokens=N)
    state = gs_decoder._init_state(cfg, gcfg0, P, len(lens))
    rng = np.random.default_rng(0)
    progress = rng.integers(0, np.maximum(lens[:wave], 1))
    state = dict(state,
                 occupied=jnp.ones((wave,), bool),
                 pos=jnp.asarray(P + progress, jnp.int32),
                 limit=jnp.full((wave,), N, jnp.int32))
    keys = jax.random.split(jax.random.PRNGKey(9), 1)

    paths = (("vmapped-per-slot", "vmapped", "jnp"),
             ("batched-jnp", "batched", "jnp"),
             ("batched-pallas-interpret", "batched", "pallas"))
    fns = {}
    prev_impl = attn_mod.get_attention_impl()
    for label, decode_path, impl in paths:
        gcfg = gs_decoder.GenServeConfig(wave=wave, max_new_tokens=N,
                                         greedy=True,
                                         decode_path=decode_path)
        try:
            attn_mod.set_attention_impl(impl)
            _, chunk_fn, _, _, _ = gs_decoder._build_fns(cfg, gcfg, P,
                                                         len(lens), impl)
            _, c = chunk_fn(params, state, keys)       # trace + compile
            jax.block_until_ready(c)
        finally:
            attn_mod.set_attention_impl(prev_impl)
        fns[label] = chunk_fn

    reps = 10 if quick else 30
    times = {label: [] for label, *_ in paths}
    for _ in range(reps):
        for label, f in fns.items():
            t0 = time.monotonic()
            _, c = f(params, state, keys)
            jax.block_until_ready(c)
            times[label].append(time.monotonic() - t0)
    return {label: statistics.median(ts) for label, ts in times.items()}


def _admission_axis(quick, timed_best):
    """One-shot vs chunked admission over prompt-length mixes.

    The stall class from the motivation: one long prompt freezes W-1
    active slots for a whole-prompt prefill under one-shot admission —
    and since the one-shot [W, P] program is padded to the longest
    prompt, *every* admission event pays the long-prompt price.
    Chunked admission ingests each request's real length in C-token
    chunks riding along with decode sub-rounds.  ``long-tail-prompt``:
    a quarter of the prompts are long (the 4k-prompt-in-the-mix
    scenario); ``bimodal-prompt``: an even short/long split.
    Generation lengths are long-tail (geometric): retirements stagger,
    so one-shot keeps paying whole-wave padded prefills to refill one
    or two slots at a time.  Both engines run iteration-level
    scheduling (decode_chunk=1 — the vLLM-style cadence and this
    engine's default: freed slots are eligible for admission after
    every wave step).  Useful tokens are identical (imposed gen_lens),
    so tok/s and TTFT are apples-to-apples.

    Expected shape of the results: chunked wins throughput on both
    mixes, and TTFT p50 decisively on ``long-tail-prompt`` (most
    requests are short prompts that land in one chunk).  On the 50/50
    ``bimodal-prompt`` mix chunked TTFT p50 can land on the *long*
    prompts — whose first tokens are deliberately spread over many
    rounds so the wave keeps decoding — which is the explicit
    latency-for-throughput trade of chunked admission; only the
    long-tail mix gates acceptance."""
    wave = 8
    B = 4 * wave
    N = 24 if quick else 48
    C = 32
    P_long = 384 if quick else 512
    P_short = 24
    chunk = 1
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(4), (B, P_long), 0,
                                 cfg.vocab_size, jnp.int32)
    rng = np.random.default_rng(7 + SEED)
    gen_lens = np.minimum(rng.geometric(3.0 / N, B), N)   # long-tail
    useful = int(gen_lens.sum())
    mixes = {
        "long-tail-prompt": rng.choice([P_short, P_long], size=B,
                                       p=[0.75, 0.25]),
        "bimodal-prompt": rng.choice([P_short, P_long], size=B,
                                     p=[0.5, 0.5]),
    }
    sampler = rollout.SamplerConfig(max_new_tokens=N, greedy=True)
    rows, js = [], {}
    for mix, plens in mixes.items():
        res = {}
        for label, pc in (("one-shot", 0), ("chunked", C)):
            def run(pc=pc, measure_ttft=False):
                return genserve.generate(
                    params, cfg, prompts, jax.random.PRNGKey(2), sampler,
                    wave=wave, decode_chunk=chunk, gen_lens=gen_lens,
                    prompt_lens=plens, prefill_chunk=pc,
                    measure_ttft=measure_ttft, fast_path=False)
            # timing runs are uninstrumented (TTFT stamping costs the
            # one-shot path a device sync per admit batch, which would
            # bias the tok/s comparison); TTFT comes from a separate
            # instrumented run
            t, (ro, stats) = timed_best(run)
            assert int(np.asarray(ro["mask"]).sum()) == useful
            _, ttft_stats = run(measure_ttft=True)
            p50, p95 = ttft_quantiles(ttft_stats)
            pred = predicted_occupancy(
                B, wave=wave, gen_lens=[int(l) for l in gen_lens],
                prefill_rounds=np.ceil(plens / C).tolist() if pc else 0.0)
            occ = stats["busy_occupancy"] if pc else stats["mean_occupancy"]
            # the ideal bound must hold on mixed rounds too (the
            # measured-vs-predicted comparison stays honest)
            assert 0.0 < occ <= pred + 1e-9, (mix, label, occ, pred)
            res[label] = {"wall_s": t, "tok_s": useful / t,
                          "ttft_p50_s": p50, "ttft_p95_s": p95,
                          "occupancy": occ, "ideal_occupancy": pred,
                          "decode_rounds": stats["decode_steps"],
                          "prefill_rounds": stats.get("prefill_rounds", 0)}
            rows.append({"mix": mix, "admission": label, **res[label]})
        js[mix] = {**{f"{m}_{k}": v for k, r in res.items()
                      for m, v in r.items()},
                   "tok_s_speedup":
                       res["chunked"]["tok_s"] / res["one-shot"]["tok_s"],
                   "ttft_p50_speedup":
                       res["one-shot"]["ttft_p50_s"]
                       / max(res["chunked"]["ttft_p50_s"], 1e-9),
                   "useful_tokens": useful,
                   "prompt_lens_mean": float(np.mean(plens)),
                   "prefill_chunk": C}
    # acceptance: on the long-prompt mix chunked admission must beat
    # one-shot on throughput AND time-to-first-token (margins are large
    # — ~2x on both — so container noise does not flake this)
    lt = js["long-tail-prompt"]
    assert lt["tok_s_speedup"] > 1.0, lt
    assert lt["ttft_p50_speedup"] > 1.0, lt
    return rows, js


def _prefix_axis(quick, timed_best):
    """Paged KV + radix prefix reuse vs the PR-5 chunked baseline.

    Three traces at the same (wave, batch, gen-length) point:

    ``grpo-ksamples`` — 8 distinct prompts x k=4 samples each (the GRPO
    rollout shape: every prompt decoded k times for the group
    baseline), ordered sample-major so the first wave admits the 8
    distinct prompts (all radix misses — pages are published at prefill
    *landing*, not admission) and every later admission re-sees a
    published prompt.  Later samples match everything but the last
    token (the hit is capped at plen-1 so the landing chunk still runs
    and emits the first token), so the expected token hit rate is about
    (k-1)/k * (P-1)/P ~ 74%; the axis gates at >= 50%.

    ``multitenant-shared-sys`` — every request carries the same hot
    system prompt (3/4 of the prompt) with a distinct user tail: later
    admissions skip prefill on the shared prefix and pay only a
    copy-on-write of the one divergent partial page.

    ``no-sharing`` — fully distinct prompts with the prefix cache off:
    the paged cache runs through the identity block table on the exact
    round schedule of the contiguous baseline, so the comparison
    isolates the gather/scatter indirection overhead (claim: ~0, gated
    at <= 5% tok/s).

    Both engines impose the same gen_lens, so useful tokens — and
    therefore tok/s and TTFT — are apples-to-apples."""
    wave = 8
    n_prompts, k = 8, 4
    B = n_prompts * k
    N = 16 if quick else 24
    C = 32
    P = 192 if quick else 256
    ps = 16
    S = (P * 3) // 4                 # shared system-prompt tokens
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11 + SEED)
    base = rng.integers(0, cfg.vocab_size, (n_prompts, P))
    grpo = np.tile(base, (k, 1))     # sample-major: wave 0 = 8 distinct
    shared = rng.integers(0, cfg.vocab_size, (B, P))
    shared[:, :S] = shared[0, :S]
    distinct = rng.integers(0, cfg.vocab_size, (B, P))
    gen_lens = np.minimum(rng.geometric(3.0 / N, B), N)   # long-tail
    useful = int(gen_lens.sum())
    sampler = rollout.SamplerConfig(max_new_tokens=N, greedy=True)

    def run_engine(prompts, page, prefix, measure_ttft=False):
        return genserve.generate(
            params, cfg, jnp.asarray(prompts, jnp.int32),
            jax.random.PRNGKey(2), sampler, wave=wave, decode_chunk=1,
            gen_lens=gen_lens, prefill_chunk=C, page_size=page,
            prefix_cache=prefix, measure_ttft=measure_ttft,
            fast_path=False)

    traces = (("grpo-ksamples", grpo, True),
              ("multitenant-shared-sys", shared, True),
              ("no-sharing", distinct, False))
    rows, js = [], {}
    for trace, prompts, prefix in traces:
        paged_label = "paged+prefix" if prefix else "paged"
        res = {}
        for label, page, pfx in (("chunked", 0, False),
                                 (paged_label, ps, prefix)):
            # best-of-4: the no-sharing trace gates a <= 5% overhead
            # ratio of two separately-timed runs, inside container
            # timing noise at best-of-2
            t, (ro, stats) = timed_best(
                lambda page=page, pfx=pfx: run_engine(prompts, page, pfx),
                repeats=4)
            assert int(np.asarray(ro["mask"]).sum()) == useful
            _, ttft_stats = run_engine(prompts, page, pfx,
                                       measure_ttft=True)
            p50, p95 = ttft_quantiles(ttft_stats)
            if pfx:
                # host allocator invariants after a full serve
                stats["_pagepool"].check()
            res[label] = {"wall_s": t, "tok_s": useful / t,
                          "ttft_p50_s": p50, "ttft_p95_s": p95,
                          "prefill_rounds": stats.get("prefill_rounds", 0),
                          "prefix_hit_rate":
                              stats.get("prefix_hit_rate", 0.0),
                          "prefill_tokens_skipped":
                              stats.get("prefill_tokens_skipped", 0)}
            rows.append({"trace": trace, "engine": label, **res[label]})
        js[trace] = {**{f"{m}_{lbl}": v for lbl, r in res.items()
                        for m, v in r.items()},
                     "tok_s_ratio":
                         res[paged_label]["tok_s"] / res["chunked"]["tok_s"],
                     "ttft_p50_speedup":
                         res["chunked"]["ttft_p50_s"]
                         / max(res[paged_label]["ttft_p50_s"], 1e-9),
                     "useful_tokens": useful, "page_size": ps,
                     "prefill_chunk": C, "prompt_len": P,
                     "shared_prefix_tokens": S if trace != "no-sharing"
                         else 0}
    # acceptance: prefix reuse must win throughput AND first-token
    # latency on both sharing traces, hit >= 50% of prompt tokens on
    # the GRPO mix, and the paged indirection must cost <= 5% tok/s on
    # the no-sharing trace (identity-block-table fallback)
    g = js["grpo-ksamples"]
    assert g["prefix_hit_rate_paged+prefix"] >= 0.5, g
    assert g["tok_s_ratio"] > 1.0, g
    assert g["ttft_p50_speedup"] > 1.0, g
    mt = js["multitenant-shared-sys"]
    assert mt["tok_s_ratio"] > 1.0, mt
    assert mt["ttft_p50_speedup"] > 1.0, mt
    ns = js["no-sharing"]
    assert ns["prefix_hit_rate_paged"] == 0.0, ns
    assert ns["tok_s_ratio"] >= 0.95, ns
    return rows, js


def _speculative_axis(quick, timed_best):
    """Draft-k speculative decoding vs the PR-6 paged chunked baseline.

    The accept-rate axis, not a draft-quality axis: the target's
    layers past the first are zeroed (the residual passes through
    untouched, since a zero-weight rmsnorm and a zeroed FFN/attention
    block contribute exactly 0), so a 1-layer draft built from the
    target's own first layer agrees with it at essentially every
    position — near-total acceptance, the regime speculation is for.
    The 4-layer target against the 1-layer draft gives the 4x
    depth ratio that makes proposals cheap relative to verification.  A fresh randomly
    initialized 1-layer draft rides along as the low-quality
    counterpoint (measured, not gated).  All runs are greedy, so the
    speculative paths must emit the baseline's exact tokens — the
    throughput comparison is over identical output.

    Gates: the self-distilled draft must clear >= 1.3x useful tok/s
    over the paged non-speculative baseline at its best k, and the cost
    model's predicted speedup (``gen_speculative_wave`` at the measured
    accept rate vs ``c_hbm``) must land within 2x of measured."""
    from repro.core import enumerate as cm_enum
    from repro.core import topology as topo_mod
    from repro.core import workflow as wf_mod
    from repro.core.costmodel import CostModel

    wave = 8
    B = 2 * wave
    N = 24 if quick else 48
    C = 32
    P = 64
    ps = 16
    cfg = dataclasses.replace(_cfg(), name="genserve-spec-bench",
                              n_layers=4, d_model=128, head_dim=32,
                              d_ff=256)
    params = T.init_params(jax.random.PRNGKey(SEED), cfg)
    params = dict(params, blocks=jax.tree_util.tree_map(
        lambda x: x.at[1:].set(0.0) if x.shape[0] == cfg.n_layers else x,
        params["blocks"]))
    dcfg = dataclasses.replace(cfg, name=f"{cfg.name}-draft", n_layers=1)
    d_self = {"embed": params["embed"], "final_norm": params["final_norm"],
              "blocks": jax.tree_util.tree_map(lambda x: x[:1],
                                               params["blocks"])}
    d_fresh = T.init_params(jax.random.PRNGKey(SEED + 3), dcfg)
    prompts = jax.random.randint(jax.random.PRNGKey(SEED + 4), (B, P), 0,
                                 cfg.vocab_size, jnp.int32)
    sampler = rollout.SamplerConfig(max_new_tokens=N, greedy=True)
    useful = B * N

    def run_engine(spec_k=0, dparams=None):
        return genserve.generate(
            params, cfg, prompts, jax.random.PRNGKey(2), sampler,
            wave=wave, decode_chunk=1, prefill_chunk=C, page_size=ps,
            spec_k=spec_k, draft_params=dparams,
            draft_cfg=dcfg if spec_k else None, fast_path=False)

    t_base, (ro_base, bstats) = timed_best(run_engine)
    base_tokens = (np.asarray(ro_base["gen_tokens"])
                   * np.asarray(ro_base["mask"]))
    assert int(np.asarray(ro_base["mask"]).sum()) == useful
    rows = [{"variant": "paged-baseline", "spec_k": 0, "accept_rate": 0.0,
             "wall_s": t_base, "tok_s": useful / t_base, "speedup": 1.0,
             "decode_rounds": bstats["decode_steps"]}]
    js = {"spec_ks": [], "prompt_len": P, "new_tokens": N, "wave": wave,
          "batch": B, "page_size": ps,
          "baseline_tok_s": useful / t_base,
          "baseline_decode_rounds": bstats["decode_steps"]}
    variants = [("self-draft", k, d_self) for k in (2, 4, 8)]
    variants.append(("fresh-draft", 4, d_fresh))
    best_self = None
    for name, k, dp in variants:
        t, (ro, stats) = timed_best(lambda k=k, dp=dp: run_engine(k, dp))
        # greedy speculation must be invisible in the tokens
        np.testing.assert_array_equal(
            np.asarray(ro["gen_tokens"]) * np.asarray(ro["mask"]),
            base_tokens)
        r = {"variant": name, "spec_k": k,
             "accept_rate": stats["accept_rate"],
             "wall_s": t, "tok_s": useful / t, "speedup": t_base / t,
             "decode_rounds": stats["decode_steps"]}
        rows.append(r)
        js["spec_ks"].append(r)
        if name == "self-draft" and (best_self is None
                                     or r["speedup"] > best_self["speedup"]):
            best_self = r

    # cost-model prediction at the measured accept rate and best k
    spec = wf_mod.LLMSpec.from_model_config(cfg)
    wf = wf_mod.make_workflow("grpo", spec, synchronous=True,
                              n_rollouts=2, seq_in=P, seq_out=N,
                              global_batch=1)
    topo = topo_mod.build_testbed("single_region",
                                  counts={"A100": 2, "L4": 2})
    plan = cm_enum.build_plan(topo, wf, (tuple(range(wf.n_tasks)),),
                              [topo.n], list(range(topo.n)))
    cm = CostModel(topo, wf)
    gen_t = next(t for t in range(wf.n_tasks)
                 if wf.task(t).kind == wf_mod.TaskKind.GEN)
    pred = cm.c_hbm(plan, gen_t, 0, 0) / cm.gen_speculative_wave(
        plan, gen_t, 0, 0, spec_k=best_self["spec_k"],
        accept_rate=best_self["accept_rate"],
        draft=wf_mod.LLMSpec.from_model_config(dcfg))
    js.update({"best_speedup": best_self["speedup"],
               "best_spec_k": best_self["spec_k"],
               "best_accept_rate": best_self["accept_rate"],
               "predicted_speedup": pred,
               "predicted_vs_measured": pred / best_self["speedup"]})
    # acceptance: >= 1.3x tok/s at high accept rate, prediction within 2x
    assert best_self["speedup"] >= 1.3, js
    assert 0.5 <= js["predicted_vs_measured"] <= 2.0, js
    return rows, js


def _single_wave(gen, params, prompts, wave):
    """The pre-genserve GEN executor: ceil(B/W) sequential full waves,
    every sequence decoded for all N steps (finished rows masked, not
    retired).  Useful tokens = the imposed lengths."""
    B = prompts.shape[0]
    key = jax.random.PRNGKey(0)
    outs = []
    for lo in range(0, B, wave):
        key, k = jax.random.split(key)
        outs.append(gen(params, prompts=prompts[lo:lo + wave], rng=k))
    for o in outs:
        jax.block_until_ready(o["sequences"])
    return outs


def run(quick: bool = QUICK):
    wave = MAX_DECODE_WAVE
    B = 4 * wave
    N = 32 if quick else 64
    P = 16
    chunk = 8
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size, jnp.int32)
    sampler = rollout.SamplerConfig(max_new_tokens=N, greedy=True)
    gen = jax.jit(functools.partial(rollout.generate, cfg=cfg,
                                    sampler=sampler))

    def timed_best(fn, repeats=2):
        """Warm (compile) once, then best-of-n to cut scheduler noise."""
        fn()
        best = float("inf")
        out = None
        for _ in range(repeats):
            t0 = time.monotonic()
            out = fn()
            best = min(best, time.monotonic() - t0)
        return best, out

    rows, path_rows = [], []
    js = {"wave": wave, "batch": B, "max_new_tokens": N,
          "prompt_len": P, "decode_chunk": chunk, "results": {}}
    for seed, dist in enumerate(("uniform", "bimodal", "long-tail")):
        lens = _lengths(dist, B, N, np.random.default_rng(100 + seed + SEED))
        useful = int(lens.sum())

        t_single, _ = timed_best(
            lambda: _single_wave(gen, params, prompts, wave))
        t_gs, (ro, stats) = timed_best(
            lambda: genserve.generate(params, cfg, prompts,
                                      jax.random.PRNGKey(2), sampler,
                                      wave=wave, decode_chunk=chunk,
                                      gen_lens=lens, fast_path=False))
        assert int(np.asarray(ro["mask"]).sum()) == useful

        ideal = predicted_occupancy(B, wave=wave, gen_lens=lens)
        speedup = t_single / t_gs
        for engine, t, occ, steps in (
                ("single-wave", t_single, useful / (np.ceil(B / wave) * N),
                 int(np.ceil(B / wave) * N)),
                ("genserve", t_gs, stats["mean_occupancy"],
                 stats["decode_steps"])):
            rows.append({"dist": dist, "engine": engine,
                         "wall_s": t, "tok_s": useful / t,
                         "occupancy": occ, "ideal_occupancy": ideal,
                         "decode_steps": steps,
                         "speedup": speedup if engine == "genserve" else 1.0})

        step_s = _decode_path_axis(cfg, params, wave, P, N, lens,
                                   quick=quick)
        t_vm = step_s["vmapped-per-slot"]
        for label, t in step_s.items():
            path_rows.append({"dist": dist, "decode_path": label,
                              "step_ms": t * 1e3,
                              "wave_tok_s": wave / t,
                              "speedup_vs_vmapped": t_vm / t})

        js["results"][dist] = {
            "useful_tokens": useful,
            "single_wave_s": t_single, "genserve_s": t_gs,
            "single_wave_tok_s": useful / t_single,
            "genserve_tok_s": useful / t_gs,
            "speedup": speedup,
            "genserve_occupancy": stats["mean_occupancy"],
            "genserve_decode_steps": stats["decode_steps"],
            "single_wave_decode_steps": int(np.ceil(B / wave) * N),
            "ideal_occupancy": ideal,
            "decode_path_step_s": step_s,
            "batched_vs_vmapped_speedup":
                t_vm / step_s["batched-jnp"],
        }

    adm_rows, adm_js = _admission_axis(quick, timed_best)
    js["admission"] = adm_js
    for mix, r in adm_js.items():
        print(f"[admission:{mix}] chunked vs one-shot: "
              f"tok/s x{r['tok_s_speedup']:.2f}, "
              f"ttft p50 x{r['ttft_p50_speedup']:.2f}")

    pfx_rows, pfx_js = _prefix_axis(quick, timed_best)
    js["prefix"] = pfx_js
    for trace, r in pfx_js.items():
        hit = r.get("prefix_hit_rate_paged+prefix",
                    r.get("prefix_hit_rate_paged", 0.0))
        print(f"[prefix:{trace}] paged vs chunked: "
              f"tok/s x{r['tok_s_ratio']:.2f}, "
              f"ttft p50 x{r['ttft_p50_speedup']:.2f}, "
              f"hit rate {hit:.1%}")

    spec_rows, spec_js = _speculative_axis(quick, timed_best)
    js["speculative"] = spec_js
    print(f"[speculative] best x{spec_js['best_speedup']:.2f} tok/s at "
          f"k={spec_js['best_spec_k']} "
          f"(accept {spec_js['best_accept_rate']:.1%}; cost model "
          f"predicts x{spec_js['predicted_speedup']:.2f})")

    emit("genserve_throughput", rows)
    emit("genserve_decode_path", path_rows)
    emit("genserve_admission", adm_rows)
    emit("genserve_prefix", pfx_rows)
    emit("genserve_speculative", spec_rows)
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "genserve_throughput.json")
    with open(path, "w") as f:
        json.dump(js, f, indent=2)
    print(f"[genserve_throughput] wrote {path}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    run()
