"""Continuous batching vs single-wave decode across gen-length skews.

The acceptance axis of the genserve subsystem: with a rollout batch of
4 x MAX_DECODE_WAVE requests and skewed output-length distributions
(uniform / bimodal / long-tail), the wave-recycling engine must beat the
single-wave GEN executor on useful tokens/sec — the single-wave path
decodes every sequence for the full budget whether finished or not,
while genserve retires finished slots and back-fills them from the
queue.  Also reports measured mean wave occupancy next to the ideal
continuous-batching occupancy from ``core.plan.predicted_occupancy``.

Decode-path axis: the jitted wave-step latency per execution path —
``vmapped-per-slot`` (the legacy W-way vmap of a B=1 decode_step),
``batched-jnp`` (one natively batched decode_step with per-slot cache
positions — the fast path this repo now defaults to) and
``batched-pallas-interpret`` (the same batched step routed through the
Sq == 1 flash-decode Pallas kernel in interpreter mode — a lowering /
parity axis on CPU; the compiled-TPU configuration is the perf target).
Alternating A/B repetitions with a median cut through container timing
noise; the per-slot cache positions are drawn from each distribution so
recycled-slot raggedness is represented.

Writes both the benchmark CSV and ``results/genserve_throughput.json``.
"""
from __future__ import annotations

import functools
import json
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import MAX_DECODE_WAVE, predicted_occupancy
from repro.genserve import adapter as genserve
from repro.genserve import decoder as gs_decoder
from repro.models import attention as attn_mod
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.rl import rollout

from benchmarks.common import QUICK, emit


def _cfg():
    # large enough that decode-step FLOPs dominate dispatch overhead —
    # below ~d_model 256 on CPU the single-wave fused scan wins on
    # per-step cost and the wave-recycling advantage is buried
    return ModelConfig(name="genserve-bench", n_layers=2, d_model=256,
                       n_heads=4, n_kv_heads=2, head_dim=64, d_ff=512,
                       vocab_size=128, dtype="float32")


def _lengths(dist: str, B: int, N: int, rng: np.random.Generator):
    if dist == "uniform":
        return rng.integers(1, N + 1, B)
    if dist == "bimodal":
        return rng.choice([max(N // 8, 1), N], size=B, p=[0.5, 0.5])
    if dist == "long-tail":
        return np.minimum(rng.geometric(3.0 / N, B), N)
    raise ValueError(dist)


def _decode_path_axis(cfg, params, wave, P, N, lens, *, quick):
    """Median jitted wave-step latency per decode path (alternating A/B).

    The wave state is mid-stream: every slot occupied, per-slot cache
    positions spread per the imposed length distribution (recycled-slot
    raggedness included).  Paths share the state, so the axis isolates
    the decode-step program itself."""
    gcfg0 = gs_decoder.GenServeConfig(wave=wave, max_new_tokens=N)
    state = gs_decoder._init_state(cfg, gcfg0, P, len(lens))
    rng = np.random.default_rng(0)
    progress = rng.integers(0, np.maximum(lens[:wave], 1))
    state = dict(state,
                 occupied=jnp.ones((wave,), bool),
                 pos=jnp.asarray(P + progress, jnp.int32),
                 limit=jnp.full((wave,), N, jnp.int32))
    keys = jax.random.split(jax.random.PRNGKey(9), 1)

    paths = (("vmapped-per-slot", "vmapped", "jnp"),
             ("batched-jnp", "batched", "jnp"),
             ("batched-pallas-interpret", "batched", "pallas"))
    fns = {}
    prev_impl = attn_mod.get_attention_impl()
    for label, decode_path, impl in paths:
        gcfg = gs_decoder.GenServeConfig(wave=wave, max_new_tokens=N,
                                         greedy=True,
                                         decode_path=decode_path)
        try:
            attn_mod.set_attention_impl(impl)
            _, chunk_fn = gs_decoder._build_fns(cfg, gcfg, P, len(lens),
                                                impl)
            _, c = chunk_fn(params, state, keys)       # trace + compile
            jax.block_until_ready(c)
        finally:
            attn_mod.set_attention_impl(prev_impl)
        fns[label] = chunk_fn

    reps = 10 if quick else 30
    times = {label: [] for label, *_ in paths}
    for _ in range(reps):
        for label, f in fns.items():
            t0 = time.monotonic()
            _, c = f(params, state, keys)
            jax.block_until_ready(c)
            times[label].append(time.monotonic() - t0)
    return {label: statistics.median(ts) for label, ts in times.items()}


def _single_wave(gen, params, prompts, wave):
    """The pre-genserve GEN executor: ceil(B/W) sequential full waves,
    every sequence decoded for all N steps (finished rows masked, not
    retired).  Useful tokens = the imposed lengths."""
    B = prompts.shape[0]
    key = jax.random.PRNGKey(0)
    outs = []
    for lo in range(0, B, wave):
        key, k = jax.random.split(key)
        outs.append(gen(params, prompts=prompts[lo:lo + wave], rng=k))
    for o in outs:
        jax.block_until_ready(o["sequences"])
    return outs


def run(quick: bool = QUICK):
    wave = MAX_DECODE_WAVE
    B = 4 * wave
    N = 32 if quick else 64
    P = 16
    chunk = 8
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size, jnp.int32)
    sampler = rollout.SamplerConfig(max_new_tokens=N, greedy=True)
    gen = jax.jit(functools.partial(rollout.generate, cfg=cfg,
                                    sampler=sampler))

    def timed_best(fn, repeats=2):
        """Warm (compile) once, then best-of-n to cut scheduler noise."""
        fn()
        best = float("inf")
        out = None
        for _ in range(repeats):
            t0 = time.monotonic()
            out = fn()
            best = min(best, time.monotonic() - t0)
        return best, out

    rows, path_rows = [], []
    js = {"wave": wave, "batch": B, "max_new_tokens": N,
          "prompt_len": P, "decode_chunk": chunk, "results": {}}
    for seed, dist in enumerate(("uniform", "bimodal", "long-tail")):
        lens = _lengths(dist, B, N, np.random.default_rng(100 + seed))
        useful = int(lens.sum())

        t_single, _ = timed_best(
            lambda: _single_wave(gen, params, prompts, wave))
        t_gs, (ro, stats) = timed_best(
            lambda: genserve.generate(params, cfg, prompts,
                                      jax.random.PRNGKey(2), sampler,
                                      wave=wave, decode_chunk=chunk,
                                      gen_lens=lens, fast_path=False))
        assert int(np.asarray(ro["mask"]).sum()) == useful

        ideal = predicted_occupancy(B, wave=wave, gen_lens=lens)
        speedup = t_single / t_gs
        for engine, t, occ, steps in (
                ("single-wave", t_single, useful / (np.ceil(B / wave) * N),
                 int(np.ceil(B / wave) * N)),
                ("genserve", t_gs, stats["mean_occupancy"],
                 stats["decode_steps"])):
            rows.append({"dist": dist, "engine": engine,
                         "wall_s": t, "tok_s": useful / t,
                         "occupancy": occ, "ideal_occupancy": ideal,
                         "decode_steps": steps,
                         "speedup": speedup if engine == "genserve" else 1.0})

        step_s = _decode_path_axis(cfg, params, wave, P, N, lens,
                                   quick=quick)
        t_vm = step_s["vmapped-per-slot"]
        for label, t in step_s.items():
            path_rows.append({"dist": dist, "decode_path": label,
                              "step_ms": t * 1e3,
                              "wave_tok_s": wave / t,
                              "speedup_vs_vmapped": t_vm / t})

        js["results"][dist] = {
            "useful_tokens": useful,
            "single_wave_s": t_single, "genserve_s": t_gs,
            "single_wave_tok_s": useful / t_single,
            "genserve_tok_s": useful / t_gs,
            "speedup": speedup,
            "genserve_occupancy": stats["mean_occupancy"],
            "genserve_decode_steps": stats["decode_steps"],
            "single_wave_decode_steps": int(np.ceil(B / wave) * N),
            "ideal_occupancy": ideal,
            "decode_path_step_s": step_s,
            "batched_vs_vmapped_speedup":
                t_vm / step_s["batched-jnp"],
        }

    emit("genserve_throughput", rows)
    emit("genserve_decode_path", path_rows)
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "genserve_throughput.json")
    with open(path, "w") as f:
        json.dump(js, f, indent=2)
    print(f"[genserve_throughput] wrote {path}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    run()
