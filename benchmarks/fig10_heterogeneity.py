"""Figure 10: throughput under varying GPU combinations (Qwen-8B):
24xA100 only, +L40S, +L4, ALL; HetRL vs verl, PPO/GRPO sync(+async).

Paper: HetRL 1.57-4.33x verl; ALL-GPUs 1.57-2.0x over 24xA100-only."""
from __future__ import annotations

from repro.core import baselines, topology, workflow
from repro.core.sha import HybridScheduler

from benchmarks.common import QUICK, emit


COMBOS = {
    "24xA100": {"A100": 24},
    "24xA100+24xL40S": {"A100": 24, "L40S": 24},
    "24xA100+16xL4": {"A100": 24, "L4": 16},
    "ALL": {"A100": 24, "L40S": 24, "L4": 16},
}


def run(quick: bool = QUICK):
    algos = [("ppo", True), ("grpo", True)] if quick else \
        [("ppo", True), ("grpo", True), ("ppo", False), ("grpo", False)]
    budget = 250 if quick else 1000
    rows = []
    base_thpt = {}
    for combo, counts in COMBOS.items():
        topo = topology.build_testbed("single_region", counts=counts)
        for algo, sync in algos:
            wf = workflow.make_workflow(algo, workflow.QWEN_8B,
                                        synchronous=sync)
            r_verl = baselines.verl_scheduler(topo, wf)
            sched = HybridScheduler(topo, wf, max_groupings=12,
                                    max_sizes_per_grouping=4)
            r = sched.search(budget=budget)
            thpt = wf.samples_per_iter / r.cost
            key = (algo, sync)
            if combo == "24xA100":
                base_thpt[key] = thpt
            rows.append({
                "gpus": combo, "algo": algo,
                "mode": "sync" if sync else "async",
                "hetrl_thpt": round(thpt, 2),
                "verl_thpt": round(wf.samples_per_iter / r_verl.cost, 2),
                "speedup_vs_verl": round(r_verl.cost / r.cost, 2),
                "vs_24xA100": round(thpt / base_thpt[key], 2),
            })
    emit("fig10_heterogeneity", rows)
    print("[fig10] paper: 1.57-4.33x vs verl; ALL vs 24xA100: 1.57-2.0x")
    return rows


if __name__ == "__main__":
    run()
