"""Figure 7: cost-model validation — predicted vs observed iteration time.

Two validations (no GPU cluster here, DESIGN.md):
  (a) closed-form Appendix-B composition vs the discrete-event simulator
      across scenarios/model sizes (composition error);
  (b) cost model vs REAL wall-clock of tiny RL iterations executed on this
      host's JAX device, with device specs calibrated by the profiler
      (absolute error at laptop scale)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import profiler, simulator, topology, workflow
from repro.core.costmodel import CostModel
from repro.core.sha import HybridScheduler

from benchmarks.common import QUICK, emit


def composition_error(quick: bool):
    rows = []
    sizes = ["8b"] if quick else ["4b", "8b", "14b"]
    for scen in topology.SCENARIOS:
        topo = topology.build_testbed(scen)
        for size in sizes:
            wf = workflow.make_ppo(workflow.QWEN[size])
            sched = HybridScheduler(topo, wf, max_groupings=8,
                                    max_sizes_per_grouping=4)
            r = sched.search(budget=150)
            sim = simulator.simulate(topo, wf, r.plan, n_iterations=5)
            err = abs(sim.iteration_time - r.cost) / sim.iteration_time
            rows.append({
                "kind": "composition", "scenario": scen, "model": size,
                "predicted_s": round(r.cost, 1),
                "observed_s": round(sim.iteration_time, 1),
                "error_pct": round(100 * err, 1),
            })
    return rows


def real_execution_error():
    """Tiny RL iteration on the actual host device vs cost model with
    profiler-calibrated specs."""
    import jax
    from repro.core.topology import Device, GPUSpec, Topology
    from repro.data.synthetic import AdditionTask, VOCAB_SIZE
    from repro.models.config import ModelConfig
    from repro.rl.trainer import RLConfig, RLTrainer
    import numpy as np

    tflops = profiler.calibrate_local_device(size=512, iters=4)
    spec = GPUSpec("host-cpu", tflops, 8.0, 10.0, 10.0)
    topo = Topology([Device(0, spec, 0, 0, "local")],
                    np.zeros((1, 1)), np.full((1, 1), 10.0))

    cfg = ModelConfig(name="val", n_layers=2, d_model=128, n_heads=4,
                      n_kv_heads=2, head_dim=32, d_ff=256,
                      vocab_size=VOCAB_SIZE, dtype="float32")
    task = AdditionTask(max_operand=9)
    B, G, NEW = 8, 2, 4
    rl = RLConfig(algorithm="grpo", n_rollouts=G, max_new_tokens=NEW)
    trainer = RLTrainer(cfg, rl, task, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts, answers = task.sample_batch(rng, B)
    trainer.iteration(prompts, answers, jax.random.PRNGKey(1))  # warmup
    t0 = time.perf_counter()
    iters = 3
    for i in range(iters):
        trainer.iteration(prompts, answers, jax.random.PRNGKey(2 + i))
    observed = (time.perf_counter() - t0) / iters

    spec_model = workflow.LLMSpec(
        "val", cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size,
        cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim)
    wf = workflow.make_grpo(spec_model, seq_in=task.prompt_len,
                            seq_out=NEW, global_batch=B, n_rollouts=G,
                            micro_batch=B * G)
    from repro.core import enumerate as enum_mod
    plan = enum_mod.build_plan(topo, wf, ((0, 1, 2, 3),), [1], [0])
    predicted = CostModel(topo, wf).cost(plan)
    err = abs(predicted - observed) / observed
    return [{
        "kind": "real-exec", "scenario": "host-cpu", "model": "tiny",
        "predicted_s": round(predicted, 3),
        "observed_s": round(observed, 3),
        "error_pct": round(100 * err, 1),
    }]


def run(quick: bool = QUICK):
    rows = composition_error(quick) + real_execution_error()
    emit("fig7_costmodel_validation", rows)
    comp = [r["error_pct"] for r in rows if r["kind"] == "composition"]
    print(f"[fig7] composition error mean={np.mean(comp):.1f}% "
          f"(paper: single-region comparable to pretraining estimators, "
          f"higher cross-region)")
    return rows


if __name__ == "__main__":
    run()
