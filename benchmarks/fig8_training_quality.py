"""Figures 8/9: training quality, synchronous vs asynchronous GRPO.

The paper shows GRPO-Sync and GRPO-Async reach comparable validation
accuracy by step (heterogeneous execution does not hurt convergence) and
async converges faster by wall-clock.  We reproduce the quality axis with
real RL training on the verifiable addition task: reward-by-iteration
curves for sync vs one-step-off-policy async must track each other.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.data.synthetic import AdditionTask, PromptDataset, VOCAB_SIZE
from repro.models.config import ModelConfig
from repro.rl.trainer import RLConfig, RLTrainer

from benchmarks.common import QUICK, emit


def run(quick: bool = QUICK):
    iters = 40 if quick else 60
    cfg = ModelConfig(name="fig8", n_layers=2, d_model=96, n_heads=4,
                      n_kv_heads=2, head_dim=24, d_ff=192,
                      vocab_size=VOCAB_SIZE, dtype="float32")
    task = AdditionTask(max_operand=4)
    rows = []
    finals = {}
    for mode in ("sync", "async"):
        rl = RLConfig(algorithm="grpo", n_rollouts=8, max_new_tokens=3,
                      lr=5e-4, kl_beta=0.0, asynchronous=(mode == "async"))
        trainer = RLTrainer(cfg, rl, task, jax.random.PRNGKey(0))
        ds = iter(PromptDataset(task, batch=12, seed=1))
        key = jax.random.PRNGKey(9)
        rewards = []
        for it in range(iters):
            prompts, answers = next(ds)
            key, k = jax.random.split(key)
            m = trainer.iteration(prompts, answers, k)
            if not m.get("pipeline_fill"):
                rewards.append(m["reward_mean"])
        finals[mode] = float(np.mean(rewards[-4:]))
        for i, r in enumerate(rewards):
            if i % max(len(rewards) // 8, 1) == 0 or i == len(rewards) - 1:
                rows.append({"mode": mode, "iter": i,
                             "reward": round(r, 3)})
    rows.append({"mode": "final-gap", "iter": iters,
                 "reward": round(abs(finals["sync"] - finals["async"]), 3)})
    emit("fig8_training_quality", rows)
    print(f"[fig8] final reward sync={finals['sync']:.3f} "
          f"async={finals['async']:.3f} (paper: comparable by step)")
    return rows


if __name__ == "__main__":
    run()
