"""Figure 3: end-to-end throughput of HetRL vs verl vs StreamRL across the
four network scenarios, Qwen 4B/8B/14B, PPO + GRPO, sync + async.

Paper headline: HetRL up to 9.17x verl, 3.17x average; per-scenario bands
in §5.2.  Throughput = samples/s from the cost model on the discovered
plan (the simulator cross-checks the composition; Fig 7 validates the
model itself)."""
from __future__ import annotations

from repro.core import baselines, topology, workflow
from repro.core.sha import HybridScheduler

from benchmarks.common import QUICK, emit, timer


def run(quick: bool = QUICK):
    sizes = ["8b"] if quick else ["4b", "8b", "14b"]
    algos = ["ppo", "grpo"]
    syncs = [True] if quick else [True, False]
    budget = 250 if quick else 1200
    rows = []
    ratios = []
    for scen in topology.SCENARIOS:
        topo = topology.build_testbed(scen)
        for size in sizes:
            for algo in algos:
                for sync in syncs:
                    wf = workflow.make_workflow(
                        algo, workflow.QWEN[size], synchronous=sync)
                    r_verl = baselines.verl_scheduler(topo, wf)
                    r_srl = baselines.streamrl_scheduler(topo, wf,
                                                         budget=2048)
                    sched = HybridScheduler(topo, wf, max_groupings=16,
                                            max_sizes_per_grouping=4)
                    with timer() as t:
                        r = sched.search(budget=budget)
                    thpt = wf.samples_per_iter / r.cost
                    sv = r_verl.cost / r.cost
                    ss = r_srl.cost / r.cost
                    ratios.append(sv)
                    rows.append({
                        "scenario": scen, "model": size, "algo": algo,
                        "mode": "sync" if sync else "async",
                        "hetrl_s": round(r.cost, 1),
                        "verl_s": round(r_verl.cost, 1),
                        "streamrl_s": round(r_srl.cost, 1),
                        "thpt_samp_s": round(thpt, 2),
                        "speedup_vs_verl": round(sv, 2),
                        "speedup_vs_streamrl": round(ss, 2),
                        "search_wall_s": round(t.seconds, 1),
                    })
    emit("fig3_e2e", rows)
    gm = 1.0
    for x in ratios:
        gm *= x
    gm **= 1.0 / len(ratios)
    print(f"[fig3] max speedup vs verl: {max(ratios):.2f}x "
          f"(paper: up to 9.17x); geomean: {gm:.2f}x (paper avg 3.17x)")
    return rows


if __name__ == "__main__":
    run()
