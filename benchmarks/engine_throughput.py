"""Engine-level throughput benchmark: real RL iterations executed by the
plan-driven engine under different plans and sync modes.

For each (plan, mode) the engine runs real GRPO iterations on the tiny
verifiable-addition actor and reports steady-state measured iteration
time, samples/s, and the cost model's prediction for the same plan on
the 8-GPU reference pool — the measured-vs-predicted axis the paper's
Fig. 7 validates for the cost model.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import enumerate as enum_mod, topology, workflow
from repro.core.plan import check_constraints
from repro.data.synthetic import AdditionTask, PromptDataset, VOCAB_SIZE
from repro.models.config import ModelConfig
from repro.rl.trainer import RLConfig, RLTrainer

from benchmarks.common import QUICK, emit


def _plan(topo, wf, grouping):
    sizes = enum_mod.proportional_sizes(wf, grouping, topo.n)
    plan = enum_mod.build_plan(topo, wf, grouping, sizes,
                               list(range(topo.n)))
    ok, msg = check_constraints(topo, wf, plan)
    assert ok, msg
    return plan


def run(quick: bool = QUICK):
    iters = 8 if quick else 24
    batch = 8
    cfg = ModelConfig(name="engine-bench", n_layers=2, d_model=96,
                      n_heads=4, n_kv_heads=2, head_dim=24, d_ff=192,
                      vocab_size=VOCAB_SIZE, dtype="float32")
    task = AdditionTask(max_operand=9)
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})
    spec = workflow.LLMSpec.from_model_config(cfg)

    rows = []
    for mode in ("sync", "async"):
        wf = workflow.make_grpo(spec, synchronous=(mode == "sync"),
                                global_batch=batch, n_rollouts=4,
                                seq_in=task.prompt_len,
                                seq_out=task.max_answer_len)
        plans = {
            "colocated": _plan(topo, wf, (tuple(range(wf.n_tasks)),)),
            "gen|rest": _plan(topo, wf, tuple(sorted((
                (0,), tuple(range(1, wf.n_tasks)))))),
        }
        for pname, plan in plans.items():
            rl = RLConfig(algorithm="grpo", n_rollouts=4,
                          max_new_tokens=task.max_answer_len,
                          asynchronous=(mode == "async"))
            trainer = RLTrainer(cfg, rl, task, jax.random.PRNGKey(0),
                                plan=plan, topo=topo, wf=wf)
            ds = iter(PromptDataset(task, batch=batch, seed=1))
            key = jax.random.PRNGKey(7)
            for _ in range(iters):
                prompts, answers = next(ds)
                key, k = jax.random.split(key)
                trainer.iteration(prompts, answers, k)
            meas = trainer.engine.measured_result()
            cmp = trainer.engine.compare_with_simulator()
            rows.append({
                "mode": mode, "plan": pname,
                "measured_ms": meas.iteration_time * 1e3,
                "samples_per_s": meas.throughput,
                "predicted_ms": cmp["predicted_iter_s"] * 1e3,
                "ratio": cmp["ratio"],
            })
    emit("engine_throughput", rows)


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    run()
