"""Figure 4: load-balancing ablation — synchronous RL training throughput
with and without the data-level + layer-level strategies.

Paper: up to +12% single-region, +18% cross-region (Metis reaches 19-22%)."""
from __future__ import annotations

from repro.core import topology, workflow
from repro.core.costmodel import CostModel
from repro.core.sha import HybridScheduler

from benchmarks.common import QUICK, emit


def run(quick: bool = QUICK):
    sizes = ["8b"] if quick else ["4b", "8b", "14b"]
    budget = 250 if quick else 1000
    rows = []
    for scen in ["single_region", "multi_country"]:
        topo = topology.build_testbed(scen)
        for size in sizes:
            for algo in ["ppo", "grpo"]:
                wf = workflow.make_workflow(algo, workflow.QWEN[size])
                costs = {}
                for lb in (False, True):
                    sched = HybridScheduler(
                        topo, wf, max_groupings=12,
                        max_sizes_per_grouping=4, use_load_balance=lb)
                    costs[lb] = sched.search(budget=budget).cost
                gain = costs[False] / costs[True] - 1.0
                rows.append({
                    "scenario": scen, "model": size, "algo": algo,
                    "no_lb_s": round(costs[False], 1),
                    "with_lb_s": round(costs[True], 1),
                    "gain_pct": round(100 * gain, 1),
                })
    emit("fig4_loadbalance", rows)
    print("[fig4] paper: +12% single-region / +18% cross-region")
    return rows


if __name__ == "__main__":
    run()
