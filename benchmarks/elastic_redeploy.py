"""Elastic redeployment benchmark (§6): throughput recovery from topology
drift vs staying on the degraded incumbent.

Two axes:

* **recovery** (paper-scale, simulated): search an incumbent plan for a
  GRPO workflow on the healthy multi-machine testbed, inject each named
  drift scenario, run the warm-started ``reschedule``, and compare
  steady-state simulated throughput of (a) the incumbent on the degraded
  topology vs (b) the rescheduled plan — plus the one-off transition cost
  and its break-even horizon.  A dropped-device drift leaves the
  incumbent infeasible (throughput 0), which is exactly the case online
  redeployment exists for.

* **engine smoke** (tiny, real execution): a live trainer crosses a
  forced ``drop_tail`` swap through the elastic controller; reports the
  reschedule wall time, checkpoint size, and per-epoch measured iteration
  times, verifying training state survives (monotone weight version).

Writes the benchmark CSV and a committed ``results/elastic_redeploy.json``.
"""
from __future__ import annotations

import json
import math
import os
import time

import jax
import numpy as np

from repro.core import redeploy, simulator, topology, workflow
from repro.core.plan import check_constraints
from repro.core.sha import HybridScheduler
from repro.data.synthetic import AdditionTask, PromptDataset, VOCAB_SIZE
from repro.engine.elastic import ElasticConfig, ElasticController
from repro.models.config import ModelConfig
from repro.rl.trainer import RLConfig, RLTrainer

from benchmarks.common import QUICK, emit


def _throughput(topo, wf, plan) -> float:
    return simulator.simulate(topo, wf, plan, n_iterations=4).throughput


def recovery_rows(quick: bool):
    """Paper-scale simulated recovery per drift scenario."""
    budget = 150 if quick else 400
    counts = {"A100": 8, "L40S": 8} if quick else None   # None = 64 GPUs
    topo = topology.build_testbed("single_region", counts=counts)
    wf = workflow.make_grpo(workflow.QWEN_1_7B, global_batch=64)
    sched = HybridScheduler(topo, wf, max_groupings=8,
                            max_sizes_per_grouping=4)
    r = sched.search(budget=budget)
    ok, msg = check_constraints(topo, wf, r.plan)
    assert ok, msg
    healthy = _throughput(topo, wf, r.plan)

    rows = []
    for scenario in topology.DRIFT_SCENARIOS:
        drift = topology.drift_scenario(scenario, topo, at=0)
        topo_d = drift.topo_at(0)
        t0 = time.monotonic()
        d = redeploy.reschedule(topo_d, wf, r.plan, budget=budget,
                                topo_old=topo)
        resched_s = time.monotonic() - t0
        incumbent_ok = math.isfinite(d.old_cost)
        thr_old = _throughput(topo_d, wf, r.plan) if incumbent_ok else 0.0
        thr_new = _throughput(topo_d, wf, d.plan) \
            if d.plan is not None else 0.0
        gain = d.old_cost - d.new_cost
        breakeven = d.transition_cost_s / gain \
            if math.isfinite(gain) and gain > 0 else 0.0
        rows.append({
            "scenario": scenario,
            "switch": d.switch,
            "incumbent_feasible": incumbent_ok,
            "healthy_thr": healthy,
            "degraded_incumbent_thr": thr_old,
            "post_swap_thr": thr_new,
            # None when the incumbent is infeasible (recovery from zero)
            "recovery_x": thr_new / thr_old if thr_old > 0 else None,
            "transition_s": d.transition_cost_s,
            "breakeven_iters": breakeven,
            "reschedule_wall_s": resched_s,
        })
    return rows


def engine_smoke(quick: bool):
    """Tiny real run across a forced drop_tail swap."""
    iters = 8 if quick else 16
    drift_at = max(iters // 3, 1)
    cfg = ModelConfig(name="elastic-bench", n_layers=2, d_model=64,
                      n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                      vocab_size=VOCAB_SIZE, dtype="float32")
    task = AdditionTask(max_operand=9)
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})
    spec = workflow.LLMSpec.from_model_config(cfg)
    wf = workflow.make_grpo(spec, global_batch=8, n_rollouts=4,
                            seq_in=task.prompt_len,
                            seq_out=task.max_answer_len)
    sched = HybridScheduler(topo, wf, max_groupings=8,
                            max_sizes_per_grouping=4)
    r = sched.search(budget=120)
    rl = RLConfig(algorithm="grpo", n_rollouts=4,
                  max_new_tokens=task.max_answer_len)
    trainer = RLTrainer(cfg, rl, task, jax.random.PRNGKey(0), plan=r.plan,
                        topo=topo, wf=wf)
    controller = ElasticController(
        trainer, topology.drift_scenario("drop_tail", topo, at=drift_at),
        ElasticConfig(budget=150, ckpt_dir="results/elastic_ckpt"))

    ds = iter(PromptDataset(task, batch=8, seed=1))
    key = jax.random.PRNGKey(7)
    wv = []
    for it in range(iters):
        prompts, answers = next(ds)
        key, k = jax.random.split(key)
        trainer.iteration(prompts, answers, k)
        wv.append(trainer.weight_version)
        controller.poll(it)
    swaps = controller.swaps
    assert swaps, "drop_tail drift must force an applied swap"
    assert all(b >= a for a, b in zip(wv, wv[1:])), \
        "weight_version must stay monotone across the swap"
    rec = swaps[0]
    return {
        "iters": iters,
        "swap_iteration": rec.iteration,
        "switch": rec.decision.switch,
        "reschedule_wall_s": rec.reschedule_s,
        "ckpt_bytes": rec.ckpt_bytes,
        "transition_cost_s": rec.decision.transition_cost_s,
        "final_epoch": trainer.engine.epoch,
        "final_weight_version": trainer.weight_version,
        "epochs": trainer.engine.epoch_report(),
    }


def run(quick: bool = QUICK):
    rows = recovery_rows(quick)
    smoke = engine_smoke(quick)
    emit("elastic_redeploy", rows)
    print(f"[elastic_redeploy] engine smoke: swap at iter "
          f"{smoke['swap_iteration']}, reschedule "
          f"{smoke['reschedule_wall_s']:.1f}s wall, epoch "
          f"{smoke['final_epoch']}, wv {smoke['final_weight_version']}")

    path = os.path.join("results", "elastic_redeploy.json")
    os.makedirs("results", exist_ok=True)
    js = _finite({"quick": quick, "recovery": rows, "engine_smoke": smoke})
    with open(path, "w") as f:
        json.dump(js, f, indent=2, allow_nan=False)
    print(f"[elastic_redeploy] wrote {path}")


def _finite(x):
    """Strict-JSON sanitizer: non-finite floats become null."""
    if isinstance(x, dict):
        return {k: _finite(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_finite(v) for v in x]
    if isinstance(x, (float, np.floating)):
        return float(x) if math.isfinite(x) else None
    if isinstance(x, (int, np.integer)):
        return int(x)
    return x


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    run()
