"""Figure 5: search efficiency — best plan cost vs search budget for
HetRL (SHA-EA), HetRL (ILP), verl's scheduler, and DEAP-style pure EA
(+ pure SHA, §6 'simplicity' discussion), training Qwen-8B with sync PPO.
"""
from __future__ import annotations

import math

from repro.core import baselines, topology, workflow
from repro.core.ilp import ilp_scheduler
from repro.core.sha import HybridScheduler

from benchmarks.common import QUICK, emit, timer


def run(quick: bool = QUICK):
    topo = topology.build_testbed("multi_country")
    wf = workflow.make_ppo(workflow.QWEN_8B)
    budgets = [30, 100, 300] if quick else [30, 100, 300, 1000, 3000]
    rows = []
    for budget in budgets:
        sha = HybridScheduler(topo, wf, max_groupings=16,
                              max_sizes_per_grouping=4, seed=1)
        with timer() as t_sha:
            r_sha = sha.search(budget=budget)
        r_deap = baselines.deap_scheduler(topo, wf, budget=budget, seed=1)
        r_psha = baselines.pure_sha_scheduler(topo, wf, budget=budget,
                                              seed=1)
        with timer() as t_ilp:
            r_ilp = ilp_scheduler(topo, wf,
                                  max_seconds=max(t_sha.seconds, 1.0),
                                  max_nodes=50 * budget)
        rows.append({
            "budget_evals": budget,
            "sha_ea_s": round(r_sha.cost, 1),
            "deap_s": round(r_deap.cost, 1)
            if math.isfinite(r_deap.cost) else "inf",
            "pure_sha_s": round(r_psha.cost, 1)
            if math.isfinite(r_psha.cost) else "inf",
            "ilp_s": round(r_ilp.cost, 1)
            if math.isfinite(r_ilp.cost) else "inf",
            "verl_s": round(baselines.verl_scheduler(topo, wf).cost, 1),
            "sha_wall_s": round(t_sha.seconds, 1),
        })
    emit("fig5_search_efficiency", rows)
    print("[fig5] paper: SHA-EA dominates at every budget; ILP needs large "
          "budgets (worse than verl when budget-starved)")
    return rows


if __name__ == "__main__":
    run()
