"""Fault-recovery benchmark: detection latency, recovery time and
goodput for each injected-fault scenario (the self-healing acceptance
gates).

Scenarios, each on a live tiny trainer with a bound ``FaultInjector``:

* **link_throttle** — an undeclared cross-machine throttle, detected
  purely from the ``DivergenceMonitor`` (the topology feed never
  changes).  The controller replans against the *inferred measured*
  topology; both the incumbent and the post-replan plan are then scored
  on the TRUE hidden topology (``injector.hidden_topology``) with the
  simulator — the gate is post-replan throughput >= 1.2x the degraded
  incumbent.
* **transient_crash** — a train-task crash absorbed by bounded retry:
  the gate is zero lost iterations (every scheduled iteration returns
  metrics).
* **permanent_crash** — retries cannot fix it: the engine escalates, the
  controller drops the presumed-dead device and forces a replan onto the
  survivors; the interrupted batch is re-run (<= 1 lost iteration).
* **crash_resume** — checkpoint mid-training, keep training, "crash",
  restore the latest checkpoint into a fresh trainer: the gate is a
  bitwise ``state_tree()`` round-trip.
* **slot_failure** — genserve decode slots die mid-wave under
  ``REPRO_OBS_STRICT=1``: requests requeue with zero leaked pages and
  (greedy) bit-identical output to the undisturbed run.

Writes the benchmark CSV and a committed ``results/fault_recovery.json``.
"""
from __future__ import annotations

import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import enumerate as enum_mod, retry, simulator, topology, \
    workflow
from repro.core.plan import check_constraints
from repro.core.workflow import TaskKind
from repro.checkpoint import io as ckpt_io
from repro.data.synthetic import AdditionTask, EOS, PromptDataset, \
    VOCAB_SIZE
from repro.engine.elastic import ElasticConfig, ElasticController
from repro.engine.executor import TaskExecutionError
from repro.faults import FaultInjector, fault_scenario
from repro.genserve.decoder import GenServeConfig, serve
from repro.models import transformer as T
from repro.models.config import LayerSpec, ModelConfig
from repro.obs import calibrate as obs_cal
from repro.obs import metrics as obs_metrics
from repro.rl.trainer import RLConfig, RLTrainer

from benchmarks.common import QUICK, emit


def _tiny_cfg(name):
    return ModelConfig(name=name, n_layers=2, d_model=64, n_heads=2,
                       n_kv_heads=2, head_dim=32, d_ff=128,
                       vocab_size=VOCAB_SIZE, dtype="float32")


def _make_trainer(name):
    cfg = _tiny_cfg(name)
    task = AdditionTask(max_operand=9)
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})
    spec = workflow.LLMSpec.from_model_config(cfg)
    wf = workflow.make_workflow("grpo", spec, synchronous=True,
                                n_rollouts=4, seq_in=task.prompt_len,
                                seq_out=4, global_batch=1)
    g = tuple(sorted(((0,), tuple(range(1, wf.n_tasks)))))
    sizes = enum_mod.proportional_sizes(wf, g, topo.n)
    plan = enum_mod.build_plan(topo, wf, g, sizes, list(range(topo.n)))
    ok, msg = check_constraints(topo, wf, plan)
    assert ok, msg
    rl = RLConfig(algorithm="grpo", n_rollouts=4, max_new_tokens=4)
    trainer = RLTrainer(cfg, rl, task, jax.random.PRNGKey(0), plan=plan,
                       topo=topo, wf=wf)
    trainer.engine.set_task_retry(
        retry.RetryPolicy(max_attempts=3, base_delay_s=0.0),
        sleep=lambda s: None)
    return trainer, topo, wf


def _train_task(wf):
    return next(t for t in range(wf.n_tasks)
                if wf.task(t).kind == TaskKind.TRAIN)


def _iterate(trainer, ds, key):
    prompts, answers = next(ds)
    key, k = jax.random.split(key)
    trainer.iteration(prompts, answers, k)
    return key


def link_throttle_row(quick: bool):
    """Undeclared throttle -> divergence-only detection -> reactive
    replan; both plans scored on the hidden ground-truth topology."""
    trainer, topo, wf = _make_trainer("fr-throttle")
    fault_at = 3
    inj = FaultInjector(fault_scenario("link_throttle", at=fault_at))
    trainer.engine.attach_fault_injector(inj)
    ctrl = ElasticController(trainer, lambda it: topo,
                             ElasticConfig(budget=120,
                                           amortization_iters=5))
    ds = iter(PromptDataset(trainer.task, batch=4, seed=1))
    key = jax.random.PRNGKey(7)
    for _ in range(fault_at):
        key = _iterate(trainer, ds, key)
    cal = obs_cal.fit_from_engine(trainer.engine)
    monitor = obs_cal.DivergenceMonitor(threshold=2.0, sustain=2)
    trainer.engine.attach_divergence_monitor(monitor, cal)
    trainer.engine.set_task_deadlines(cal, slack=5.0)
    ctrl.monitor = monitor

    incumbent = trainer.plan
    rec, step = None, fault_at
    t0 = time.monotonic()
    while rec is None and step < fault_at + 8:
        key = _iterate(trainer, ds, key)
        rec = ctrl.poll(step)
        step += 1
    recovery_s = time.monotonic() - t0
    assert rec is not None and rec.reactive and rec.applied, \
        "link throttle was never detected through the divergence monitor"

    hidden = inj.hidden_topology(topo)
    thr_old = simulator.simulate(hidden, wf, incumbent,
                                 n_iterations=4).throughput
    thr_new = simulator.simulate(hidden, wf, trainer.plan,
                                 n_iterations=4).throughput
    speedup = thr_new / thr_old if thr_old > 0 else math.inf
    assert speedup >= 1.2, \
        f"post-replan throughput {thr_new:.3f} is not >=1.2x the " \
        f"degraded incumbent {thr_old:.3f}"
    return {
        "scenario": "link_throttle",
        "recovered": True,
        "detect_iters": rec.iteration - fault_at + 1,
        "recovery_wall_s": recovery_s,
        "lost_iters": 0,
        "goodput_x": speedup,
        "detail": {
            "reactive": rec.reactive,
            "reschedule_wall_s": rec.reschedule_s,
            "hidden_incumbent_thr": thr_old,
            "hidden_post_replan_thr": thr_new,
            "epoch": trainer.engine.epoch,
        },
    }


def transient_crash_row(quick: bool):
    """Bounded retry absorbs a transient train-task crash in-iteration."""
    trainer, topo, wf = _make_trainer("fr-transient")
    iters = 6
    inj = FaultInjector(fault_scenario("transient_crash", at=2,
                                       train_task=_train_task(wf)))
    trainer.engine.attach_fault_injector(inj)
    ds = iter(PromptDataset(trainer.task, batch=4, seed=1))
    key = jax.random.PRNGKey(7)
    r0 = obs_metrics.counter("engine.task_retries").value
    f0 = obs_metrics.counter("engine.task_failures").value
    done, t0 = 0, time.monotonic()
    for _ in range(iters):
        key = _iterate(trainer, ds, key)
        done += 1
    wall = time.monotonic() - t0
    retries = obs_metrics.counter("engine.task_retries").value - r0
    failures = obs_metrics.counter("engine.task_failures").value - f0
    lost = iters - done
    assert lost <= 1 and failures == 0 and retries >= 2
    return {
        "scenario": "transient_crash",
        "recovered": True,
        "detect_iters": 0,
        "recovery_wall_s": wall / iters,
        "lost_iters": lost,
        "goodput_x": 1.0,
        "detail": {"retries": int(retries), "failures": int(failures),
                   "iters": iters},
    }


def permanent_crash_row(quick: bool):
    """Escalation: drop the dead device, force a replan, re-run the
    interrupted batch."""
    trainer, topo, wf = _make_trainer("fr-permanent")
    inj = FaultInjector(fault_scenario("permanent_crash", at=2,
                                       train_task=_train_task(wf)))
    trainer.engine.attach_fault_injector(inj)
    ctrl = ElasticController(trainer, lambda it: topo,
                             ElasticConfig(budget=120,
                                           amortization_iters=5))
    ds = iter(PromptDataset(trainer.task, batch=4, seed=1))
    key = jax.random.PRNGKey(7)
    iters, done, step, lost = 5, 0, 0, 0
    forced, recovery_s = None, 0.0
    while done < iters:
        prompts, answers = next(ds)
        key, k = jax.random.split(key)
        try:
            trainer.iteration(prompts, answers, k)
        except TaskExecutionError as e:
            t0 = time.monotonic()
            forced = ctrl.handle_failure(step, e)
            recovery_s = time.monotonic() - t0
            lost += 1                    # the batch itself is re-drawn
            continue
        done += 1
        step += 1
    assert forced is not None and forced.forced and forced.applied
    assert lost <= 1
    return {
        "scenario": "permanent_crash",
        "recovered": True,
        "detect_iters": 0,
        "recovery_wall_s": recovery_s,
        "lost_iters": lost,
        "goodput_x": 1.0,
        "detail": {"reschedule_wall_s": forced.reschedule_s,
                   "epoch": trainer.engine.epoch,
                   "surviving_devices": trainer.engine.topo.n},
    }


def crash_resume_row(quick: bool, ckpt_dir: str):
    """Checkpoint mid-training, crash, restore latest: bitwise."""
    trainer, topo, wf = _make_trainer("fr-resume")
    ctrl = ElasticController(trainer, lambda it: topo,
                             ElasticConfig(ckpt_dir=ckpt_dir,
                                           ckpt_retain=3))
    ds = iter(PromptDataset(trainer.task, batch=4, seed=1))
    key = jax.random.PRNGKey(7)
    for _ in range(3):
        key = _iterate(trainer, ds, key)
    path, nbytes = ctrl.checkpoint_now(2)
    want = jax.tree_util.tree_map(lambda x: np.asarray(x).copy(),
                                  trainer.state_tree())
    key = _iterate(trainer, ds, key)    # diverge past the checkpoint

    fresh, _, _ = _make_trainer("fr-resume")
    t0 = time.monotonic()
    tree, loaded = ckpt_io.load_latest(ckpt_dir, fresh.state_tree())
    fresh.load_state_tree(tree)
    restore_s = time.monotonic() - t0
    got = jax.tree_util.tree_flatten(fresh.state_tree())[0]
    ref = jax.tree_util.tree_flatten(want)[0]
    bitwise = len(got) == len(ref) and all(
        np.asarray(a).dtype == np.asarray(b).dtype
        and np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(got, ref))
    assert bitwise, "state_tree() did not round-trip bitwise"
    assert loaded == path
    return {
        "scenario": "crash_resume",
        "recovered": True,
        "detect_iters": 0,
        "recovery_wall_s": restore_s,
        "lost_iters": 1,                 # iterations past the checkpoint
        "goodput_x": 1.0,
        "detail": {"ckpt_bytes": int(nbytes), "bitwise": bitwise,
                   "path": os.path.basename(path)},
    }


def slot_failure_row(quick: bool):
    """Mid-wave decode-slot deaths: requeue, zero leaked pages (strict
    mode), greedy output identical to the undisturbed run."""
    cfg = ModelConfig(name="fr-gs", n_layers=2, d_model=64, n_heads=2,
                      n_kv_heads=2, head_dim=32, d_ff=128,
                      vocab_size=VOCAB_SIZE, dtype="float32",
                      pattern=(LayerSpec(window=None),))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, P, N = (6, 8, 6) if quick else (12, 8, 8)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (B, P), 0,
                                 cfg.vocab_size, jnp.int32)
    kw = dict(wave=4, max_new_tokens=N, eos_token=EOS, prefill_chunk=4,
              greedy=True, page_size=4, prefix_cache=True)
    prev = os.environ.get("REPRO_OBS_STRICT")
    os.environ["REPRO_OBS_STRICT"] = "1"
    try:
        # warm the jit caches so the clean/faulted timing compares
        # steady-state serving, not compilation
        serve(params, cfg, prompts, jax.random.PRNGKey(7),
              GenServeConfig(**kw))
        t0 = time.monotonic()
        ref, ref_stats = serve(params, cfg, prompts, jax.random.PRNGKey(7),
                               GenServeConfig(**kw))
        clean_s = time.monotonic() - t0
        t0 = time.monotonic()
        got, stats = serve(params, cfg, prompts, jax.random.PRNGKey(7),
                           GenServeConfig(**kw),
                           slot_failures={2: [0, 1]})
        faulted_s = time.monotonic() - t0
    finally:
        if prev is None:
            os.environ.pop("REPRO_OBS_STRICT", None)
        else:
            os.environ["REPRO_OBS_STRICT"] = prev
    assert stats["requeued"] == 2 and stats["retired"] == B
    m = np.asarray(ref["mask"]).astype(np.int32)
    assert np.array_equal(m, np.asarray(got["mask"]).astype(np.int32))
    assert np.array_equal(np.asarray(ref["gen_tokens"]) * m,
                          np.asarray(got["gen_tokens"]) * m)
    tokens = int(m.sum())
    goodput = (tokens / faulted_s) / (tokens / clean_s) \
        if faulted_s > 0 else 1.0
    return {
        "scenario": "slot_failure",
        "recovered": True,
        "detect_iters": 0,
        "recovery_wall_s": faulted_s - clean_s,
        "lost_iters": 0,
        "goodput_x": goodput,
        "detail": {"requeued": int(stats["requeued"]),
                   "retired": int(stats["retired"]),
                   "rounds_clean": len(ref_stats.get("rounds", [])),
                   "rounds_faulted": len(stats.get("rounds", [])),
                   "leaked_pages": 0},
    }


def run(quick: bool = QUICK):
    ckpt_dir = os.path.join("results", "fault_ckpt")
    rows = [
        link_throttle_row(quick),
        transient_crash_row(quick),
        permanent_crash_row(quick),
        crash_resume_row(quick, ckpt_dir),
        slot_failure_row(quick),
    ]
    details = {r["scenario"]: r.pop("detail") for r in rows}
    emit("fault_recovery", rows)
    for r in rows:
        r["detail"] = details[r["scenario"]]
    path = os.path.join("results", "fault_recovery.json")
    os.makedirs("results", exist_ok=True)
    with open(path, "w") as f:
        json.dump(_finite({"quick": quick, "scenarios": rows}), f,
                  indent=2, allow_nan=False)
    print(f"[fault_recovery] wrote {path}")


def _finite(x):
    """Strict-JSON sanitizer: non-finite floats become null."""
    if isinstance(x, dict):
        return {k: _finite(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_finite(v) for v in x]
    if isinstance(x, (float, np.floating)):
        return float(x) if math.isfinite(x) else None
    if isinstance(x, (int, np.integer)):
        return int(x)
    return x


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    run()
