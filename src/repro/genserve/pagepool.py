"""Host-side page allocator and radix prefix cache for the paged KV
layout (``repro.models.cache.init_paged_cache``).

Two small pure-Python structures drive admission:

``PagePool``
    A free-list allocator over the device page pool with per-page
    refcounts.  Slots and the radix tree both hold references; a page
    returns to the free list when its count reaches zero.  Nothing here
    touches device memory — the pool only decides *which* page ids the
    engine's block tables may use.

``RadixCache``
    A page-granular prefix tree over prompt token ids (SGLang-style,
    coarsened to page boundaries so a tree edge is exactly one page's
    worth of tokens).  ``match`` returns the longest cached prefix as
    (a) whole pages the new slot can map copy-free (refcount++ — true
    sharing) and (b) at most one *partially* matching page, which the
    engine copies on write: a fresh page is allocated, the cached page's
    contents are copied device-side (``cache.copy_pages``) and only the
    copy is mapped, so the divergent suffix never corrupts the cached
    original.  ``insert`` registers a landed prompt's complete pages for
    future admissions; leaves are evicted in LRU order when the free
    list runs dry.

The decoder consumes these during admission (match → start the prefill
cursor after the shared prefix), at landing (insert) and at retirement
(decref the slot's pages).  See ``repro.genserve.decoder``.
"""
from __future__ import annotations

import os
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs import metrics as obs_metrics


class PagePool:
    """Free-list page allocator with refcounts.

    Page ids are ``0 .. n_pages-1``; ``n_pages`` itself is the sentinel
    value block tables use for unmapped entries (device gathers fill
    zeros / scatters drop for it, so the host never allocates it).
    """

    def __init__(self, n_pages: int, page_size: int):
        assert n_pages > 0 and page_size > 0
        self.n_pages = n_pages
        self.page_size = page_size
        self.refcount = [0] * n_pages
        # LIFO free list: recently freed pages are reused first, which
        # keeps the working set of pool pages compact
        self._free: List[int] = list(range(n_pages - 1, -1, -1))

    @property
    def sentinel(self) -> int:
        return self.n_pages

    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` fresh pages (refcount 1 each); None if exhausted."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            assert self.refcount[p] == 0
            self.refcount[p] = 1
        return pages

    def incref(self, pages: Sequence[int]) -> None:
        for p in pages:
            assert self.refcount[p] > 0, f"incref on free page {p}"
            self.refcount[p] += 1

    def decref(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; returns the ids that hit zero
        (now back on the free list)."""
        freed = []
        for p in pages:
            assert self.refcount[p] > 0, f"decref on free page {p}"
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed

    def check(self) -> None:
        """Invariant: every page is either free (refcount 0, on the
        free list exactly once) or live (refcount > 0, not on it)."""
        seen = set(self._free)
        assert len(seen) == len(self._free), "duplicate free-list entry"
        for p, rc in enumerate(self.refcount):
            assert rc >= 0
            assert (rc == 0) == (p in seen), (
                f"page {p}: refcount {rc} vs free-list {p in seen}")

    # -- observability ---------------------------------------------------
    def utilization(self) -> float:
        """Fraction of pool pages currently referenced (live)."""
        live = sum(1 for rc in self.refcount if rc > 0)
        return live / self.n_pages

    def stats(self) -> Dict[str, float]:
        """Invariant-checked pool summary for metrics snapshots."""
        self.check()
        return {"n_pages": self.n_pages, "free": len(self._free),
                "live": self.n_pages - len(self._free),
                "utilization": self.utilization(),
                "max_refcount": max(self.refcount, default=0)}

    def leak_check(self, expected_refs: Optional[Dict[int, int]] = None
                   ) -> List[int]:
        """Shutdown leak assertion: with ``expected_refs`` (page ->
        refcount the caller can account for — at decoder teardown, the
        radix tree's node references), any page holding references
        beyond them is a leak.  Leaks are counted into the
        ``pagepool.leaked_pages`` metric and warned; under
        ``REPRO_OBS_STRICT=1`` they raise instead.  Also verifies the
        free-list invariant (``check``).  Returns the leaked page ids."""
        self.check()
        expected = expected_refs or {}
        leaked = [p for p, rc in enumerate(self.refcount)
                  if rc > expected.get(p, 0)]
        if leaked:
            obs_metrics.counter("pagepool.leaked_pages").inc(len(leaked))
            msg = (f"page pool leak: {len(leaked)} page(s) hold "
                   f"unaccounted references at teardown "
                   f"(ids {leaked[:8]}{'...' if len(leaked) > 8 else ''})")
            if os.environ.get("REPRO_OBS_STRICT") == "1":
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return leaked


class _Node:
    __slots__ = ("key", "page", "children", "parent", "last_use")

    def __init__(self, key: Tuple[int, ...], page: int, parent):
        self.key = key
        self.page = page
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.parent = parent
        self.last_use = 0


class RadixCache:
    """Page-granular prefix tree over prompt token ids.

    Every edge below the root is labelled with exactly ``page_size``
    token ids and owns one reference on the page holding their KV.
    Prompts that share a prefix share a path; ``match`` walks it.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.page_size = pool.page_size
        self.root = _Node((), -1, None)
        self._clock = 0
        self.n_nodes = 0

    # -- lookup ----------------------------------------------------------

    def match(self, tokens: Sequence[int], max_len: int,
              ) -> Tuple[List[int], Optional[Tuple[int, int]]]:
        """Longest cached prefix of ``tokens`` capped at ``max_len``.

        Returns ``(full_pages, partial)``: ``full_pages`` are pool page
        ids whose whole page_size-token span matches (sharable in place
        after an incref); ``partial`` is ``(page, n_tokens)`` for at
        most one further page matching only its first ``n_tokens``
        (copy-on-write material), or None.  The cap exists so a fully
        cached prompt still runs a landing chunk: the caller passes
        ``len(prompt) - 1`` and always prefills at least one token."""
        ps = self.page_size
        self._clock += 1
        node = self.root
        full: List[int] = []
        depth = 0
        while (depth + 1) * ps <= max_len:
            key = tuple(tokens[depth * ps:(depth + 1) * ps])
            child = node.children.get(key)
            if child is None:
                break
            child.last_use = self._clock
            full.append(child.page)
            node = child
            depth += 1
        # one partially matching page: the child sharing the longest
        # proper token prefix with what remains under the cap
        partial = None
        rest = list(tokens[depth * ps:max_len])
        if rest:
            best = 0
            for key, child in node.children.items():
                n = 0
                for a, b in zip(key, rest):
                    if a != b:
                        break
                    n += 1
                if n > best:
                    best, partial = n, (child.page, n)
                    child.last_use = self._clock
        return full, partial

    # -- insertion -------------------------------------------------------

    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Register a landed prompt's complete pages: page j covers
        tokens ``[j*ps, (j+1)*ps)``.  Only whole pages enter the tree
        (the trailing partial page is private to its slot).  Existing
        nodes are kept (first inserter wins); each newly created node
        increfs its page.  Returns the number of nodes created."""
        ps = self.page_size
        self._clock += 1
        node = self.root
        created = 0
        for j, page in enumerate(pages):
            key = tuple(tokens[j * ps:(j + 1) * ps])
            if len(key) < ps:
                break
            child = node.children.get(key)
            if child is None:
                child = _Node(key, page, node)
                node.children[key] = child
                self.pool.incref([page])
                self.n_nodes += 1
                created += 1
            child.last_use = self._clock
            node = child
        return created

    # -- eviction --------------------------------------------------------

    def evict(self, need: int) -> int:
        """Drop LRU leaves until ``need`` pages have actually been freed
        (refcount reached zero) or no leaf remains.  Leaves whose page
        is still mapped by a live slot are dropped from the tree too —
        they stop matching immediately and the page frees at slot
        retirement.  Returns the number of pages freed now."""
        freed = 0
        while freed < need:
            leaf = self._lru_leaf()
            if leaf is None:
                break
            del leaf.parent.children[leaf.key]
            self.n_nodes -= 1
            freed += len(self.pool.decref([leaf.page]))
        return freed

    def _lru_leaf(self) -> Optional[_Node]:
        best = None
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root and not n.children:
                if best is None or n.last_use < best.last_use:
                    best = n
            stack.extend(n.children.values())
        return best

    # -- observability ---------------------------------------------------
    def page_refs(self) -> Dict[int, int]:
        """page id -> number of references the tree holds on it (each
        node owns exactly one).  At decoder teardown these are the only
        references that should remain — ``PagePool.leak_check`` takes
        this as its expected-refs baseline."""
        refs: Dict[int, int] = {}
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            refs[n.page] = refs.get(n.page, 0) + 1
            stack.extend(n.children.values())
        return refs
