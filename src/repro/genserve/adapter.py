"""Rollout-contract adapter: genserve as a drop-in GEN executor.

``generate`` returns exactly the ``rl.rollout.generate`` contract
(``{sequences, gen_tokens, logprobs, mask}``) plus an engine-stats dict,
so the trainer / losses are untouched by the execution regime.  When the
whole batch fits into one decode wave (and no per-request budgets are
requested), the single-wave reference path *is* the fast path — one fused
``lax.scan`` beats a host-driven loop — and its wave stats are
synthesized from the validity mask (a wave's useful occupancy at decode
step t is the number of still-alive sequences).

The ``TaskKind.GEN`` executor in ``engine.tasks`` routes through this
module, making ``core.plan.MAX_DECODE_WAVE`` semantics real in the
measured timeline.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_mod
from repro.genserve.decoder import GenServeConfig, serve
from repro.models.config import ModelConfig
from repro.rl import rollout


def ttft_quantiles(stats) -> Tuple[float, float]:
    """(p50, p95) seconds over per-request time-to-first-token; (0, 0)
    when the engine path did not record TTFT (single-wave fast path, or
    ``measure_ttft`` off)."""
    ts = sorted(stats.get("ttft", {}).values())
    if not ts:
        return 0.0, 0.0
    return (float(np.percentile(ts, 50)), float(np.percentile(ts, 95)))


def wave_stats_from_mask(mask, wave: Optional[int] = None
                         ) -> Dict[str, object]:
    """Synthesize single-wave engine stats from a validity mask [B, N].

    The single-wave executor decodes every sequence for all N steps; its
    *useful* occupancy at step t is the number of sequences whose token t
    is valid — the same metric genserve's slot table records."""
    m = np.asarray(mask)
    B, N = m.shape
    trace = m.sum(axis=0)
    occ = float(m.sum() / max(N, 1))
    return {"engine": "single-wave", "wave": wave or B,
            "decode_steps": int(N),
            "slot_steps": int(m.sum()),
            "mean_occupancy": occ,
            "busy_occupancy": occ,
            "occupancy_trace": [int(c) for c in trace],
            "prefill_trace": [], "prefill_rounds": 0,
            "prefill_slot_steps": 0, "prefill_chunk": 0,
            "prefill_rounds_per_req": 0.0,
            "max_new_tokens": int(N), "ttft": {}, "queue_wait": {},
            "rounds": [], "prefills": 1, "admitted": B, "retired": B}


def generate(params, cfg: ModelConfig, prompts, rng,
             sampler: "rollout.SamplerConfig", *,
             wave: Optional[int] = None, decode_chunk: int = 1,
             gen_lens: Optional[Sequence[int]] = None,
             fast_path: bool = True, decode_path: str = "batched",
             admission: str = "fifo", prefill_chunk: int = 0,
             prompt_lens: Optional[Sequence[int]] = None,
             measure_ttft: bool = False, page_size: int = 0,
             prefix_cache: bool = False, pool_pages: int = 0,
             sjf_aging: int = 0,
             slot_failures: Optional[Dict[int, Sequence[int]]] = None,
             cancels: Optional[Dict[int, Sequence[int]]] = None,
             spec_k: int = 0, draft_params=None,
             draft_cfg: Optional[ModelConfig] = None,
             mesh=None
             ) -> Tuple[Dict[str, jnp.ndarray], Dict[str, object]]:
    """Continuous-batching generation with the rollout contract.

    `wave` defaults to ``core.plan.decode_wave(B)``; batches no larger
    than the wave take the single-wave reference path unless
    ``fast_path=False`` (tests), per-request budgets, or chunked
    admission force the engine.  ``decode_path`` / ``admission`` select
    the wave-decode execution path (batched fast path vs the vmapped
    per-slot reference) and the queue policy (FIFO vs shortest-job-first
    when budgets are known).  ``prefill_chunk > 0`` enables chunked
    admission (mixed wave-steps: prompts ingested ``prefill_chunk``
    tokens per round alongside decode, optional ragged ``prompt_lens``).
    ``page_size > 0`` switches the KV cache to the paged layout and
    ``prefix_cache=True`` adds radix prefix reuse across slots (prefill
    skipped on cached prompt prefixes) — both imply the engine path
    since paged admission is chunked by construction.
    ``slot_failures`` / ``cancels`` (round -> slot ids / request ids)
    inject mid-wave slot deaths and explicit request cancels; either
    forces the engine path (the single-wave scan has no slots to fail).
    ``spec_k > 0`` turns on draft-model speculative decoding (requires
    ``draft_params`` + ``draft_cfg``) — always the engine path: the
    draft/verify sub-round is a wave-step program.
    ``mesh`` routes through the mesh-aware serve entry (sharded decode
    on the generation group's devices) and disables the fast path —
    the caller shards the single-wave path itself.
    """
    B = int(np.asarray(prompts).shape[0])
    W = int(wave) if wave else plan_mod.decode_wave(B)
    if fast_path and mesh is None and gen_lens is None \
            and prefill_chunk == 0 \
            and page_size == 0 and B <= W and spec_k == 0 \
            and not slot_failures and not cancels:
        ro = rollout.generate(params, cfg, jnp.asarray(prompts), rng,
                              sampler)
        return ro, wave_stats_from_mask(ro["mask"], wave=min(W, B))
    gcfg = GenServeConfig(wave=min(W, B), max_new_tokens=sampler.max_new_tokens,
                          decode_chunk=decode_chunk,
                          temperature=sampler.temperature,
                          eos_token=sampler.eos_token, greedy=sampler.greedy,
                          decode_path=decode_path, admission=admission,
                          prefill_chunk=prefill_chunk,
                          measure_ttft=measure_ttft, page_size=page_size,
                          prefix_cache=prefix_cache, pool_pages=pool_pages,
                          sjf_aging=sjf_aging, spec_k=spec_k)
    return serve(params, cfg, prompts, rng, gcfg, gen_lens=gen_lens,
                 prompt_lens=prompt_lens, slot_failures=slot_failures,
                 cancels=cancels, draft_params=draft_params,
                 draft_cfg=draft_cfg, mesh=mesh)
