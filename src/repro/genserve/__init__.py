"""Continuous-batching generation subsystem (vLLM-style bounded waves).

HetRL's cost model prices generation as continuous batching in decode
waves of at most ``core.plan.MAX_DECODE_WAVE`` sequences (the ``C_hbm``
term divides KV streaming by the wave size).  This package makes that
execution regime real: a request queue feeding at most ``wave`` active
decode slots, jitted fixed-shape wave steps with an active-slot mask,
retirement on EOS / budget, and prefill back-fill of freed slots — so the
measured timeline and the model it is validated against finally describe
the same machine.

Slot lifecycle::

        queue (FIFO | SJF)                    wave of W slots
     ┌──────────────┐   admit (one-shot     ┌────┬────┬────┬────┐
     │ r7 r6 r5 r4  │ ─────────────────────▶│ r0 │ r1 │ r2 │ r3 │
     └──────────────┘   or chunked install) └─┬──┴─┬──┴─┬──┴─┬──┘
                                              │    │    │    │  decode step
            ▲                                 ▼    ▼    ▼    ▼  (batched,
            │                               tok  tok  EOS  tok   per-slot pos)
            │         retire (EOS or budget) ────── r2 ──────┐
            │                                                ▼
            └─────────── freed slot back-filled ──── outputs[r2] complete

    FREE ──admit──▶ ACTIVE ──emits tokens──▶ RETIRED(EOS | budget) ──▶ FREE
    FREE ──install─▶ PREFILLING ──chunks──▶ lands (first token) ─▶ ACTIVE

Device-program table (every round is one of these fixed-shape jitted
programs; details in ``genserve.decoder``):

  program  | inputs                      | static-shape rule
  -------- | --------------------------- | -------------------------------
  admit    | [W,P] prompts, masks, key   | one-shot whole-prompt prefill
  install  | [W,P] prompts, masks, plens | chunked: metadata only, no
           |                             |   compute, zeroed cache rows
  mixed    | [k] decode keys, [k] land   | scan of k sub-rounds (k <=
           |   keys                      |   decode_chunk): each one
           |                             |   decode step + one [W,
           |                             |   prefill_chunk] prompt
           |                             |   chunk, all masked
  chunk    | [decode_chunk] keys         | pure decode steps under scan
  copy     | [W] src/dst page ids        | paged only: pool page copies
           |                             |   (COW), sentinel-padded

With ``page_size > 0`` the attention KV cache is a shared page pool
addressed through per-slot block tables (``models.cache``); the model
programs are unchanged — they consume a gathered contiguous view and
scatter back just the written token.  ``prefix_cache=True`` adds the
host-side refcounted allocator + radix prefix tree
(``genserve.pagepool``): admission matches each prompt against prompts
already resident in the pool and skips prefill on the cached prefix
(copy-on-write on a divergent partial page), reported as
``prefix_hit_rate`` / ``prefill_tokens_skipped`` in the engine stats.

Membership, prompt raggedness, chunk counts and landings are masks and
scatters — the host never recompiles on admission order or prompt mix.

Invariants:
  * shapes are static — membership is masks/scatters, never recompiles;
  * every slot carries its own cache position: recycled slots get exact
    RoPE phases, ring-window validity and recurrent state (the wave is
    one natively batched ``transformer.decode_step`` with per-slot
    positions — the Sq == 1 flash-decode kernel path under the pallas
    impl; a vmap of the B=1 decode remains as the parity reference);
  * one-shot admission replaces a slot's cache rows wholesale
    (``models.cache.scatter_slots``); chunked admission zeroes them and
    rebuilds via per-slot-cursor chunk writes
    (``transformer.prefill_chunk_step``) — no stale state can leak
    either way, and a sequence of chunk steps is numerically the
    one-shot prefill (pinned by the random-trace parity tests);
  * EOS/validity semantics are shared with the single-wave reference
    path through ``models.sampling`` (first EOS valid, everything after
    masked, prompt-ends-with-EOS starts dead);
  * when ``batch <= wave`` the engine's rng schedule equals
    ``rl.rollout.generate``'s on both admission paths, so the reference
    path is reproduced token-for-token (pinned by
    tests/test_genserve.py).
"""
from repro.genserve.adapter import generate, wave_stats_from_mask  # noqa: F401
from repro.genserve.decoder import GenServeConfig, serve  # noqa: F401
from repro.genserve.pagepool import PagePool, RadixCache  # noqa: F401
from repro.genserve.scheduler import (Request, RequestQueue,  # noqa: F401
                                      SlotTable)
