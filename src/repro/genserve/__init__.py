"""Continuous-batching generation subsystem (vLLM-style bounded waves).

HetRL's cost model prices generation as continuous batching in decode
waves of at most ``core.plan.MAX_DECODE_WAVE`` sequences (the ``C_hbm``
term divides KV streaming by the wave size).  This package makes that
execution regime real: a request queue feeding at most ``wave`` active
decode slots, jitted fixed-shape wave steps with an active-slot mask,
retirement on EOS / budget, and prefill back-fill of freed slots — so the
measured timeline and the model it is validated against finally describe
the same machine.

Slot lifecycle::

        queue (FIFO | SJF)                    wave of W slots
     ┌──────────────┐   admit (prefill      ┌────┬────┬────┬────┐
     │ r7 r6 r5 r4  │ ─────────────────────▶│ r0 │ r1 │ r2 │ r3 │
     └──────────────┘   inject + scatter)   └─┬──┴─┬──┴─┬──┴─┬──┘
                                              │    │    │    │  decode step
            ▲                                 ▼    ▼    ▼    ▼  (batched,
            │                               tok  tok  EOS  tok   per-slot pos)
            │         retire (EOS or budget) ────── r2 ──────┐
            │                                                ▼
            └─────────── freed slot back-filled ──── outputs[r2] complete

    FREE ──admit──▶ ACTIVE ──emits tokens──▶ RETIRED(EOS | budget) ──▶ FREE

Invariants:
  * shapes are static — membership is masks/scatters, never recompiles;
  * every slot carries its own cache position: recycled slots get exact
    RoPE phases, ring-window validity and recurrent state (the wave is
    one natively batched ``transformer.decode_step`` with per-slot
    positions — the Sq == 1 flash-decode kernel path under the pallas
    impl; a vmap of the B=1 decode remains as the parity reference);
  * admission replaces a slot's cache rows wholesale
    (``models.cache.scatter_slots``) — no stale state can leak;
  * EOS/validity semantics are shared with the single-wave reference
    path through ``models.sampling`` (first EOS valid, everything after
    masked, prompt-ends-with-EOS starts dead);
  * when ``batch <= wave`` the engine's rng schedule equals
    ``rl.rollout.generate``'s, so the reference path is reproduced
    token-for-token (pinned by tests/test_genserve.py).
"""
from repro.genserve.adapter import generate, wave_stats_from_mask  # noqa: F401
from repro.genserve.decoder import GenServeConfig, serve  # noqa: F401
from repro.genserve.scheduler import (Request, RequestQueue,  # noqa: F401
                                      SlotTable)
