"""Wave-stepped continuous-batching decode loop with chunked prefill.

Device-side execution is a small set of jitted, fixed-shape programs per
(model, engine-config, prompt-shape) triple — the device-program table:

  program   inputs (beyond params/state)      what it does
  -------   -------------------------------   ---------------------------
  admit     prompts [W,P], admit_mask [W],    one-shot path
            rows [W], limits [W], key         (``prefill_chunk == 0``):
                                              prefill the whole [W, P]
                                              prompt batch, sample each
                                              admitted request's first
                                              token, scatter fresh cache
                                              rows (``scatter_slots``).
  install   prompts [W,P], admit_mask [W],    chunked path: stage request
            rows [W], limits [W], plens [W]   metadata + prompt rows into
                                              per-slot buffers, zero the
                                              admitted slots' cache rows
                                              (``cache.zero_slots``), set
                                              ``prefill_cursor = 0``.  No
                                              model compute, no sampling.
  mixed     k_decodes [k], k_lands [k]        the **mixed wave-step**: a
                                              scan of k sub-rounds (k <=
                                              ``decode_chunk``, sized by
                                              the host to the pending
                                              prefill work), each ONE
                                              batched ``decode_step``
                                              over decoding slots (cache
                                              rows of admitting slots
                                              protected) plus ONE
                                              ``prefill_chunk_step`` (up
                                              to ``prefill_chunk`` prompt
                                              tokens) over admitting
                                              slots, all masked; a slot
                                              whose final chunk lands
                                              samples its first token
                                              (its sub-round's k_lands
                                              entry) and decodes from the
                                              next sub-round on.
  chunk     keys [decode_chunk]               ``decode_chunk`` wave steps
                                              under ``lax.scan`` (used
                                              when no slot is mid-
                                              prefill): batched decode,
                                              sample, record, retire on
                                              EOS / budget.

With ``spec_k > 0`` the decode half of every sub-round (in both the
``chunk`` and ``mixed`` programs) becomes the **draft/verify
sub-round**: k+1 autoregressive draft-model decode steps propose
d_1..d_k per live slot (the trailing step's proposal is discarded —
it runs so d_k's draft k/v lands for the full-accept-plus-bonus case;
the draft keeps its own small full-attention per-slot cache
``st["dcache"]`` in the slot lifecycle, always at the target's
position), then ONE batched target step over the [W, k+1]
candidate chunk ``[tok, d_1..d_k]`` verifies them
(``transformer.verify_chunk_step`` — all-position logits, cache left
untouched).  Greedy: exact longest-prefix match against the target
argmax; sampled: standard rejection-resampling, so the emitted-token
distribution is exactly the target's.  The accepted prefix plus the
bonus/corrected token (up to k+1 tokens) lands through the chunked
variable-length write machinery — validity-masked ``cache.write_kv``
at per-row cursors, ``paged_update_chunk`` on the paged pool — so ring
windows, GQA, recycled slots and prefix sharing all carry over.  On
the chunked-admission path the draft ingests the *full* prompt in its
own chunk cursor (``st["dcur"]``; it has no radix cache), while a
prefix-cache target skips ahead — ``st["pdelay"]`` idles the target's
prefill so both cursors land on the same sub-round.

Static-shape rules: every program's operand shapes depend only on
(W, P, C, N, n_reqs) plus, for ``mixed``, the scan length k — a value
in {1..decode_chunk}, so at most ``decode_chunk`` program variants
exist, each traced once on first use (decode_chunk=1, the default,
means a single variant).  Membership, prompt raggedness, chunk counts
and landings are masks and scatters — none of them retrace; the
bounded k variants trade a one-time compile each for never running
masked all-idle chunk passes every round.
``decode_path="vmapped"`` selects the legacy W-way vmap of a B=1 decode
for parity testing.

Paged KV (``page_size > 0``, requires chunked admission): attention k/v
live in per-layer page pools addressed through a per-slot block table
``st["btab"]`` (``models.cache.init_paged_cache``).  Each program's
model compute is unchanged — a gather (``cache.paged_view``) materializes
the contiguous per-slot view the decode / prefill-chunk steps consume,
and the freshly written token k/v are scattered back into the pools
(``paged_update_decode`` / ``paged_update_chunk``), always emit-masked
so a retired slot's stale block-table row can never corrupt a reused
page.  Two more paged programs join the table:

  program   inputs (beyond params/state)      what it does
  -------   -------------------------------   ---------------------------
  install   ... + pstarts [W], btab [W,MP]    paged variant: additionally
                                              maps the admitted slots'
                                              block-table rows and starts
                                              each prefill cursor at
                                              ``pstarts`` (after the
                                              cached prefix).
  copy      src [W], dst [W]                  copy-on-write page copies
                                              (``cache.copy_pages``) the
                                              host schedules when an
                                              admission diverges mid-page
                                              from a cached prefix;
                                              sentinel-padded, fixed
                                              shape.

With ``prefix_cache=True`` the host admission loop additionally runs a
radix prefix tree + refcounted page allocator
(``genserve.pagepool``): each admitted prompt is matched against the
tree, whole matching pages are mapped copy-free (refcount++), at most
one partially matching page is copied (COW) — and chunked prefill runs
only on the uncached suffix (the cursor starts at the hit length,
capped at plen-1 so the landing chunk always runs and samples from real
landing logits).  Landed prompts insert their complete pages; retired
slots decref; LRU leaves evict when the free list runs dry.  Sharing
requires every layer to be full-window attention
(``cache.supports_prefix_sharing``) — ring windows would clobber shared
pages and recurrent state cannot be snapshotted at a prompt boundary —
while the paged *layout* itself (with the identity block table) works
for every config and is the exact-parity no-sharing fallback.

The host loop owns dynamic membership: it reads back the ``occupied``
vector after every round, retires finished requests via the
``scheduler.SlotTable``, back-fills freed slots from the admission
queue (FIFO by default; ``admission="sjf"`` admits shortest known
budgets first), and mirrors each slot's prefill cursor (deterministic:
every mixed round advances every prefilling slot by one chunk) so it
knows landings without a device sync.

RNG schedule: the first ``max_new_tokens`` sampling events use
``jax.random.split(rng, max_new_tokens)`` — the exact schedule of
``rl.rollout.generate`` — so a batch that fits into a single wave
reproduces the reference path token-for-token *on both admission
paths*: chunked prefill consumes no keys until the landing round, whose
first-token sample uses ``rngs[0]`` exactly like the one-shot admit.
Late admissions and overflow steps draw from ``fold_in`` side streams.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.genserve.pagepool import PagePool, RadixCache
from repro.genserve.scheduler import FREE, Request, RequestQueue, SlotTable
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.models import attention as attn_mod
from repro.models import cache as cache_mod
from repro.models import sampling
from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class GenServeConfig:
    """Engine knobs (hashable: keys the jit cache)."""

    wave: int                        # max concurrently decoding slots (W)
    max_new_tokens: int              # global budget N (output buffer width)
    decode_chunk: int = 1            # decode steps per jitted host round
    temperature: float = 1.0
    eos_token: Optional[int] = None
    greedy: bool = False
    decode_path: str = "batched"     # "batched" | "vmapped" wave decode
    admission: str = "fifo"          # "fifo" | "sjf" queue policy
    prefill_chunk: int = 0           # tokens per mixed-round prefill chunk
    #                                  (0 = one-shot whole-prompt admit)
    measure_ttft: bool = False       # stamp per-request time-to-first-token
    #                                  (one-shot admission pays a device sync
    #                                  per admit batch; chunked stamps ride
    #                                  the round sync for free, but are
    #                                  gated too so the flag means one thing)
    page_size: int = 0               # paged KV: tokens per pool page
    #                                  (0 = contiguous per-slot cache)
    prefix_cache: bool = False       # radix prefix reuse across slots
    #                                  (requires page_size > 0 and a
    #                                  full-attention config)
    pool_pages: int = 0              # page-pool size override (0 = sized
    #                                  automatically: W*MP contiguous-
    #                                  equivalent, 2*W*MP with prefix
    #                                  cache so eviction can always make
    #                                  room for a full admission wave)
    sjf_aging: int = 0               # sjf anti-starvation: admit a
    #                                  passed-over request after at most
    #                                  this many pops (0 = pure sjf)
    spec_k: int = 0                  # speculative decoding: draft tokens
    #                                  proposed per wave sub-round (0 =
    #                                  off).  Requires a draft model
    #                                  (``serve(draft_params=,
    #                                  draft_cfg=)``); the target must
    #                                  satisfy
    #                                  ``cache.supports_speculative_target``
    #                                  and the draft the stricter
    #                                  ``cache.supports_speculative_draft``.

    def validate(self) -> None:
        assert self.wave >= 1 and self.max_new_tokens >= 1
        assert self.decode_chunk >= 1
        assert self.decode_path in ("batched", "vmapped")
        assert self.admission in ("fifo", "sjf")
        assert self.prefill_chunk >= 0
        assert self.page_size >= 0 and self.pool_pages >= 0
        assert self.sjf_aging >= 0
        assert self.spec_k >= 0
        if self.spec_k > 0:
            # the spec sub-round is one natively batched verify step
            assert self.decode_path == "batched", \
                "spec_k > 0 requires the batched decode path"
        if self.page_size > 0:
            # paged KV rides the chunked-admission machinery (per-slot
            # cursors, install/mixed programs)
            assert self.prefill_chunk > 0, \
                "page_size > 0 requires chunked admission (prefill_chunk > 0)"
        if self.prefix_cache:
            assert self.page_size > 0, \
                "prefix_cache requires a paged cache (page_size > 0)"


# ---------------------------------------------------------------------------
# Wave decode: one batched decode_step over all W slots.  The cache
# leaves are already laid out [R, W, ...] — exactly decode_step's batch
# layout — and per-slot cache positions ([W] `pos`) carry each recycled
# slot's own RoPE phase and ring-window validity, so the whole wave
# shares one fused attention call (the Sq == 1 flash-decode path when
# `set_attention_impl("pallas")` is active).  The legacy W-way vmap of a
# B=1 decode_step is kept as the "vmapped" parity path.
# ---------------------------------------------------------------------------

def _wave_decode_batched(params, cfg: ModelConfig, tok, pos, blocks):
    """tok, pos: [W]; blocks: cache leaves [R, W, ...].
    Returns (logits [W, V], new blocks)."""
    logits, new = T.decode_step(params, cfg, tok[:, None],
                                {"blocks": blocks, "pos": pos})
    return logits, new["blocks"]


def _wave_decode_vmapped(params, cfg: ModelConfig, tok, pos, blocks):
    """Per-slot reference: vmap of the B=1 decode_step over the wave."""

    def one_slot(tok_w, pos_w, slot_blocks):
        cache = {"blocks": jax.tree_util.tree_map(lambda l: l[:, None],
                                                  slot_blocks),
                 "pos": pos_w}
        logits, new = T.decode_step(params, cfg, tok_w[None, None], cache)
        return logits[0], jax.tree_util.tree_map(lambda l: l[:, 0],
                                                 new["blocks"])

    return jax.vmap(one_slot, in_axes=(0, 0, 1), out_axes=(0, 1))(
        tok, pos, blocks)


# ---------------------------------------------------------------------------
# Jitted engine programs (cached per (cfg, gcfg, P, n_reqs))
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _build_fns(cfg: ModelConfig, gcfg: GenServeConfig, prompt_len: int,
               n_reqs: int, impl: str = "jnp",
               draft_cfg: Optional[ModelConfig] = None,
               mesh=None):
    # `impl` (the active models.attention implementation) is part of the
    # cache key only: tracing reads the global impl at first call, so a
    # cached jitted fn built under "jnp" must not be reused under
    # "pallas" (or vice versa).  `mesh` likewise: the jitted programs
    # bake in the sharding constraints (``parallel.sharding`` hints)
    # active when first traced, so a fn traced for one mesh — or none —
    # must not serve another.
    N = gcfg.max_new_tokens
    eos = gcfg.eos_token
    dummy_row = n_reqs               # output buffers carry a scratch row
    ps = gcfg.page_size
    paged = ps > 0
    max_seq = prompt_len + N
    spec_k = gcfg.spec_k
    spec = spec_k > 0
    k1 = spec_k + 1                  # candidate-chunk width [tok, d_1..d_k]
    assert not spec or draft_cfg is not None, \
        "spec_k > 0 requires a draft model config"
    # without prefix sharing the block table is the identity mapping
    # forever (install always writes identity rows), so the pool view is
    # a pure reshape — no gather, ~zero overhead over contiguous
    identity_pool = (paged and not gcfg.prefix_cache
                     and gcfg.pool_pages == 0)

    def view_of(st):
        """Contiguous per-slot blocks view the model steps consume."""
        if not paged:
            return st["cache"]
        return cache_mod.paged_view(cfg, st["cache"], st["btab"], max_seq,
                                    page_size=ps, identity=identity_pool)

    def sample(key, logits):
        return sampling.sample_tokens(key, logits,
                                      temperature=gcfg.temperature,
                                      greedy=gcfg.greedy)

    def sample_lp(key, logits):
        """(tokens, logprobs) at the engine's temperature — the shared
        sampling point of every program (models.sampling owns the
        temperature/greedy semantics)."""
        return sampling.sample_with_logprobs(key, logits,
                                             temperature=gcfg.temperature,
                                             greedy=gcfg.greedy)

    def admit(params, dparams, state, prompts, admit_mask, rows, limits,
              key):
        """Prefill [W, P] prompts; install admitted slots; sample token 0."""
        out = T.forward(params, cfg, {"tokens": prompts}, return_cache=True,
                        max_cache_len=prompt_len + N, remat=False)
        logits0 = out["logits"][:, -1]
        tok0, lp0 = sample_lp(key, logits0)
        alive0 = sampling.initial_alive(prompts, eos) & admit_mask
        finished0 = limits <= 1
        if eos is not None:
            finished0 |= tok0 == eos

        buf_rows = jnp.where(admit_mask, rows, dummy_row)
        st = dict(state)
        st["gen"] = state["gen"].at[buf_rows, 0].set(tok0)
        st["lp"] = state["lp"].at[buf_rows, 0].set(lp0)
        st["mask"] = state["mask"].at[buf_rows, 0].set(
            alive0.astype(jnp.float32))
        st["cache"] = cache_mod.scatter_slots(state["cache"],
                                              out["cache"]["blocks"],
                                              admit_mask)
        st["pos"] = jnp.where(admit_mask, prompt_len, state["pos"])
        st["tok"] = jnp.where(admit_mask, tok0, state["tok"])
        st["ngen"] = jnp.where(admit_mask, 1, state["ngen"])
        st["req"] = jnp.where(admit_mask, rows, state["req"])
        st["limit"] = jnp.where(admit_mask, limits, state["limit"])
        st["occupied"] = jnp.where(admit_mask, alive0 & ~finished0,
                                   state["occupied"])
        if spec:
            # prime the draft's own per-slot cache on the same prompts:
            # its position cursor stays identical to the target's
            dout = T.forward(dparams, draft_cfg, {"tokens": prompts},
                             return_cache=True,
                             max_cache_len=prompt_len + N, remat=False)
            st["dcache"] = cache_mod.scatter_slots(
                state["dcache"], dout["cache"]["blocks"], admit_mask)
        return st

    wave_decode = (_wave_decode_batched if gcfg.decode_path == "batched"
                   else _wave_decode_vmapped)

    def decode_substep(params, st, key, protect: bool):
        """One batched wave decode step.  With ``protect`` the cache
        writes (KV + recurrent state) of non-emitting rows are merged
        back — required whenever slots may be mid-prefill (the mixed
        wave-step), free to skip on the pure decode path (a free row's
        clobbered cache is replaced wholesale at one-shot re-admission,
        or zeroed + rewritten by chunked re-admission).  Paged caches
        scatter the token delta through the block table instead
        (``paged_update_decode``) — inherently emit-masked, so protect
        is moot and freed pages stay untouched."""
        logits, new_blocks = wave_decode(params, cfg, st["tok"],
                                         st["pos"], view_of(st))
        nxt, lp = sample_lp(key, logits)
        emit = st["occupied"]
        buf_rows = jnp.where(emit, st["req"], dummy_row)
        cols = jnp.where(emit, st["ngen"], 0)
        alive_after = sampling.next_alive(emit, nxt, eos)
        finished = emit & (~alive_after |
                           (st["ngen"] + 1 >= st["limit"]))
        st = dict(st)
        st["gen"] = st["gen"].at[buf_rows, cols].set(nxt)
        st["lp"] = st["lp"].at[buf_rows, cols].set(lp)
        st["mask"] = st["mask"].at[buf_rows, cols].set(
            emit.astype(jnp.float32))
        if paged:
            st["cache"] = cache_mod.paged_update_decode(
                cfg, st["cache"], new_blocks, st["btab"], st["pos"],
                emit, max_seq, page_size=ps)
        else:
            st["cache"] = cache_mod.scatter_slots(st["cache"], new_blocks,
                                                  emit) if protect \
                else new_blocks
        st["pos"] = jnp.where(emit, st["pos"] + 1, st["pos"])
        st["tok"] = jnp.where(emit, nxt, st["tok"])
        st["ngen"] = jnp.where(emit, st["ngen"] + 1, st["ngen"])
        st["occupied"] = emit & ~finished
        return st, jnp.sum(emit.astype(jnp.int32))

    def _commit_accepted(view_blocks, deltas, pos, n_valid):
        """Write the accepted prefix of a verify chunk into the
        contiguous (or contiguous-view) cache: per-layer validity-masked
        ``write_kv`` at per-row cursors.  Rows with ``n_valid == 0`` take
        no writes at all, so mid-prefill / free slots are protected for
        free and rejected speculative k/v never lands."""
        valid = jnp.arange(k1)[None, :] < n_valid[:, None]
        out = {}
        for j, spec_l in enumerate(cfg.pattern):
            w = cache_mod.effective_window(cfg, spec_l, False)
            lk = f"layer{j}"
            nk, nv = jax.vmap(
                lambda a, b, c, d, w=w: cache_mod.write_kv(
                    a, b, c, d, pos, w, valid=valid))(
                view_blocks[lk]["k"], view_blocks[lk]["v"],
                deltas[lk]["k"], deltas[lk]["v"])
            out[lk] = {"k": nk, "v": nv}
        return out

    def spec_substep(params, dparams, st, key, protect: bool):
        """The draft/verify sub-round: k draft decode steps propose
        d_1..d_k per live slot, ONE batched target step over the
        [W, k+1] candidate chunk verifies them, and up to k+1 tokens
        (accepted prefix + bonus/corrected token) land at once through
        the variable-length chunk-write machinery.  The commit is
        inherently emit-masked (``n_valid == 0`` rows take no writes),
        so ``protect`` is moot — mid-prefill rows are safe either way.

        The draft keeps its own full-attention cache at the target's
        position: after committing e tokens, draft positions
        pos..pos+e-1 hold exactly the tokens the target committed, and
        stale entries beyond are masked invalid (and overwritten before
        read) in a full cache — the rollback-free invariant
        ``cache.supports_speculative_draft`` guarantees."""
        emit = st["occupied"]
        pos = st["pos"]
        keys = jax.random.split(key, spec_k + 2)

        def propose(carry, kk):
            tok_c, dblocks, dpos = carry
            logits, new = T.decode_step(dparams, draft_cfg, tok_c[:, None],
                                        {"blocks": dblocks, "pos": dpos})
            nxt = sample(kk, logits)
            return (nxt, new["blocks"], dpos + 1), (nxt, logits)

        # k+1 draft steps for k proposals: the extra step's proposal is
        # discarded, but it writes d_k's draft k/v — without it the
        # full-accept-plus-bonus round (e == k+1) leaves a hole at
        # pos+k that the draft would attend to on every later round
        (_, dblocks, _), (drafts, dlogits) = jax.lax.scan(
            propose, (st["tok"], st["dcache"], pos), keys[:spec_k + 1])
        drafts = jnp.swapaxes(drafts, 0, 1)[:, :spec_k]      # [W, k]
        dlogits = jnp.swapaxes(dlogits, 0, 1)[:, :spec_k]    # [W, k, V]

        view = view_of(st)
        cand_in = jnp.concatenate([st["tok"][:, None], drafts], axis=1)
        vlogits, deltas = T.verify_chunk_step(
            params, cfg, cand_in, {"blocks": view, "pos": pos})
        a, cand = sampling.speculative_accept(
            keys[spec_k + 1], vlogits, drafts, dlogits,
            temperature=gcfg.temperature, greedy=gcfg.greedy)

        # effective emit count e: accepted + bonus, cut at the remaining
        # budget and at the first emitted EOS (the EOS itself is valid)
        e = jnp.minimum(a + 1, jnp.maximum(st["limit"] - st["ngen"], 0))
        if eos is not None:
            first_eos = jnp.min(
                jnp.where(cand == eos, jnp.arange(k1)[None, :], k1 + N),
                axis=1)
            e = jnp.minimum(e, first_eos + 1)
        e = jnp.where(emit, e, 0)
        hit_eos = (first_eos < e) if eos is not None \
            else jnp.zeros_like(emit)
        finished = emit & (hit_eos | (st["ngen"] + e >= st["limit"]))

        ar = jnp.arange(k1)[None, :]
        val = (ar < e[:, None]) & emit[:, None]        # [W, k+1]
        buf_rows = jnp.where(val, st["req"][:, None], dummy_row)
        cols = jnp.where(val, st["ngen"][:, None] + ar, 0)
        lp_all = sampling.token_logprobs(vlogits, cand)
        st = dict(st)
        st["gen"] = st["gen"].at[buf_rows, cols].set(
            jnp.where(val, cand, 0), mode="drop")
        st["lp"] = st["lp"].at[buf_rows, cols].set(
            jnp.where(val, lp_all, 0.0), mode="drop")
        st["mask"] = st["mask"].at[buf_rows, cols].set(
            val.astype(jnp.float32), mode="drop")

        committed = _commit_accepted(view, deltas, pos, e)
        if paged:
            st["cache"] = cache_mod.paged_update_chunk(
                cfg, st["cache"], committed, st["btab"], pos, e, k1,
                max_seq, page_size=ps)
        else:
            st["cache"] = committed
        st["dcache"] = cache_mod.scatter_slots(st["dcache"], dblocks, emit)

        new_tok = jnp.take_along_axis(
            cand, jnp.clip(e - 1, 0, spec_k)[:, None], axis=1)[:, 0]
        st["tok"] = jnp.where(emit, new_tok, st["tok"])
        st["pos"] = pos + e
        st["ngen"] = st["ngen"] + e
        st["occupied"] = emit & ~finished
        emit_n = jnp.sum(emit.astype(jnp.int32))
        return st, (emit_n, jnp.sum(e), emit_n * spec_k,
                    jnp.sum(jnp.where(emit, a, 0)))

    def chunk(params, dparams, state, keys):
        """`decode_chunk` wave steps; returns per-step active counts
        (speculative: (active, emitted-token, proposed, accepted)
        per-step counts)."""
        if spec:
            return jax.lax.scan(
                lambda st, key: spec_substep(params, dparams, st, key,
                                             protect=False),
                state, keys)
        return jax.lax.scan(
            lambda st, key: decode_substep(params, st, key, protect=False),
            state, keys)

    C = max(gcfg.prefill_chunk, 1)

    def install(state, prompts, admit_mask, rows, limits, plens,
                pstarts=None, btab_new=None):
        """Chunked admission: stage request metadata into the admitted
        slots and zero their cache rows — no model compute; the prompt
        is ingested chunk by chunk by subsequent ``mixed`` rounds.
        Paged: additionally merge the admitted slots' block-table rows,
        start each prefill cursor at ``pstarts`` (after the prefix the
        host found cached) and zero only the per-slot leaves — pool
        pages may be shared with live slots and stale page contents are
        masked by position validity anyway."""
        st = dict(state)
        st["prompt"] = jnp.where(admit_mask[:, None], prompts,
                                 state["prompt"])
        st["plen"] = jnp.where(admit_mask, plens, state["plen"])
        st["prefilling"] = state["prefilling"] | admit_mask
        st["req"] = jnp.where(admit_mask, rows, state["req"])
        st["limit"] = jnp.where(admit_mask, limits, state["limit"])
        st["ngen"] = jnp.where(admit_mask, 0, state["ngen"])
        st["occupied"] = state["occupied"] & ~admit_mask
        if paged:
            st["pcur"] = jnp.where(admit_mask, pstarts, state["pcur"])
            st["btab"] = jnp.where(admit_mask[:, None], btab_new,
                                   state["btab"])
            st["cache"] = cache_mod.zero_paged_slots(cfg, state["cache"],
                                                     admit_mask)
        else:
            st["pcur"] = jnp.where(admit_mask, 0, state["pcur"])
            st["cache"] = cache_mod.zero_slots(state["cache"], admit_mask)
        if spec:
            # the draft has no radix cache: it ingests the full prompt
            # from cursor 0, while a prefix-cache target starts after the
            # cached prefix.  Idle the target's prefill for the chunk-
            # count difference so both cursors land on the same sub-round
            # (the landing logits must come from the target's real final
            # chunk).
            st["dcur"] = jnp.where(admit_mask, 0, state["dcur"])
            st["dcache"] = cache_mod.zero_slots(state["dcache"],
                                                admit_mask)
            ncd = (st["plen"] + C - 1) // C
            nct = (st["plen"] - st["pcur"] + C - 1) // C
            st["pdelay"] = jnp.where(admit_mask, ncd - nct,
                                     state["pdelay"])
        return st

    def copy(state, src, dst):
        """COW page copies (paged only): pool[dst] = pool[src] in every
        attention layer; sentinel-padded entries are dropped."""
        st = dict(state)
        st["cache"] = cache_mod.copy_pages(cfg, state["cache"], src, dst)
        return st

    def prefill_substep(params, dparams, st, k_land):
        """The prefill half of one mixed sub-round: one [W, C] prompt
        chunk over the admitting slots (masked), landing any slot whose
        final chunk just arrived (first token sampled from k_land).
        Speculative engines additionally advance the draft's own prompt
        cursor (full prompt, no prefix skip) and hold the target idle
        while ``pdelay > 0`` so both cursors finish together."""
        st = dict(st)
        pf = st["prefilling"]
        pcur = st["pcur"]
        idle = (st["pdelay"] > 0) if spec else jnp.zeros_like(pf)
        n_valid = jnp.where(pf & ~idle,
                            jnp.clip(st["plen"] - pcur, 0, C), 0)
        idx = jnp.clip(pcur[:, None] + jnp.arange(C), 0,
                       st["prompt"].shape[1] - 1)
        chunk_tok = jnp.take_along_axis(st["prompt"], idx, axis=1)
        last_logits, pf_cache = T.prefill_chunk_step(
            params, cfg, chunk_tok, {"blocks": view_of(st), "pos": pcur},
            n_valid=n_valid)
        prow = n_valid > 0
        if paged:
            cache_p = cache_mod.paged_update_chunk(
                cfg, st["cache"], pf_cache["blocks"], st["btab"], pcur,
                n_valid, C, max_seq, page_size=ps)
        else:
            cache_p = cache_mod.scatter_slots(st["cache"],
                                              pf_cache["blocks"], prow)

        land = pf & (pcur + n_valid >= st["plen"])
        if spec:
            dcur = st["dcur"]
            n_valid_d = jnp.where(pf, jnp.clip(st["plen"] - dcur, 0, C), 0)
            idx_d = jnp.clip(dcur[:, None] + jnp.arange(C), 0,
                             st["prompt"].shape[1] - 1)
            dtok = jnp.take_along_axis(st["prompt"], idx_d, axis=1)
            _, d_cache = T.prefill_chunk_step(
                dparams, draft_cfg, dtok,
                {"blocks": st["dcache"], "pos": dcur}, n_valid=n_valid_d)
            st["dcache"] = cache_mod.scatter_slots(
                st["dcache"], d_cache["blocks"], n_valid_d > 0)
            st["dcur"] = dcur + n_valid_d
            st["pdelay"] = jnp.where(pf & idle, st["pdelay"] - 1,
                                     st["pdelay"])
            land = land & (st["dcur"] >= st["plen"])
            prow = prow | (n_valid_d > 0)
        tok0, lp0 = sample_lp(k_land, last_logits)
        last_prompt_tok = jnp.take_along_axis(
            st["prompt"], jnp.clip(st["plen"] - 1, 0, None)[:, None],
            axis=1)[:, 0]
        alive0 = land if eos is None else land & (last_prompt_tok != eos)
        finished0 = st["limit"] <= 1
        if eos is not None:
            finished0 = finished0 | (tok0 == eos)
        buf0 = jnp.where(land, st["req"], dummy_row)
        st["gen"] = st["gen"].at[buf0, 0].set(tok0)
        st["lp"] = st["lp"].at[buf0, 0].set(lp0)
        st["mask"] = st["mask"].at[buf0, 0].set(alive0.astype(jnp.float32))
        st["cache"] = cache_p
        st["pcur"] = jnp.where(pf, pcur + n_valid, pcur)
        st["prefilling"] = pf & ~land
        st["pos"] = jnp.where(land, st["plen"], st["pos"])
        st["tok"] = jnp.where(land, tok0, st["tok"])
        st["ngen"] = jnp.where(land, 1, st["ngen"])
        st["occupied"] = jnp.where(land, alive0 & ~finished0,
                                   st["occupied"])
        return st, jnp.sum(prow.astype(jnp.int32))

    def mixed(params, dparams, state, k_decodes, k_lands):
        """The mixed wave-step: a scan of sub-rounds, each ONE batched
        decode step over decoding slots (cache rows of admitting slots
        protected) plus ONE [W, C] prefill chunk over admitting slots —
        one fixed-shape program per round, so admission of a long prompt
        never stalls the decode wave.  A slot landing at sub-round j
        decodes from sub-round j+1 onward *within the same program*.
        The host sizes the scan (len(k_decodes) sub-rounds, bounded by
        ``decode_chunk``) to the outstanding prefill work so no
        all-masked chunk passes run — each distinct scan length is its
        own jit variant, compiled once (at most ``decode_chunk`` of
        them).  Returns (state, (per-sub-round decode counts,
        per-sub-round prefill counts))."""

        def sub(st, keys2):
            k_d, k_l = keys2
            if spec:
                st, d = spec_substep(params, dparams, st, k_d,
                                     protect=True)
            else:
                st, d = decode_substep(params, st, k_d, protect=True)
            st, p = prefill_substep(params, dparams, st, k_l)
            return st, (d, p)

        st, (d_counts, p_counts) = jax.lax.scan(
            sub, dict(state), (k_decodes, k_lands))
        return st, (d_counts, p_counts)

    if spec:
        return (jax.jit(admit), jax.jit(chunk), jax.jit(install),
                jax.jit(mixed), jax.jit(copy))
    # signature-stable non-speculative programs: no dparams operand, so
    # existing call sites (and the jit caches keyed on them) are
    # untouched when spec_k == 0
    return (
        jax.jit(lambda params, state, prompts, admit_mask, rows, limits,
                key: admit(params, None, state, prompts, admit_mask,
                           rows, limits, key)),
        jax.jit(lambda params, state, keys: chunk(params, None, state,
                                                  keys)),
        jax.jit(install),
        jax.jit(lambda params, state, k_decodes, k_lands: mixed(
            params, None, state, k_decodes, k_lands)),
        jax.jit(copy))


def _pool_pages(cfg: ModelConfig, gcfg: GenServeConfig,
                prompt_len: int) -> Tuple[int, int]:
    """(max pages per slot, pool size) for a paged engine config.

    Default pool: ``W*MP`` without prefix caching (exactly the
    contiguous footprint — the identity block table fills it), ``2*W*MP``
    with it, which guarantees a full admission wave can always be
    satisfied after LRU eviction: live slots pin at most ``W*MP``
    distinct pages, so at least ``W*MP`` are free or tree-only."""
    MP = cache_mod.max_pages_per_slot(cfg, prompt_len + gcfg.max_new_tokens,
                                      gcfg.page_size)
    if gcfg.pool_pages > 0:
        NP = gcfg.pool_pages
    else:
        NP = gcfg.wave * MP * (2 if gcfg.prefix_cache else 1)
    return MP, NP


def _init_state(cfg: ModelConfig, gcfg: GenServeConfig, prompt_len: int,
                n_reqs: int, draft_cfg: Optional[ModelConfig] = None
                ) -> Dict[str, object]:
    W, N = gcfg.wave, gcfg.max_new_tokens
    if gcfg.page_size > 0:
        MP, NP = _pool_pages(cfg, gcfg, prompt_len)
        cache = cache_mod.init_paged_cache(cfg, W, prompt_len + N,
                                           page_size=gcfg.page_size,
                                           n_pages=NP,
                                           dtype=jnp.dtype(cfg.dtype))
        # without prefix sharing the block table is the static identity
        # map (pool == reshaped contiguous layout); with sharing the
        # host assigns rows at admission, so start fully unmapped
        if gcfg.prefix_cache:
            btab = jnp.full((W, MP), NP, jnp.int32)
        else:
            btab = jnp.asarray(cache_mod.identity_block_table(W, MP))
    else:
        cache = cache_mod.init_cache(cfg, W, prompt_len + N,
                                     dtype=jnp.dtype(cfg.dtype))
        btab = None
    st = {
        "tok": jnp.zeros((W,), jnp.int32),
        "pos": jnp.zeros((W,), jnp.int32),
        "occupied": jnp.zeros((W,), bool),
        "req": jnp.full((W,), n_reqs, jnp.int32),
        "ngen": jnp.zeros((W,), jnp.int32),
        "limit": jnp.ones((W,), jnp.int32),
        "cache": cache["blocks"],
        "gen": jnp.zeros((n_reqs + 1, N), jnp.int32),
        "lp": jnp.zeros((n_reqs + 1, N), jnp.float32),
        "mask": jnp.zeros((n_reqs + 1, N), jnp.float32),
    }
    if gcfg.prefill_chunk > 0:
        # chunked-prefill slot state: per-slot prompt buffer, prefill
        # cursor, prompt length and mid-prefill flag (only present when
        # chunked admission is on — the one-shot path's per-round calls
        # should not carry dead operands)
        st.update({
            "prompt": jnp.zeros((W, prompt_len), jnp.int32),
            "pcur": jnp.zeros((W,), jnp.int32),
            "plen": jnp.full((W,), prompt_len, jnp.int32),
            "prefilling": jnp.zeros((W,), bool),
        })
    if btab is not None:
        st["btab"] = btab
    if gcfg.spec_k > 0:
        assert draft_cfg is not None
        # the draft's per-slot state: small contiguous full-attention
        # cache (never paged — it is a fraction of the target's
        # footprint) plus, under chunked admission, its own prompt
        # cursor and the target's prefix-skip idle counter
        st["dcache"] = cache_mod.init_cache(
            draft_cfg, W, prompt_len + N,
            dtype=jnp.dtype(draft_cfg.dtype))["blocks"]
        if gcfg.prefill_chunk > 0:
            st["dcur"] = jnp.zeros((W,), jnp.int32)
            st["pdelay"] = jnp.zeros((W,), jnp.int32)
    return st


# ---------------------------------------------------------------------------
# Host-driven engine loop
# ---------------------------------------------------------------------------

def serve(params, cfg: ModelConfig, prompts, rng, gcfg: GenServeConfig,
          gen_lens: Optional[Sequence[int]] = None,
          prompt_lens: Optional[Sequence[int]] = None,
          slot_failures: Optional[Dict[int, Sequence[int]]] = None,
          cancels: Optional[Dict[int, Sequence[int]]] = None,
          draft_params=None, draft_cfg: Optional[ModelConfig] = None,
          mesh=None, _in_mesh: bool = False
          ) -> Tuple[Dict[str, jnp.ndarray], Dict[str, object]]:
    """Generate for all `prompts` [B, P] with continuous batching.

    Returns (rollout dict — the exact `rl.rollout.generate` contract —
    and an engine-stats dict with the per-round wave timeline, occupancy
    trace and per-request time-to-first-token).  `gen_lens` optionally
    caps each request's budget (used by benchmarks to impose
    output-length distributions).  `prompt_lens` marks each request's
    real prompt length inside the padded [B, P] array: the chunked
    admission path (``gcfg.prefill_chunk > 0``) ingests only the real
    tokens and lands each slot at its own length; the one-shot path
    prefills the padded width (padding-as-content — the reference
    semantics), so cross-path parity holds only for uniform lengths.

    Fault/cancel hooks (both keyed on the 1-based host round index,
    applied at the top of that round):

    * ``slot_failures``: round -> decode-slot ids that die there.  The
      in-flight request is requeued from scratch — slot freed, device
      occupancy/prefill flags cleared, partial output rows zeroed,
      pages decrefed (shared prefix pages survive in the radix tree, so
      re-admission skips the cached prefill) — and the loop runs until
      it completes like any other request.
    * ``cancels``: round -> request ids to retire explicitly (no EOS,
      no budget exhaustion): dequeued if pending, evicted + zeroed if
      in-flight; their output rows are all-zero with an all-zero mask.

    ``mesh`` makes the serve loop mesh-aware: params (and draft params)
    are committed onto the mesh's TP/FSDP shardings and every engine
    program is traced under the mesh with ``parallel.sharding``
    activation hints active, so continuous-batching decode runs sharded
    over the generation group's devices.  The mesh joins the jit-program
    cache key (constraints are baked in at trace time)."""
    gcfg.validate()
    if mesh is not None and not _in_mesh and mesh.devices.size > 1:
        from repro.parallel import sharding as sh_mod
        params = jax.device_put(params, sh_mod.named_shardings(
            mesh, sh_mod.param_tree_specs(params), params))
        if draft_params is not None:
            draft_params = jax.device_put(draft_params, sh_mod.named_shardings(
                mesh, sh_mod.param_tree_specs(draft_params), draft_params))
        rules = sh_mod.default_activation_rules(seq_shard=False)
        with mesh, sh_mod.use_hints(rules):
            return serve(params, cfg, prompts, rng, gcfg,
                         gen_lens=gen_lens, prompt_lens=prompt_lens,
                         slot_failures=slot_failures, cancels=cancels,
                         draft_params=draft_params, draft_cfg=draft_cfg,
                         mesh=mesh, _in_mesh=True)
    if mesh is not None and mesh.devices.size <= 1:
        mesh = None
    spec = gcfg.spec_k > 0
    if spec:
        assert draft_params is not None and draft_cfg is not None, \
            "spec_k > 0 requires draft_params and draft_cfg"
        assert cache_mod.supports_speculative_target(cfg), (
            "speculative decoding requires an attention-only target "
            "without rwkv_channel FFNs: recurrent state cannot roll "
            "back rejected tokens")
        assert cache_mod.supports_speculative_draft(draft_cfg), (
            "the draft must be full-window attention-only: its cache "
            "takes pre-acceptance writes that only a full (non-ring) "
            "layout can mask out after rollback")
        assert draft_cfg.vocab_size == cfg.vocab_size, \
            "draft and target must share a vocabulary"
    prompts_np = np.asarray(prompts, np.int32)
    B, P = prompts_np.shape
    N, W = gcfg.max_new_tokens, gcfg.wave
    K = min(gcfg.decode_chunk, N)
    C = gcfg.prefill_chunk
    chunked = C > 0
    if chunked and prompt_lens is not None:
        plens_np = np.clip(np.asarray(prompt_lens, np.int64), 1, P)
    else:
        plens_np = np.full((B,), P, np.int64)
    nchunks = int(np.ceil(P / C)) if chunked else 0

    limits = np.full((B,), N, np.int64) if gen_lens is None \
        else np.clip(np.asarray(gen_lens, np.int64), 1, N)
    queue = RequestQueue([Request(i, int(limits[i])) for i in range(B)],
                         policy=gcfg.admission, aging=gcfg.sjf_aging)
    table = SlotTable(W)
    ps = gcfg.page_size
    paged = ps > 0
    sharing = gcfg.prefix_cache
    if sharing:
        assert cache_mod.supports_prefix_sharing(cfg), (
            "prefix_cache requires every layer to be full-window "
            "attention: ring windows would clobber shared pages and "
            "recurrent state cannot be snapshotted at a prompt boundary "
            "(use page_size without prefix_cache for those configs)")
    if paged:
        MP, NP = _pool_pages(cfg, gcfg, P)
        identity_rows = cache_mod.identity_block_table(W, MP)
    pool = radix = None
    if sharing:
        pool = PagePool(NP, ps)
        radix = RadixCache(pool)
        slot_pages: List[List[int]] = [[] for _ in range(W)]
        slot_tokens: Dict[int, List[int]] = {}
    # measure_ttft is host-only — strip it from the program cache key so
    # flipping instrumentation never recompiles the device programs
    fns_cfg = dataclasses.replace(gcfg, measure_ttft=False)
    admit_fn, chunk_fn, install_fn, mixed_fn, copy_fn = _build_fns(
        cfg, fns_cfg, P, B, attn_mod.get_attention_impl(),
        draft_cfg if spec else None, mesh)
    state = _init_state(cfg, fns_cfg, P, B, draft_cfg if spec else None)
    spec_tokens = spec_proposed = spec_accepted = 0

    # rngs[t] drives the t-th sampling event, mirroring rollout.generate:
    # the first admission consumes rngs[0] (at its landing round when
    # chunked), decode step t consumes rngs[t].
    rngs = jax.random.split(rng, N)
    side = jax.random.fold_in(rng, 0x5EED)
    side_admit = jax.random.fold_in(side, 0)    # late-admission sampling
    side_step = jax.random.fold_in(side, 1)     # decode steps beyond rngs
    next_key = 0
    rounds: List[Tuple[float, float, float, int]] = []
    ttft: Dict[int, float] = {}
    queue_wait: Dict[int, float] = {}    # request admission wait (host)
    n_prefills = 0
    round_idx = 0
    occupied = np.zeros((W,), bool)      # device occupancy, host view
    # host mirror of each slot's remaining prefill chunks — every mixed
    # round advances every prefilling slot by exactly one chunk, so
    # landings are known without a device sync
    prefill_left = np.zeros((W,), np.int64)
    t_start = time.monotonic()

    def evict_slot(s: int) -> None:
        """Clear slot ``s``'s device + host residue after a failure or
        cancel: occupancy/prefill flags down, pages decrefed.  The
        caller has already fixed the table's books."""
        nonlocal state, occupied
        state["occupied"] = state["occupied"].at[s].set(False)
        if chunked:
            state["prefilling"] = state["prefilling"].at[s].set(False)
            prefill_left[s] = 0
        occupied = occupied.copy()  # np.asarray views of jax arrays are
        occupied[s] = False         # read-only

        if sharing and slot_pages[s]:
            pool.decref(slot_pages[s])
            slot_pages[s] = []
            slot_tokens.pop(s, None)

    def zero_request_rows(rid: int) -> None:
        """Wipe a request's partial output (tokens, logprobs, mask) so a
        requeued regeneration — possibly shorter — leaves no stale
        columns, and a cancelled request reads as all-masked."""
        nonlocal state
        for k in ("gen", "lp", "mask"):
            state[k] = state[k].at[rid].set(0)

    while len(queue) or table.active:
        round_idx += 1
        # requeued requests legitimately extend the round budget: each
        # re-admission costs at most one extra full request lifetime
        budget_reqs = B + table.requeued
        assert round_idx <= 2 * budget_reqs * (N + 1) \
            + budget_reqs * (nchunks + 1), \
            "genserve loop did not converge"
        t0 = time.monotonic()
        # span opened/closed manually: the loop body stays un-indented
        # (an aborted round is simply not recorded)
        rspan = obs_trace.span("gen.round", round=round_idx)
        rspan.__enter__()
        if slot_failures and round_idx in slot_failures:
            for s in slot_failures[round_idx]:
                rid = table.fail_slot(int(s))
                if rid == FREE:
                    continue
                evict_slot(int(s))
                zero_request_rows(rid)
                queue.push(Request(rid, int(limits[rid])))
        if cancels and round_idx in cancels:
            for rid in cancels[round_idx]:
                rid = int(rid)
                if queue.cancel(rid):
                    # never admitted: only the cancel is counted
                    table.cancelled += 1
                    obs_metrics.counter("gen.cancelled").inc()
                    continue
                if rid in table.slot_req:
                    s = table.slot_req.index(rid)
                    table.cancel_slot(s)
                    evict_slot(s)
                    zero_request_rows(rid)
        obs_metrics.gauge("gen.queue_depth").set(len(queue))
        admitted = 0
        may_live = False
        reqs: List[Request] = []
        free = table.free_slots()
        if free and len(queue):
            aspan = obs_trace.span("gen.install" if chunked
                                   else "gen.admit")
            aspan.__enter__()
            reqs = queue.pop(len(free))
            slots = free[:len(reqs)]
            pb = np.broadcast_to(prompts_np[reqs[0].rid],
                                 (W, P)).copy()
            admit_mask = np.zeros((W,), bool)
            rows = np.full((W,), B, np.int32)
            lim = np.ones((W,), np.int32)
            pl = np.full((W,), P, np.int32)
            for s, rq in zip(slots, reqs):
                pb[s] = prompts_np[rq.rid]
                admit_mask[s] = True
                rows[s] = rq.rid
                lim[s] = rq.max_new_tokens
                pl[s] = plens_np[rq.rid]
            if chunked:
                pstarts = np.zeros((W,), np.int32)
                btab_new = None
                if paged:
                    btab_new = np.full((W, MP), NP, np.int32)
                if sharing:
                    copy_src: List[int] = []
                    copy_dst: List[int] = []
                    cow_srcs: List[int] = []
                    for s, rq in zip(slots, reqs):
                        p = int(plens_np[rq.rid])
                        toks = prompts_np[rq.rid, :p].tolist()
                        # cap the hit at p-1 tokens: the landing chunk
                        # must always run so first-token logits come
                        # from a real forward pass
                        full, part = radix.match(toks, p - 1)
                        pool.incref(full)          # the slot's refs
                        if part is not None:
                            # keep the COW source alive across eviction
                            # until its copy is scheduled
                            pool.incref([part[0]])
                            cow_srcs.append(part[0])
                        need = MP - len(full)
                        if pool.available() < need:
                            radix.evict(need - pool.available())
                        fresh = pool.alloc(need)
                        assert fresh is not None, "page pool exhausted"
                        row = full + fresh
                        btab_new[s, :len(row)] = row
                        pstart = len(full) * ps
                        if part is not None:
                            copy_src.append(part[0])
                            copy_dst.append(fresh[0])
                            pstart += part[1]
                        pstarts[s] = pstart
                        slot_pages[s] = row
                        slot_tokens[s] = toks
                        table.record_prefix(pstart, p)
                        # speculative: the draft ingests the full prompt
                        # (no radix cache), so its chunk count paces the
                        # slot; the target idles for the difference
                        prefill_left[s] = -(-p // C) if spec \
                            else -(-(p - pstart) // C)
                    if copy_src:
                        src = np.full((W,), NP, np.int32)
                        dst = np.full((W,), NP, np.int32)
                        src[:len(copy_src)] = copy_src
                        dst[:len(copy_dst)] = copy_dst
                        state = copy_fn(state, src, dst)
                    if cow_srcs:
                        pool.decref(cow_srcs)
                else:
                    for s, rq in zip(slots, reqs):
                        p = int(plens_np[rq.rid])
                        if paged:
                            btab_new[s] = identity_rows[s]
                        table.record_prefix(0, p)
                        prefill_left[s] = -(-p // C)
                if paged:
                    state = install_fn(state, pb, admit_mask, rows, lim,
                                       pl, pstarts, jnp.asarray(btab_new))
                else:
                    state = install_fn(state, pb, admit_mask, rows, lim, pl)
            else:
                key = rngs[0] if next_key == 0 \
                    else jax.random.fold_in(side_admit, round_idx)
                if spec:
                    state = admit_fn(params, draft_params, state, pb,
                                     admit_mask, rows, lim, key)
                else:
                    state = admit_fn(params, state, pb, admit_mask, rows,
                                     lim, key)
                next_key = max(next_key, 1)
                if gcfg.measure_ttft:
                    # first tokens exist once the admit program completes
                    # — stamp TTFT on the sampled-token leaf (this sync
                    # serializes admission against the decode chunk, so
                    # it is opt-in)
                    jax.block_until_ready(state["tok"])
                    now = time.monotonic()
                    for rq in reqs:
                        ttft[rq.rid] = now - t_start
                # host-side liveness bound — a synced read of `occupied`
                # here would serialize admission against the decode
                # chunk; this is conservative only for first-token EOS
                # (one chunk of bounded waste in that rare case)
                may_live = any(
                    rq.max_new_tokens > 1
                    and (gcfg.eos_token is None
                         or prompts_np[rq.rid, -1] != gcfg.eos_token)
                    for rq in reqs)
            table.admit(slots, reqs)
            n_prefills += 1
            admitted = len(reqs)
            now_adm = time.monotonic()
            for rq in reqs:
                queue_wait[rq.rid] = now_adm - t_start
            aspan.set("admitted", admitted)
            aspan.__exit__(None, None, None)

        counts = ()
        if chunked and prefill_left.any():
            # mixed wave-step: a scan of (decode step + prefill chunk)
            # sub-rounds, sized to the outstanding prefill work (slots
            # landing mid-scan decode for the remaining sub-rounds)
            decode_live = occupied.any()
            active_left = prefill_left[prefill_left > 0]
            k_len = int(min(K, active_left.max()))
            # reference-schedule bookkeeping: which rngs[t] each decode
            # sub-round consumes.  While nothing decodes, sub-rounds
            # before the first landing (index f) burn side keys only;
            # the first landing takes rngs[0]; decode resumes at rngs[1]
            # right after it — token-exact vs rollout.generate on a
            # single-wave batch.
            if decode_live:
                key_idx = list(range(next_key, next_key + k_len))
                next_key += k_len
                land_r0 = -1
            else:
                f = int(active_left.min()) - 1
                if k_len <= f:          # no landing inside this scan
                    key_idx = [None] * k_len
                    land_r0 = -1
                else:
                    start = max(next_key, 1)
                    key_idx = [None] * (f + 1) + \
                        [start + j for j in range(k_len - f - 1)]
                    land_r0 = f if next_key == 0 else -1
                    next_key = start + (k_len - f - 1)
            keys = jnp.stack([
                jax.random.fold_in(side_step,
                                   1_000_000 + round_idx * (K + 1) + j)
                if i is None
                else (rngs[i] if i < N
                      else jax.random.fold_in(side_step, i))
                for j, i in enumerate(key_idx)])
            k_lands = jnp.stack([
                rngs[0] if j == land_r0
                else jax.random.fold_in(side_admit,
                                        round_idx * (K + 1) + j)
                for j in range(k_len)])
            with obs_trace.span("gen.mixed", subrounds=k_len,
                                spec_k=gcfg.spec_k):
                if spec:
                    state, (d4, p) = mixed_fn(params, draft_params,
                                              state, keys, k_lands)
                    d, toks, props, accs = d4
                    spec_tokens += int(np.asarray(toks).sum())
                    spec_proposed += int(np.asarray(props).sum())
                    spec_accepted += int(np.asarray(accs).sum())
                else:
                    state, (d, p) = mixed_fn(params, state, keys, k_lands)
                counts = np.asarray(d)         # device sync
            table.record_round(counts, np.asarray(p))
            occupied = np.asarray(state["occupied"])
            landed = (prefill_left > 0) & (prefill_left <= k_len)
            if gcfg.measure_ttft:
                # free here: the occupied read above already synced
                now = time.monotonic()
                for s in np.nonzero(landed)[0]:
                    ttft[table.slot_req[s]] = now - t_start
            if sharing:
                # a landed prompt's complete pages enter the radix tree
                # (insert-at-landing: earlier rounds' matches can never
                # map pages whose KV has not been written yet).  The
                # trailing partial page stays private — decode tokens
                # share it with the prompt tail.
                for s in np.nonzero(landed)[0]:
                    toks = slot_tokens[s]
                    radix.insert(toks, slot_pages[s][:len(toks) // ps])
            prefill_left = np.maximum(prefill_left - k_len, 0)
        elif occupied.any() or may_live:
            # decode only when a slot can be occupied: requests that
            # finished at admission (budget 1, prompt-dead) never burn
            # wave steps
            keys = jnp.stack(
                [rngs[i] if i < N else jax.random.fold_in(side_step, i)
                 for i in range(next_key, next_key + K)])
            with obs_trace.span("gen.decode", steps=K,
                                spec_k=gcfg.spec_k):
                if spec:
                    state, (cnt, toks, props, accs) = chunk_fn(
                        params, draft_params, state, keys)
                    spec_tokens += int(np.asarray(toks).sum())
                    spec_proposed += int(np.asarray(props).sum())
                    spec_accepted += int(np.asarray(accs).sum())
                    counts = np.asarray(cnt)   # device sync
                else:
                    state, counts = chunk_fn(params, state, keys)
                    counts = np.asarray(counts)    # device sync
            next_key += K
            table.record_step(counts)
            occupied = np.asarray(state["occupied"])

        if sharing:
            prev_slot_req = list(table.slot_req)
        table.retire_finished(occupied | (prefill_left > 0))
        if sharing:
            # retired slots drop their page references; pages shared
            # with the radix tree or other slots survive the decref
            for s in range(W):
                if prev_slot_req[s] != FREE and table.slot_req[s] == FREE:
                    pool.decref(slot_pages[s])
                    slot_pages[s] = []
                    slot_tokens.pop(s, None)
        t1 = time.monotonic()
        occ = float(np.mean(counts)) if len(counts) else 0.0
        rounds.append((t0, t1, occ, admitted))
        rspan.set("occupancy", occ)
        rspan.__exit__(None, None, None)

    gen = np.asarray(state["gen"])[:B]
    lp = np.asarray(state["lp"])[:B]
    mask = np.asarray(state["mask"])[:B]
    res = {"sequences": jnp.concatenate(
               [jnp.asarray(prompts_np), jnp.asarray(gen)], axis=1),
           "gen_tokens": jnp.asarray(gen),
           "logprobs": jnp.asarray(lp),
           "mask": jnp.asarray(mask)}
    stats = {"engine": "genserve", "wave": W,
             "decode_steps": table.decode_steps,
             "slot_steps": table.slot_steps,
             "mean_occupancy": table.mean_occupancy(),
             "busy_occupancy": table.busy_occupancy(),
             "occupancy_trace": list(table.occupancy_trace),
             "prefill_trace": list(table.prefill_trace),
             "prefill_rounds": table.prefill_rounds,
             "prefill_slot_steps": table.prefill_slot_steps,
             "prefill_chunk": C, "prompt_len": P,
             "max_new_tokens": N,
             "prefill_rounds_per_req":
                 float(np.mean(np.ceil(plens_np / C))) if chunked else 0.0,
             "ttft": ttft, "queue_wait": queue_wait,
             "rounds": rounds, "prefills": n_prefills,
             "admitted": table.admitted, "retired": table.retired,
             "requeued": table.requeued, "cancelled": table.cancelled,
             "page_size": ps, "prefix_cache": sharing,
             "prefix_hit_rate": table.prefix_hit_rate(),
             "prefill_tokens_skipped": table.prefix_hit_tokens,
             "prompt_tokens": table.prompt_tokens,
             "spec_k": gcfg.spec_k,
             "spec_proposed": spec_proposed,
             "spec_accepted": spec_accepted,
             "spec_tokens": spec_tokens,
             "accept_rate": (spec_accepted / spec_proposed)
                 if spec_proposed else 0.0}
    # registry metrics: one batch of updates per serve() call (the hot
    # round loop only touches the queue-depth gauge)
    obs_metrics.counter("gen.tokens").inc(
        spec_tokens if spec else table.slot_steps)
    if spec:
        obs_metrics.counter("gen.spec_proposed").inc(spec_proposed)
        obs_metrics.counter("gen.spec_accepted").inc(spec_accepted)
        obs_metrics.histogram("gen.spec_accept_rate").observe(
            stats["accept_rate"])
    obs_metrics.counter("gen.requests").inc(table.retired)
    obs_metrics.histogram("gen.wave_occupancy").observe(
        table.mean_occupancy())
    obs_metrics.counter("gen.prefix_hit_tokens").inc(table.prefix_hit_tokens)
    obs_metrics.counter("gen.prompt_tokens").inc(table.prompt_tokens)
    ttft_h = obs_metrics.histogram("gen.ttft_s")
    for v in ttft.values():
        ttft_h.observe(v)
    qw_h = obs_metrics.histogram("gen.queue_wait_s")
    for v in queue_wait.values():
        qw_h.observe(v)
    if sharing:
        # teardown invariants: every slot is retired, so remaining page
        # references must be exactly the radix tree's — anything beyond
        # is a leak (warned + counted; raises under REPRO_OBS_STRICT=1)
        obs_metrics.gauge("pagepool.utilization").set(pool.utilization())
        pool.leak_check(expected_refs=radix.page_refs())
        # debug/test handles (host-side structures, no device state)
        stats["_pagepool"] = pool
        stats["_radix"] = radix
    return res, stats
