"""Wave-stepped continuous-batching decode loop.

Device-side execution is two jitted, fixed-shape programs per
(model, engine-config, prompt-shape) triple:

  * ``admit``  — prefill a [W, P] prompt batch, sample each admitted
    request's first token, and scatter the fresh per-slot cache rows into
    the wave cache (``models.cache.scatter_slots`` — whole-row
    replacement, so recycled slots cannot see stale state);
  * ``chunk``  — ``decode_chunk`` wave decode steps under ``lax.scan``:
    each step runs one *batched* single-token ``decode_step`` over the
    whole wave with per-slot cache positions (every slot keeps its own
    RoPE phase, ring-buffer window and recurrent state, so recycled
    slots stay exact while sharing a single fused attention call —
    the flash-decode Pallas kernel when the pallas impl is active),
    samples the next token for the whole wave, records it into the
    per-request output buffers, and retires slots that emitted EOS or
    hit their budget.  ``decode_path="vmapped"`` selects the legacy
    W-way vmap of a B=1 decode for parity testing.

The host loop owns dynamic membership: it reads back the ``occupied``
vector after every chunk, retires finished requests via the
``scheduler.SlotTable``, and back-fills freed slots from the admission
queue (FIFO by default; ``admission="sjf"`` admits shortest known
budgets first) with another ``admit`` call.  All shapes stay static —
membership changes are masks and scatters, never recompilation.

RNG schedule: the first ``max_new_tokens`` sampling events use
``jax.random.split(rng, max_new_tokens)`` — the exact schedule of
``rl.rollout.generate`` — so a batch that fits into a single wave
reproduces the reference path token-for-token.  Late admissions and
overflow steps draw from a ``fold_in``-derived side stream.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.genserve.scheduler import Request, RequestQueue, SlotTable
from repro.models import attention as attn_mod
from repro.models import cache as cache_mod
from repro.models import sampling
from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class GenServeConfig:
    """Engine knobs (hashable: keys the jit cache)."""

    wave: int                        # max concurrently decoding slots (W)
    max_new_tokens: int              # global budget N (output buffer width)
    decode_chunk: int = 1            # decode steps per jitted host round
    temperature: float = 1.0
    eos_token: Optional[int] = None
    greedy: bool = False
    decode_path: str = "batched"     # "batched" | "vmapped" wave decode
    admission: str = "fifo"          # "fifo" | "sjf" queue policy

    def validate(self) -> None:
        assert self.wave >= 1 and self.max_new_tokens >= 1
        assert self.decode_chunk >= 1
        assert self.decode_path in ("batched", "vmapped")
        assert self.admission in ("fifo", "sjf")


# ---------------------------------------------------------------------------
# Wave decode: one batched decode_step over all W slots.  The cache
# leaves are already laid out [R, W, ...] — exactly decode_step's batch
# layout — and per-slot cache positions ([W] `pos`) carry each recycled
# slot's own RoPE phase and ring-window validity, so the whole wave
# shares one fused attention call (the Sq == 1 flash-decode path when
# `set_attention_impl("pallas")` is active).  The legacy W-way vmap of a
# B=1 decode_step is kept as the "vmapped" parity path.
# ---------------------------------------------------------------------------

def _wave_decode_batched(params, cfg: ModelConfig, tok, pos, blocks):
    """tok, pos: [W]; blocks: cache leaves [R, W, ...].
    Returns (logits [W, V], new blocks)."""
    logits, new = T.decode_step(params, cfg, tok[:, None],
                                {"blocks": blocks, "pos": pos})
    return logits, new["blocks"]


def _wave_decode_vmapped(params, cfg: ModelConfig, tok, pos, blocks):
    """Per-slot reference: vmap of the B=1 decode_step over the wave."""

    def one_slot(tok_w, pos_w, slot_blocks):
        cache = {"blocks": jax.tree_util.tree_map(lambda l: l[:, None],
                                                  slot_blocks),
                 "pos": pos_w}
        logits, new = T.decode_step(params, cfg, tok_w[None, None], cache)
        return logits[0], jax.tree_util.tree_map(lambda l: l[:, 0],
                                                 new["blocks"])

    return jax.vmap(one_slot, in_axes=(0, 0, 1), out_axes=(0, 1))(
        tok, pos, blocks)


# ---------------------------------------------------------------------------
# Jitted engine programs (cached per (cfg, gcfg, P, n_reqs))
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _build_fns(cfg: ModelConfig, gcfg: GenServeConfig, prompt_len: int,
               n_reqs: int, impl: str = "jnp"):
    # `impl` (the active models.attention implementation) is part of the
    # cache key only: tracing reads the global impl at first call, so a
    # cached jitted fn built under "jnp" must not be reused under
    # "pallas" (or vice versa).
    N = gcfg.max_new_tokens
    eos = gcfg.eos_token
    dummy_row = n_reqs               # output buffers carry a scratch row

    def sample(key, logits):
        return sampling.sample_tokens(key, logits,
                                      temperature=gcfg.temperature,
                                      greedy=gcfg.greedy)

    def admit(params, state, prompts, admit_mask, rows, limits, key):
        """Prefill [W, P] prompts; install admitted slots; sample token 0."""
        out = T.forward(params, cfg, {"tokens": prompts}, return_cache=True,
                        max_cache_len=prompt_len + N, remat=False)
        logits0 = out["logits"][:, -1]
        tok0 = sample(key, logits0)
        lp0 = sampling.token_logprobs(logits0, tok0)
        alive0 = sampling.initial_alive(prompts, eos) & admit_mask
        finished0 = limits <= 1
        if eos is not None:
            finished0 |= tok0 == eos

        buf_rows = jnp.where(admit_mask, rows, dummy_row)
        st = dict(state)
        st["gen"] = state["gen"].at[buf_rows, 0].set(tok0)
        st["lp"] = state["lp"].at[buf_rows, 0].set(lp0)
        st["mask"] = state["mask"].at[buf_rows, 0].set(
            alive0.astype(jnp.float32))
        st["cache"] = cache_mod.scatter_slots(state["cache"],
                                              out["cache"]["blocks"],
                                              admit_mask)
        st["pos"] = jnp.where(admit_mask, prompt_len, state["pos"])
        st["tok"] = jnp.where(admit_mask, tok0, state["tok"])
        st["ngen"] = jnp.where(admit_mask, 1, state["ngen"])
        st["req"] = jnp.where(admit_mask, rows, state["req"])
        st["limit"] = jnp.where(admit_mask, limits, state["limit"])
        st["occupied"] = jnp.where(admit_mask, alive0 & ~finished0,
                                   state["occupied"])
        return st

    wave_decode = (_wave_decode_batched if gcfg.decode_path == "batched"
                   else _wave_decode_vmapped)

    def chunk(params, state, keys):
        """`decode_chunk` wave steps; returns per-step active counts."""

        def step(st, key):
            logits, new_blocks = wave_decode(params, cfg, st["tok"],
                                             st["pos"], st["cache"])
            nxt = sample(key, logits)
            lp = sampling.token_logprobs(logits, nxt)
            emit = st["occupied"]
            buf_rows = jnp.where(emit, st["req"], dummy_row)
            cols = jnp.where(emit, st["ngen"], 0)
            alive_after = sampling.next_alive(emit, nxt, eos)
            finished = emit & (~alive_after |
                               (st["ngen"] + 1 >= st["limit"]))
            st = dict(st)
            st["gen"] = st["gen"].at[buf_rows, cols].set(nxt)
            st["lp"] = st["lp"].at[buf_rows, cols].set(lp)
            st["mask"] = st["mask"].at[buf_rows, cols].set(
                emit.astype(jnp.float32))
            st["cache"] = new_blocks
            st["pos"] = jnp.where(emit, st["pos"] + 1, st["pos"])
            st["tok"] = jnp.where(emit, nxt, st["tok"])
            st["ngen"] = jnp.where(emit, st["ngen"] + 1, st["ngen"])
            st["occupied"] = emit & ~finished
            return st, jnp.sum(emit.astype(jnp.int32))

        return jax.lax.scan(step, state, keys)

    return jax.jit(admit), jax.jit(chunk)


def _init_state(cfg: ModelConfig, gcfg: GenServeConfig, prompt_len: int,
                n_reqs: int) -> Dict[str, object]:
    W, N = gcfg.wave, gcfg.max_new_tokens
    cache = cache_mod.init_cache(cfg, W, prompt_len + N,
                                 dtype=jnp.dtype(cfg.dtype))
    return {
        "tok": jnp.zeros((W,), jnp.int32),
        "pos": jnp.zeros((W,), jnp.int32),
        "occupied": jnp.zeros((W,), bool),
        "req": jnp.full((W,), n_reqs, jnp.int32),
        "ngen": jnp.zeros((W,), jnp.int32),
        "limit": jnp.ones((W,), jnp.int32),
        "cache": cache["blocks"],
        "gen": jnp.zeros((n_reqs + 1, N), jnp.int32),
        "lp": jnp.zeros((n_reqs + 1, N), jnp.float32),
        "mask": jnp.zeros((n_reqs + 1, N), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Host-driven engine loop
# ---------------------------------------------------------------------------

def serve(params, cfg: ModelConfig, prompts, rng, gcfg: GenServeConfig,
          gen_lens: Optional[Sequence[int]] = None
          ) -> Tuple[Dict[str, jnp.ndarray], Dict[str, object]]:
    """Generate for all `prompts` [B, P] with continuous batching.

    Returns (rollout dict — the exact `rl.rollout.generate` contract —
    and an engine-stats dict with the per-round wave timeline and
    occupancy trace).  `gen_lens` optionally caps each request's budget
    (used by benchmarks to impose output-length distributions)."""
    gcfg.validate()
    prompts_np = np.asarray(prompts, np.int32)
    B, P = prompts_np.shape
    N, W = gcfg.max_new_tokens, gcfg.wave
    K = min(gcfg.decode_chunk, N)

    limits = np.full((B,), N, np.int64) if gen_lens is None \
        else np.clip(np.asarray(gen_lens, np.int64), 1, N)
    queue = RequestQueue([Request(i, int(limits[i])) for i in range(B)],
                         policy=gcfg.admission)
    table = SlotTable(W)
    admit_fn, chunk_fn = _build_fns(cfg, gcfg, P, B,
                                    attn_mod.get_attention_impl())
    state = _init_state(cfg, gcfg, P, B)

    # rngs[t] drives the t-th sampling event, mirroring rollout.generate:
    # the first admission consumes rngs[0], decode step t consumes rngs[t].
    rngs = jax.random.split(rng, N)
    side = jax.random.fold_in(rng, 0x5EED)
    side_admit = jax.random.fold_in(side, 0)    # late-admission sampling
    side_step = jax.random.fold_in(side, 1)     # decode steps beyond rngs
    next_key = 0
    rounds: List[Tuple[float, float, float, int]] = []
    n_prefills = 0
    round_idx = 0
    occupied = np.zeros((W,), bool)      # device occupancy, host view
    while len(queue) or table.active:
        round_idx += 1
        assert round_idx <= 2 * B * (N + 1), "genserve loop did not converge"
        t0 = time.monotonic()
        admitted = 0
        may_live = False
        free = table.free_slots()
        if free and len(queue):
            reqs = queue.pop(len(free))
            slots = free[:len(reqs)]
            pb = np.broadcast_to(prompts_np[reqs[0].rid],
                                 (W, P)).copy()
            admit_mask = np.zeros((W,), bool)
            rows = np.full((W,), B, np.int32)
            lim = np.ones((W,), np.int32)
            for s, rq in zip(slots, reqs):
                pb[s] = prompts_np[rq.rid]
                admit_mask[s] = True
                rows[s] = rq.rid
                lim[s] = rq.max_new_tokens
            key = rngs[0] if next_key == 0 \
                else jax.random.fold_in(side_admit, round_idx)
            state = admit_fn(params, state, pb, admit_mask, rows, lim, key)
            table.admit(slots, reqs)
            next_key = max(next_key, 1)
            n_prefills += 1
            admitted = len(reqs)
            # host-side liveness bound — a synced read of `occupied`
            # here would serialize admission against the decode chunk;
            # this is conservative only for first-token EOS (one chunk
            # of bounded waste in that rare case)
            may_live = any(
                rq.max_new_tokens > 1
                and (gcfg.eos_token is None
                     or prompts_np[rq.rid, -1] != gcfg.eos_token)
                for rq in reqs)

        counts = ()
        if occupied.any() or may_live:
            # decode only when a slot can be occupied: requests that
            # finished at admission (budget 1, prompt-dead) never burn
            # wave steps
            keys = jnp.stack(
                [rngs[i] if i < N else jax.random.fold_in(side_step, i)
                 for i in range(next_key, next_key + K)])
            state, counts = chunk_fn(params, state, keys)
            next_key += K
            counts = np.asarray(counts)
            table.record_step(counts)
            occupied = np.asarray(state["occupied"])

        table.retire_finished(occupied)
        t1 = time.monotonic()
        occ = float(np.mean(counts)) if len(counts) else 0.0
        rounds.append((t0, t1, occ, admitted))

    gen = np.asarray(state["gen"])[:B]
    lp = np.asarray(state["lp"])[:B]
    mask = np.asarray(state["mask"])[:B]
    res = {"sequences": jnp.concatenate(
               [jnp.asarray(prompts_np), jnp.asarray(gen)], axis=1),
           "gen_tokens": jnp.asarray(gen),
           "logprobs": jnp.asarray(lp),
           "mask": jnp.asarray(mask)}
    stats = {"engine": "genserve", "wave": W,
             "decode_steps": table.decode_steps,
             "slot_steps": table.slot_steps,
             "mean_occupancy": table.mean_occupancy(),
             "occupancy_trace": list(table.occupancy_trace),
             "rounds": rounds, "prefills": n_prefills,
             "admitted": table.admitted, "retired": table.retired}
    return res, stats
