"""Host-side request queue + decode-slot table for continuous batching.

The scheduler owns no device state: it tracks which request occupies which
of the ``wave`` decode slots, admits queued requests into freed slots
(FIFO by default; shortest-job-first when per-request budgets are known
— ``policy="sjf"`` drains short requests early, which lowers mean
completion latency without changing which tokens each request produces),
and records the per-step occupancy trace that the cost-model parity
checks consume.  The decoder (``genserve.decoder``) drives it: one
``admit``/``install`` batch per host round when slots are free,
retirements after every decode chunk from the device's ``occupied``
vector (a slot mid-chunked-prefill counts as occupied until it lands).
Under chunked admission the table additionally records each mixed
sub-round's prefilling-slot count (``prefill_trace``), keeping both the
decode occupancy (``mean_occupancy`` — prefill-only sub-rounds are
explicit zero-decode entries, not missing ones) and the busy occupancy
(``busy_occupancy`` — decode or prefill work per round) honest against
``core.plan.predicted_occupancy``.

Paged prefix-cache admission (``decoder`` with ``prefix_cache=True``)
adds one more accounting stream: at each admission the decoder matches
the prompt against the radix tree (``genserve.pagepool.RadixCache``),
maps the shared pages into the slot's block table and starts the
prefill cursor *after* the cached prefix — the table records the
skipped tokens via ``record_prefix`` and reports the token
``prefix_hit_rate()`` alongside occupancy, so the benchmark and
``launch/serve.py`` can show how much prefill the cache elided.

Invariants (asserted):
  * a slot is FREE or holds exactly one in-flight request;
  * a request is admitted at most once (FIFO order from the queue);
  * every admitted request is eventually retired — the engine's outer
    loop terminates because each occupied slot emits at least one token
    per decode round and per-request budgets are finite.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.obs import metrics as obs_metrics

FREE = -1


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request (prompts are equal-length, padded upstream)."""

    rid: int                     # row in the caller's prompt/output arrays
    max_new_tokens: int          # per-request budget (<= engine-wide cap)


class RequestQueue:
    """Admission queue.  ``policy``:

    * ``"fifo"`` — arrival order (the default; matches the reference
      rollout's request numbering);
    * ``"sjf"``  — shortest-job-first by ``max_new_tokens``, arrival
      order breaking ties (stable), for when budgets are known upfront
      (the ROADMAP non-FIFO admission follow-on).

    ``aging`` (sjf only) bounds starvation: every ``pop`` a passed-over
    request accrues one skip, and once a request has been skipped
    ``aging`` times it jumps ahead of every shorter newcomer (starved
    requests drain in arrival order).  ``aging=0`` (default) disables
    the knob and reproduces the pure static shortest-first order.
    """

    def __init__(self, requests: Sequence[Request], policy: str = "fifo",
                 aging: int = 0):
        assert policy in ("fifo", "sjf"), policy
        assert aging >= 0
        self.policy = policy
        self.aging = aging
        # [arrival order, skip count, request] per pending request, kept
        # in the static policy order (sjf: shortest-first, stable)
        self._pending: List[List] = [[i, 0, r]
                                     for i, r in enumerate(requests)]
        if policy == "sjf":
            self._pending.sort(key=lambda e: (e[2].max_new_tokens, e[0]))

    def __len__(self) -> int:
        return len(self._pending)

    def pop(self, n: int) -> List[Request]:
        n = min(n, len(self._pending))
        if n == 0:
            return []
        if self.policy == "sjf" and self.aging > 0:
            # starved requests (skips >= aging) first, in arrival order;
            # the rest keep shortest-first order.  Selection is dynamic
            # so a request's skip count can promote it between pops.
            starved = sorted((e for e in self._pending
                              if e[1] >= self.aging), key=lambda e: e[0])
            rest = [e for e in self._pending if e[1] < self.aging]
            order = starved + rest
            take = order[:n]
            if len(order) > n:
                obs_metrics.counter("gen.sjf_skips").inc(len(order) - n)
            aged = sum(1 for e in take if e[1] >= self.aging)
            if aged:
                obs_metrics.counter("gen.sjf_aged_admissions").inc(aged)
            for e in order[n:]:
                e[1] += 1
            taken = {id(e) for e in take}
            self._pending = [e for e in self._pending
                             if id(e) not in taken]
        else:
            take, self._pending = self._pending[:n], self._pending[n:]
        return [e[2] for e in take]

    def push(self, request: Request) -> None:
        """Re-admit a request (slot-failure requeue).  It rejoins with a
        fresh arrival number — after everything currently pending under
        fifo, in budget order under sjf (stable re-sort)."""
        order = (self._pending[-1][0] + 1) if self._pending else 0
        self._pending.append([order, 0, request])
        if self.policy == "sjf":
            self._pending.sort(key=lambda e: (e[2].max_new_tokens, e[0]))

    def cancel(self, rid: int) -> bool:
        """Drop a pending request by id; True if it was queued."""
        before = len(self._pending)
        self._pending = [e for e in self._pending if e[2].rid != rid]
        return len(self._pending) != before


class SlotTable:
    """Tracks occupancy of the ``wave`` decode slots + engine statistics."""

    def __init__(self, wave: int):
        assert wave >= 1
        self.wave = wave
        self.slot_req: List[int] = [FREE] * wave
        self.admitted = 0
        self.retired = 0
        self.requeued = 0                      # slot-failure re-admissions
        self.cancelled = 0                     # explicit cancels
        self.occupancy_trace: List[int] = []   # active slots per decode step
        self.prefill_trace: List[int] = []     # prefilling slots per mixed
        #                                        round (chunked admission)
        self.prefix_hit_tokens = 0             # prompt tokens served from
        self.prompt_tokens = 0                 # the prefix cache / admitted

    # -- state ----------------------------------------------------------
    @property
    def active(self) -> int:
        return sum(1 for r in self.slot_req if r != FREE)

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r == FREE]

    # -- transitions ----------------------------------------------------
    def admit(self, slots: Sequence[int], requests: Sequence[Request]) -> None:
        assert len(slots) == len(requests)
        for s, req in zip(slots, requests):
            assert self.slot_req[s] == FREE, f"slot {s} already occupied"
            self.slot_req[s] = req.rid
            self.admitted += 1

    def fail_slot(self, s: int) -> int:
        """A decode slot died mid-flight: free it and return the request
        id that was in it (FREE if it was empty).  The caller owns page
        decref + requeue; this only fixes the table's books."""
        rid = self.slot_req[s]
        if rid != FREE:
            self.slot_req[s] = FREE
            self.requeued += 1
            obs_metrics.counter("gen.slot_failures").inc()
        return rid

    def cancel_slot(self, s: int) -> int:
        """Explicitly retire a slot's request without EOS/budget: free
        the slot, count the cancel, return the evicted request id."""
        rid = self.slot_req[s]
        if rid != FREE:
            self.slot_req[s] = FREE
            self.retired += 1
            self.cancelled += 1
            obs_metrics.counter("gen.cancelled").inc()
        return rid

    def retire_finished(self, occupied: np.ndarray) -> List[int]:
        """Reconcile with the device's occupied vector after a decode
        round; returns the request ids that finished this round."""
        done = []
        for s in range(self.wave):
            if self.slot_req[s] != FREE and not bool(occupied[s]):
                done.append(self.slot_req[s])
                self.slot_req[s] = FREE
                self.retired += 1
        return done

    # -- statistics -----------------------------------------------------
    def record_step(self, active_counts: Sequence[int]) -> None:
        """Pure decode rounds: one trace entry per wave decode step."""
        self.occupancy_trace.extend(int(c) for c in active_counts)

    def record_round(self, decode_counts: Sequence[int],
                     prefill_counts: Sequence[int]) -> None:
        """Mixed wave-step round (chunked admission): records each
        sub-round's decode count *and* prefill count explicitly, so a
        prefill-only sub-round shows up as zero decode progress instead
        of silently not existing — this keeps ``mean_occupancy`` vs the
        cost model's ``predicted_occupancy`` an honest comparison under
        mixed rounds (and ``busy_occupancy`` credits the prefill
        work)."""
        assert len(decode_counts) == len(prefill_counts)
        self.occupancy_trace.extend(int(c) for c in decode_counts)
        self.prefill_trace.extend(int(c) for c in prefill_counts)

    def record_prefix(self, hit_tokens: int, prompt_tokens: int) -> None:
        """One admission's prefix-cache outcome: ``hit_tokens`` of the
        ``prompt_tokens``-token prompt were found cached (prefill starts
        after them).  Zero hits are recorded too — the denominator must
        cover every admission or the rate flatters the cache."""
        assert 0 <= hit_tokens < max(prompt_tokens, 1)
        self.prefix_hit_tokens += int(hit_tokens)
        self.prompt_tokens += int(prompt_tokens)

    def prefix_hit_rate(self) -> float:
        """Fraction of admitted prompt tokens served from the prefix
        cache (0.0 when prefix caching is off or nothing was
        admitted)."""
        if self.prompt_tokens == 0:
            return 0.0
        return self.prefix_hit_tokens / self.prompt_tokens

    @property
    def decode_steps(self) -> int:
        """Device rounds with a decode half (every round under mixed
        wave-stepping — prefill-only rounds count, with zero decode)."""
        return len(self.occupancy_trace)

    @property
    def slot_steps(self) -> int:
        return int(sum(self.occupancy_trace))

    @property
    def prefill_rounds(self) -> int:
        return len(self.prefill_trace)

    @property
    def prefill_slot_steps(self) -> int:
        """Total per-slot prefill-chunk executions across mixed rounds."""
        return int(sum(self.prefill_trace))

    def mean_occupancy(self) -> float:
        if not self.occupancy_trace:
            return 0.0
        return self.slot_steps / self.decode_steps

    def busy_occupancy(self) -> float:
        """Mean slots doing *any* work (decode step or prefill chunk)
        per device round — the occupancy figure comparable against
        ``core.plan.predicted_occupancy(..., prefill_rounds=...)``,
        which prices admission instead of assuming it free."""
        if not self.occupancy_trace:
            return 0.0
        return (self.slot_steps + self.prefill_slot_steps) \
            / self.decode_steps
