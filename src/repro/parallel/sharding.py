"""Sharding rules + activation sharding hints.

Two pieces:

1. ``hint(x, name)`` — models call this on named intermediate activations
   (residual stream, mamba inner, moe buffer, logits...).  Outside
   ``use_hints`` it is the identity, so all models run unchanged on a
   single CPU device.  Inside ``use_hints(rules)`` each named activation
   gets a ``with_sharding_constraint`` — this is where the distribution
   schedule (and the §Perf iterations) plug in without touching model
   code.

2. ``param_tree_specs(params)`` — maps a parameter pytree to
   PartitionSpecs by parameter-name pattern (TP over ``model``, FSDP over
   ``data``); ``named_shardings`` turns them into per-mesh
   ``NamedSharding``s, sanitized so non-dividing axes replicate.

Plan → mesh → sharding flow (how the engine uses this module):

- the scheduler's ``Plan`` assigns each task (dp, pp, tp) and plan
  device ids; ``engine.placement.build_placements`` folds those onto the
  host's real devices (group-aware: disjoint plan groups get disjoint
  real device sets when the host is large enough) and builds one
  ``Mesh(("data", "model"))`` per task with ``model = gcd(tp, n)``.
- ``TaskPlacement.param_shardings`` runs ``param_tree_specs`` →
  ``named_shardings`` on that mesh; ``rl.trainer`` commits each task's
  state (params + optimizer moments) onto its owner's shardings with
  ``device_put`` and jits the step functions with explicit
  in/out_shardings (jit refuses mismatched committed arrays, so the
  device_put is load-bearing, not an optimization).
- batches are committed with ``TaskPlacement.shard_batch`` (leading dim
  over ``data``); step bodies trace inside ``use_hints(rules)`` under
  ``with mesh:`` so ``hint`` constraints resolve (a bare
  ``PartitionSpec`` constraint needs an ambient mesh at trace time).
- train → gen weight publication is an explicit ``device_put`` onto the
  gen placement's shardings (``rl.pipeline.sync_actor_weights``), priced
  by the cost model's sync term.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# Sharding-invariant PRNG: partitionable threefry makes random bits a
# pure function of (key, position) regardless of how an array is
# partitioned, so a sharded decode samples exactly the stream the
# single-device reference samples (cross-mesh token parity).  Set once
# at import — flipping it mid-process would fork the PRNG stream
# between already-initialized and later-initialized state.
jax.config.update("jax_threefry_partitionable", True)

_local = threading.local()


def _rules() -> Optional[Dict[str, P]]:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def use_hints(rules: Dict[str, P]):
    prev = _rules()
    _local.rules = rules
    try:
        yield
    finally:
        _local.rules = prev


def hint(x, name: str):
    rules = _rules()
    if rules is None or name not in rules:
        return x
    spec = rules[name]
    # trim spec to rank
    spec = P(*(list(spec) + [None] * x.ndim)[: x.ndim])
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Activation-sharding rule sets
# ---------------------------------------------------------------------------

def default_activation_rules(*, data_axes=("data",), model_axis="model",
                             seq_shard: bool = True) -> Dict[str, P]:
    """Baseline schedule: batch over data axes, TP over model axis,
    sequence-parallel residual stream (S over model) when seq_shard."""
    da = data_axes if len(data_axes) > 1 else data_axes[0]
    m = model_axis
    rules = {
        # [B, S, d]
        "residual": P(da, m, None) if seq_shard else P(da, None, None),
        # [B, S, H, hd] after qkv projection — heads over model
        "attn_q": P(da, None, m, None),
        "attn_kv": P(da, None, m, None),
        # [B, S, f]
        "ffn_hidden": P(da, None, m),
        # [B, S, di] mamba inner — channels over model (recurrence is
        # elementwise in di, so this shards the scan with zero collectives)
        "mamba_inner": P(da, None, m),
        # [B, S, H, hd, hd']-ish rwkv head state dims: heads over model
        "rwkv_heads": P(da, None, m, None),
        # [Gn, E, C, d] moe dispatch buffer — groups over data; experts
        # over model in EP mode
        "moe_buffer": P(da, None, None, None),
        "moe_buffer_ep": P(da, m, None, None),
        # expert weights at USE time (storage stays FSDP-sharded):
        #  - EP mode (E >= model axis): experts over model, ffn unsharded —
        #    the combine einsum contracts sharded-E => the TP all-reduce
        #    carries token-sized tensors, not the capacity buffer
        #  - TP mode (small E): ffn dim over model
        "moe_w_in_ep": P(m, None, None),   # [E, d, f]
        "moe_w_out_ep": P(m, None, None),  # [E, f, d]
        "moe_w_in": P(None, None, m),
        "moe_w_out": P(None, m, None),
        # [Gn, g, E, C] dispatch/combine one-hots in EP mode
        "moe_onehot_ep": P(da, None, m, None),
        # [Gn, E, C, f] expert hidden activations: 2D-sharded (GSPMD left
        # to itself gathers the group dim to apply the ffn sharding)
        "moe_hidden": P(da, None, None, m),
        "moe_hidden_ep": P(da, m, None, None),
        # [B, S, V]
        "logits": P(da, None, m),
        # decode: [B, 1, d]
        "decode_residual": P(da, None, None),
        # [B, S_cache, KV, hd]
        "kv_cache": P(da, None, m, None),
        # long-context decode: cache sequence-sharded over data (batch=1)
        "kv_cache_seqshard": P(None, da, m, None),
    }
    return rules


# ---------------------------------------------------------------------------
# Parameter partition specs
# ---------------------------------------------------------------------------

# pattern -> spec builder keyed by array rank; leading stacked "repeats"
# dimension (from scan-over-blocks) is added automatically.
_PARAM_RULES = [
    # embeddings / unembedding
    (r"embed/tokens$", lambda r: P("model", "data")),
    (r"embed/lm_head$", lambda r: P("data", "model")),
    (r"embed/feature_proj$", lambda r: P(None, "model")),
    # attention
    (r"wq$|wkv$", lambda r: P("data", "model")),
    (r"wo$", lambda r: P("model", "data")),
    # dense ffn
    (r"w_gate$|w_up$|w_key$", lambda r: P("data", "model")),
    (r"w_down$|w_value$", lambda r: P("model", "data")),
    (r"w_recept$", lambda r: P("data", "model")),
    # moe experts: expert dim over data (expert parallelism), TP inside
    (r"experts/w_gate$|experts/w_up$|experts/w_key$",
     lambda r: P("data", None, "model")),
    (r"experts/w_down$|experts/w_value$", lambda r: P("data", "model", None)),
    (r"router$", lambda r: P(None, None)),
    # mamba
    (r"in_proj$", lambda r: P("data", "model")),
    (r"out_proj$", lambda r: P("model", "data")),
    (r"x_proj$", lambda r: P("model", None)),
    (r"dt_proj$", lambda r: P(None, "model")),
    (r"conv_w$", lambda r: P(None, "model")),
    (r"conv_b$|dt_bias$|D$", lambda r: P("model")),
    (r"A_log$", lambda r: P("model", None)),
    # rwkv time mix
    (r"w_r$|w_k$|w_v$|w_g$", lambda r: P("data", "model")),
    (r"w_o$", lambda r: P("model", "data")),
    (r"decay_w1$", lambda r: P("model", None)),
    (r"decay_w2$", lambda r: P(None, "model")),
]


def path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        else:
            parts.append(str(p))
    return "/".join(parts)


def spec_for_param(path: str, arr, *, stacked: bool) -> P:
    """PartitionSpec for one parameter; `stacked` = leading scan-repeat dim."""
    rank = arr.ndim - (1 if stacked else 0)
    for pat, builder in _PARAM_RULES:
        if re.search(pat, path):
            spec = builder(rank)
            spec = P(*(list(spec) + [None] * rank)[:rank])
            break
    else:
        # default: replicate small params (norm scales, biases, mixes)
        spec = P(*([None] * rank))
    if stacked:
        spec = P(None, *spec)
    return spec


def param_tree_specs(params) -> "jax.tree_util.PyTreeDef":
    """PartitionSpec pytree mirroring the param tree. Leaves under
    'blocks/' carry a leading stacked repeat dim."""
    def fn(path, leaf):
        p = path_str(path)
        return spec_for_param(p, leaf, stacked=p.startswith("blocks/"))
    return jax.tree_util.tree_map_with_path(fn, params)


def sanitize_spec(spec: P, shape, mesh) -> P:
    """jit in_/out_shardings demand exact divisibility (GSPMD pads only
    internal constraints).  Drop axis assignments whose product does not
    divide the dimension; try axis subsets first for tuple entries."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for d, entry in enumerate(list(spec)[:len(shape)]):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        # greedily keep a prefix of axes that divides the dim
        kept = []
        prod = 1
        for a in axes:
            if shape[d] % (prod * sizes[a]) == 0:
                kept.append(a)
                prod *= sizes[a]
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


def named_shardings(mesh, spec_tree, shape_tree=None):
    from jax.sharding import NamedSharding
    if shape_tree is None:
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), spec_tree,
            is_leaf=lambda s: isinstance(s, P))
    return jax.tree_util.tree_map(
        lambda s, x: NamedSharding(mesh, sanitize_spec(s, x.shape, mesh)),
        spec_tree, shape_tree,
        is_leaf=lambda s: isinstance(s, P))
