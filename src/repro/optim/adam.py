"""Adam/AdamW + gradient clipping + LR schedules, from scratch (no optax
in this environment).  Mixed-precision aware: moments can be stored in a
reduced dtype for memory-constrained configs (see DESIGN.md jamba notes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"   # "bfloat16" to halve optimizer memory
    warmup_steps: int = 0
    total_steps: Optional[int] = None  # enables cosine decay
    min_lr_frac: float = 0.1


def init_adam_state(params, cfg: AdamConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=mdt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def schedule_lr(cfg: AdamConfig, step):
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.warmup_steps > 0:
        lr = lr * jnp.minimum((step + 1) / cfg.warmup_steps, 1.0)
    if cfg.total_steps:
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        lr = lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)
    return lr


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adam_update(params, grads, state, cfg: AdamConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    if cfg.grad_clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    else:
        gnorm = global_norm(grads)
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    outs = [upd(p, g, m, v)
            for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    unf = lambda i: jax.tree_util.tree_unflatten(
        treedef, [o[i] for o in outs])
    new_state = {"step": step, "m": unf(1), "v": unf(2)}
    return unf(0), new_state, {"lr": lr, "grad_norm": gnorm}
