"""Assigned input shapes and ShapeDtypeStruct input specs for the dry-run.

Shapes (assigned):
    train_4k     seq_len=4096    global_batch=256   (training)
    prefill_32k  seq_len=32768   global_batch=32    (inference-prefill)
    decode_32k   seq_len=32768   global_batch=128   (inference-decode)
    long_500k    seq_len=524288  global_batch=1     (long-context-decode)

Decode shapes lower ``serve_step`` (one new token + KV cache of seq_len).
``long_500k`` is run only for architectures with a sub-quadratic long mode
(see DESIGN.md §3); encoder-only architectures have no decode step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import cache as cache_mod
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    phase: str                      # "train" | "prefill" | "decode"
    long_mode: bool = False


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode", long_mode=True),
}

# number of stubbed modality-frontend positions for feature-input archs
N_PATCHES = 1024


def skip_reason(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """None if the (arch, shape) pair runs; otherwise a documented skip."""
    if shape.phase == "decode" and cfg.is_encoder_only:
        return "encoder-only architecture has no decode step"
    if shape.long_mode and not cfg.supports_long_context():
        return ("pure full-attention architecture: long_500k requires a "
                "sub-quadratic variant (none configured)")
    return None


def token_splits(cfg: ModelConfig, seq_len: int):
    """(n_feature_positions, n_token_positions) summing to seq_len."""
    if cfg.frontend != "features":
        return 0, seq_len
    if cfg.is_encoder_only:
        return seq_len, 0
    n_feat = min(N_PATCHES, seq_len // 2)
    return n_feat, seq_len - n_feat


def input_specs(cfg: ModelConfig, shape: InputShape,
                param_dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    n_feat, n_tok = token_splits(cfg, S)

    if shape.phase in ("train", "prefill"):
        specs = {}
        if n_feat:
            specs["features"] = sds((B, n_feat, cfg.feature_dim), param_dtype)
        if n_tok:
            specs["tokens"] = sds((B, n_tok), i32)
        if shape.phase == "train":
            specs["labels"] = sds((B, S), i32)
            specs["loss_mask"] = sds((B, S), param_dtype)
        return specs

    # decode: one token + cache of seq_len
    cache = jax.eval_shape(
        lambda: cache_mod.init_cache(cfg, B, S, long_mode=shape.long_mode,
                                     dtype=param_dtype))
    return {"tokens": sds((B, 1), i32), "cache": cache}
