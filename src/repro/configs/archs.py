"""The 10 assigned architectures (exact full configs + reduced smoke
variants).  Every entry cites its source in ``source``.
"""
from __future__ import annotations

from repro.models.config import FFN, LayerSpec, Mixer, ModelConfig, reduced

A = Mixer.ATTENTION
M = Mixer.MAMBA
R6 = Mixer.RWKV6


PHI3_MEDIUM_14B = ModelConfig(
    name="phi3-medium-14b",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
    d_ff=17920, vocab_size=100352,
    pattern=(LayerSpec(A, FFN.SWIGLU),),
    rope=True, rope_theta=10_000.0,
    source="arXiv:2404.14219 (Phi-3 technical report)",
)

GRANITE_MOE_3B = ModelConfig(
    name="granite-moe-3b-a800m",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    pattern=(LayerSpec(A, FFN.SWIGLU, moe=True),),
    n_experts=40, top_k=8,
    rope=True, rope_theta=10_000.0,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (scaled per assignment)",
)

MIXTRAL_8X7B = ModelConfig(
    name="mixtral-8x7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    pattern=(LayerSpec(A, FFN.SWIGLU, moe=True, window=4096),),
    n_experts=8, top_k=2,
    rope=True, rope_theta=1_000_000.0,
    source="arXiv:2401.04088 (Mixtral of Experts; SWA per Mistral-7B base)",
)

QWEN3_0_6B = ModelConfig(
    name="qwen3-0.6b",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=3072, vocab_size=151936,
    pattern=(LayerSpec(A, FFN.SWIGLU),),
    qk_norm=True, rope=True, rope_theta=1_000_000.0, tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B family (0.6B variant)",
)

NEMOTRON_4_15B = ModelConfig(
    name="nemotron-4-15b",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=256000,
    pattern=(LayerSpec(A, FFN.SQUARED_RELU),),
    rope=True, rope_theta=10_000.0,
    source="arXiv:2402.16819 (Nemotron-4 15B; squared-ReLU MLP)",
)

HUBERT_XLARGE = ModelConfig(
    name="hubert-xlarge",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504,
    pattern=(LayerSpec(A, FFN.GELU),),
    causal=False, rope=False,
    frontend="features", feature_dim=512,
    source="arXiv:2106.07447 (HuBERT X-Large; conv frontend stubbed per spec)",
)

# Jamba: attention every 8th layer (1:7 attn:mamba), MoE every other layer.
_JAMBA_PATTERN = tuple(
    LayerSpec(A if j == 0 else M, FFN.SWIGLU, moe=(j % 2 == 1))
    for j in range(8))
JAMBA_1_5_LARGE = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    pattern=_JAMBA_PATTERN,
    n_experts=16, top_k=2,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    rope=False,  # Jamba uses no positional encoding in attention layers
    source="arXiv:2403.19887 (Jamba; 1.5-Large scale per assignment)",
)

RWKV6_3B = ModelConfig(
    name="rwkv6-3b",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=8960, vocab_size=65536,
    pattern=(LayerSpec(R6, FFN.RWKV_CHANNEL),),
    rwkv_head_dim=64,
    rope=False,
    source="arXiv:2404.05892 (RWKV6 'Finch'; data-dependent decay)",
)

PIXTRAL_12B = ModelConfig(
    name="pixtral-12b",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    pattern=(LayerSpec(A, FFN.SWIGLU),),
    rope=True, rope_theta=1_000_000.0,
    frontend="features", feature_dim=1024,  # Pixtral-ViT patch embeds (stub)
    source="hf:mistralai/Pixtral-12B-2409 (mistral-nemo decoder + ViT stub)",
)

GEMMA2_27B = ModelConfig(
    name="gemma2-27b",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    pattern=(LayerSpec(A, FFN.GEGLU, window=4096),   # local
             LayerSpec(A, FFN.GEGLU, window=None)),  # global
    attn_softcap=50.0, final_softcap=30.0,
    rope=True, rope_theta=10_000.0, tie_embeddings=True,
    long_mode_window=32768,  # long-context variant: global layers -> 32k SWA
    source="arXiv:2408.00118 (Gemma 2; local/global alternating + softcap)",
)


ARCHS = {
    c.name: c for c in [
        PHI3_MEDIUM_14B, GRANITE_MOE_3B, MIXTRAL_8X7B, QWEN3_0_6B,
        NEMOTRON_4_15B, HUBERT_XLARGE, JAMBA_1_5_LARGE, RWKV6_3B,
        PIXTRAL_12B, GEMMA2_27B,
    ]
}

SMOKE = {name: reduced(cfg) for name, cfg in ARCHS.items()}


def get(name: str, smoke: bool = False) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return SMOKE[name] if smoke else ARCHS[name]
