"""Architecture configs (--arch <id>) and assigned input shapes."""
from repro.configs.archs import ARCHS, SMOKE, get  # noqa: F401
from repro.configs.shapes import SHAPES, input_specs, skip_reason  # noqa: F401
