"""RL trainers: synchronous PPO (6 tasks) and GRPO (4 tasks).

The iteration structure mirrors the paper's workflow graph exactly:
  actor_generation -> {reward, reference, critic} inference ->
  {actor, critic} training -> weight reshard/sync.

Execution is delegated to the plan-driven engine (``repro.engine``): the
scheduler's ``Plan`` decides which tasks colocate (serialize) and which
GPU groups run concurrently, the async one-step off-policy double buffer
lives in ``engine.pipeline``, and every iteration records a measured
``Event`` timeline comparable with ``core.simulator.simulate``.  The
trainer itself is a thin facade that owns the JAX state (params,
optimizers, jitted step functions) the task executors operate on; its
public API and metrics dict are unchanged from the pre-engine version.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import enumerate as enum_mod, topology, workflow
from repro.data.synthetic import AdditionTask, EOS
from repro.engine.executor import Engine
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.obs import trace as obs_trace
from repro.optim import adam
from repro.parallel import sharding as sh
from repro.rl import gae, losses, rewards as rewards_mod, rollout


@dataclasses.dataclass(frozen=True)
class RLConfig:
    algorithm: str = "grpo"          # "ppo" | "grpo"
    clip_eps: float = 0.2
    kl_beta: float = 0.02
    gamma: float = 1.0
    lam: float = 0.95
    value_coef: float = 0.5
    entropy_coef: float = 0.0
    n_rollouts: int = 4              # responses per prompt (GRPO group)
    max_new_tokens: int = 8
    temperature: float = 1.0
    # greedy decode: deterministic argmax sampling — what sharded-vs-
    # unsharded parity checks pin token-identical generation on
    greedy: bool = False
    whiten_advantages: bool = True
    lr: float = 1e-4
    # asynchronous (one-step off-policy) RL: generation for iteration t+1
    # overlaps training on iteration t's rollouts (§2.1); the PPO ratio
    # absorbs the one-step staleness of logp_old
    asynchronous: bool = False
    # GEN executor routing: "auto" takes the jitted single-wave path when
    # the rollout batch fits in one decode wave and the continuous-
    # batching engine (repro.genserve) beyond it; "rollout"/"genserve"
    # force a path
    gen_engine: str = "auto"
    decode_chunk: int = 1            # genserve decode steps per host round
    prefill_chunk: int = 0           # genserve chunked admission (tokens
    #                                  per mixed round; 0 = one-shot)
    # draft-model speculative decoding inside the wave step: k tokens
    # proposed by the draft per round, one batched (k+1)-wide target
    # verify.  0 = off.  ``draft_arch`` names a configs.archs entry for
    # the draft ("" = a scaled-down copy of the target); always routes
    # through the genserve engine.
    spec_k: int = 0
    draft_arch: str = ""


def default_plan(wf: workflow.RLWorkflow, n_devices: Optional[int] = None):
    """Colocate-all plan over the host devices (what a plan-less trainer
    executes): one task group, every task on every device."""
    n = n_devices or jax.device_count()
    topo = topology.build_host(n)
    grouping = (tuple(range(wf.n_tasks)),)
    return topo, enum_mod.build_plan(topo, wf, grouping, [n],
                                     list(range(n)))


class RLTrainer:
    def __init__(self, model_cfg: ModelConfig, rl_cfg: RLConfig,
                 task: AdditionTask, key, plan=None, topo=None, wf=None,
                 devices=None, overlap=None):
        self.cfg = model_cfg
        self.rl = rl_cfg
        self.task = task
        k_actor, k_critic, k_vh = jax.random.split(key, 3)
        self.actor = T.init_params(k_actor, model_cfg)
        self.ref = jax.tree_util.tree_map(jnp.copy, self.actor)
        self.actor_opt = adam.init_adam_state(
            self.actor, adam.AdamConfig(lr=rl_cfg.lr))
        self.gen_params = self.actor  # generation replica (synced weights)
        self.sync_bytes = 0
        self.weight_version = 0
        if rl_cfg.algorithm == "ppo":
            self.critic = T.init_params(k_critic, model_cfg)
            self.value_head = rewards_mod.init_value_head(k_vh, model_cfg)
            self.critic_opt = adam.init_adam_state(
                (self.critic, self.value_head),
                adam.AdamConfig(lr=rl_cfg.lr))
        self.sampler = rollout.SamplerConfig(
            max_new_tokens=rl_cfg.max_new_tokens,
            temperature=rl_cfg.temperature, eos_token=EOS,
            greedy=rl_cfg.greedy)
        self._shard_cache: Dict[Tuple, Callable] = {}
        self._jit()

        # plan-driven engine: the plan decides task colocation/concurrency
        # and the sync path; without one, execute the colocate-all default.
        # Pass the workflow the plan was searched with (its global_batch
        # scales the cost model) so measured-vs-predicted compares like
        # with like; otherwise a representative one is built here.
        if wf is not None and wf.algorithm != rl_cfg.algorithm:
            raise ValueError(f"workflow algorithm {wf.algorithm!r} != "
                             f"rl config algorithm {rl_cfg.algorithm!r}")
        self.wf = wf or workflow.make_workflow(
            rl_cfg.algorithm,
            workflow.LLMSpec.from_model_config(model_cfg),
            synchronous=not rl_cfg.asynchronous,
            n_rollouts=rl_cfg.n_rollouts,
            seq_in=getattr(task, "prompt_len", 16),
            seq_out=rl_cfg.max_new_tokens,
            global_batch=1)
        if plan is not None and \
                set(plan.parallel) != set(range(self.wf.n_tasks)):
            raise ValueError(
                f"plan covers tasks {sorted(plan.parallel)} but the "
                f"{rl_cfg.algorithm} workflow has {self.wf.n_tasks} tasks")
        if plan is None:
            host_topo, plan = default_plan(self.wf)
            topo = topo if topo is not None else host_topo
        # ``devices`` restricts the engine to a subset of the host's jax
        # devices (e.g. an unsharded single-device baseline next to a
        # sharded run); ``overlap`` forwards the gen/train wall-clock
        # overlap switch (None = auto on disjoint folded groups).
        self.engine = Engine(self.wf, plan, self, topo=topo,
                             asynchronous=rl_cfg.asynchronous,
                             devices=devices, overlap=overlap)

    @property
    def plan(self):
        """The engine's *live* plan — after an elastic swap
        (``engine.apply_plan``) this tracks the new plan epoch."""
        return self.engine.plan

    # -- checkpointable state (§6: what must survive a plan swap) -------
    def state_tree(self) -> Dict[str, object]:
        """The full live training state as one pytree for
        ``checkpoint.io.save``/``restore``: parameters, optimizer state,
        the generation replica and the weight-sync version counter.
        Execution state (plan, placements, timeline) is deliberately
        excluded — it is rebuilt from the plan on restore."""
        tree: Dict[str, object] = {
            "actor": self.actor, "ref": self.ref,
            "actor_opt": self.actor_opt, "gen_params": self.gen_params,
            "weight_version": jnp.asarray(self.weight_version, jnp.int32),
        }
        if self.rl.algorithm == "ppo":
            tree["critic"] = self.critic
            tree["value_head"] = self.value_head
            tree["critic_opt"] = self.critic_opt
        return tree

    def load_state_tree(self, tree: Dict[str, object]) -> None:
        self.actor = tree["actor"]
        self.ref = tree["ref"]
        self.actor_opt = tree["actor_opt"]
        self.gen_params = tree["gen_params"]
        self.weight_version = int(tree["weight_version"])
        if self.rl.algorithm == "ppo":
            self.critic = tree["critic"]
            self.value_head = tree["value_head"]
            self.critic_opt = tree["critic_opt"]

    # ------------------------------------------------------------------
    def _jit(self):
        cfg, rl = self.cfg, self.rl

        self._generate_raw = functools.partial(
            rollout.generate, cfg=cfg, sampler=self.sampler)
        self._generate = jax.jit(self._generate_raw, static_argnames=())

        def ref_logp(params, sequences, gen_start):
            lp, _ = rollout.sequence_logprobs(params, cfg, sequences,
                                              gen_start)
            return lp
        self._ref_logp_raw = ref_logp
        self._ref_logp = jax.jit(ref_logp, static_argnames=("gen_start",))

        def actor_loss(params, batch, gen_start):
            lp_new, out = rollout.sequence_logprobs(
                params, cfg, batch["sequences"], gen_start)
            pl = losses.ppo_policy_loss(
                lp_new, batch["logp_old"], batch["advantages"],
                batch["mask"], clip_eps=rl.clip_eps)
            loss = pl["loss"] + out["aux_loss"]
            if rl.entropy_coef:
                logits = out["logits"][:, gen_start - 1:-1]
                loss = loss - rl.entropy_coef * losses.entropy_bonus(
                    logits, batch["mask"])
            return loss, pl

        def actor_step(params, opt_state, batch, gen_start):
            (loss, pl), grads = jax.value_and_grad(
                actor_loss, has_aux=True)(params, batch, gen_start)
            new_params, new_opt, om = adam.adam_update(
                params, grads, opt_state, adam.AdamConfig(lr=rl.lr))
            return new_params, new_opt, {**pl, "loss": loss, **om}
        self._actor_step_raw = actor_step
        self._actor_step = jax.jit(actor_step,
                                   static_argnames=("gen_start",))

        if rl.algorithm == "ppo":
            def critic_vals(critic, head, sequences, gen_start):
                return rewards_mod.critic_values(critic, head, cfg,
                                                 sequences, gen_start)
            self._critic_vals_raw = critic_vals
            self._critic_vals = jax.jit(critic_vals,
                                        static_argnames=("gen_start",))

            def critic_loss(cp, batch, gen_start):
                critic, head = cp
                v = rewards_mod.critic_values(critic, head, cfg,
                                              batch["sequences"], gen_start)
                return rl.value_coef * losses.value_loss(
                    v, batch["values_old"], batch["returns"], batch["mask"],
                    clip_eps=rl.clip_eps)

            def critic_step(cp, opt_state, batch, gen_start):
                loss, grads = jax.value_and_grad(critic_loss)(
                    cp, batch, gen_start)
                new_cp, new_opt, _ = adam.adam_update(
                    cp, grads, opt_state, adam.AdamConfig(lr=rl.lr))
                return new_cp, new_opt, loss
            self._critic_step_raw = critic_step
            self._critic_step = jax.jit(critic_step,
                                        static_argnames=("gen_start",))

    # -- sharded execution (plan → mesh → shardings) --------------------
    #
    # When a task's placement spans more than one real device, its state
    # is committed onto the placement mesh (``install_placements``) and
    # the step runs through a per-mesh jit with explicit in/out
    # shardings + ``use_hints`` activation rules.  jit refuses committed
    # arrays whose sharding mismatches ``in_shardings``, so callers go
    # through ``TaskPlacement.shard_batch`` for inputs; single-device
    # placements keep the original jitted paths untouched.

    def _opt_shardings(self, placement, pshard):
        return {"step": placement.replicated_sharding(),
                "m": pshard, "v": pshard}

    def _sharded(self, name: str, placement, build: Callable) -> Callable:
        key = (name, placement.mesh)
        fn = self._shard_cache.get(key)
        if fn is None:
            fn = self._shard_cache[key] = build()
        return fn

    def install_placements(self, placements, wf) -> None:
        """Commit each task's state onto its owning placement.

        Called by the engine whenever placements (re)build — initial
        construction and every elastic plan swap.  Ownership: actor +
        optimizer → actor_training; reference → reference_inference;
        generation replica → actor_generation; critic state →
        critic_training.  Single-host single-device runs are left
        untouched (everything already lives on the only device)."""
        self._shard_cache.clear()
        multi = len({id(d) for pl in placements.values()
                     for d in pl.local_devices}) > 1
        by_name = {wf.tasks[t].name: pl for t, pl in placements.items()}

        tp = by_name.get("actor_training")
        if tp is not None and (tp.sharded or multi):
            ps = tp.param_shardings(self.actor)
            self.actor = jax.device_put(self.actor, ps)
            self.actor_opt = jax.device_put(
                self.actor_opt, self._opt_shardings(tp, ps))
        rp = by_name.get("reference_inference")
        if rp is not None and (rp.sharded or multi):
            self.ref = jax.device_put(self.ref,
                                      rp.param_shardings(self.ref))
        gp = by_name.get("actor_generation")
        if gp is not None and (gp.sharded or multi):
            self.gen_params = jax.device_put(
                self.gen_params, gp.param_shardings(self.gen_params))
        if self.rl.algorithm == "ppo":
            cp = by_name.get("critic_training") \
                or by_name.get("critic_inference")
            if cp is not None and (cp.sharded or multi):
                cps = (cp.param_shardings(self.critic),
                       cp.param_shardings(self.value_head))
                self.critic, self.value_head = jax.device_put(
                    (self.critic, self.value_head), cps)
                self.critic_opt = jax.device_put(
                    self.critic_opt, self._opt_shardings(cp, cps))

    def sharded_actor_step(self, placement, batch) -> Callable:
        """(params, opt, batch, gen_start) jitted on the placement mesh;
        gen_start positional-static (jit forbids kwargs with
        in_shardings)."""
        def build():
            rules = placement.activation_rules()
            ps = placement.param_shardings(self.actor)
            repl = placement.replicated_sharding()
            os_ = self._opt_shardings(placement, ps)
            bs = placement.batch_shardings(batch)
            raw = self._actor_step_raw

            def step(params, opt_state, batch, gen_start):
                with sh.use_hints(rules):
                    return raw(params, opt_state, batch, gen_start)
            return jax.jit(step, static_argnums=(3,),
                           in_shardings=(ps, os_, bs),
                           out_shardings=(ps, os_, repl))
        return self._sharded("actor_step", placement, build)

    def sharded_ref_logp(self, placement, sequences) -> Callable:
        def build():
            rules = placement.activation_rules()
            ps = placement.param_shardings(self.ref)
            repl = placement.replicated_sharding()
            bs = placement.batch_shardings(sequences)
            raw = self._ref_logp_raw

            def step(params, sequences, gen_start):
                with sh.use_hints(rules):
                    return raw(params, sequences, gen_start)
            return jax.jit(step, static_argnums=(2,),
                           in_shardings=(ps, bs), out_shardings=repl)
        return self._sharded("ref_logp", placement, build)

    def sharded_generate(self, placement, prompts) -> Callable:
        """(params, prompts, rng) single-wave decode on the gen mesh."""
        def build():
            rules = placement.activation_rules()
            ps = placement.param_shardings(self.gen_params)
            repl = placement.replicated_sharding()
            bs = placement.batch_shardings(prompts)
            raw = self._generate_raw

            def step(params, prompts, rng):
                with sh.use_hints(rules):
                    return raw(params, prompts=prompts, rng=rng)
            return jax.jit(step, in_shardings=(ps, bs, repl),
                           out_shardings=repl)
        return self._sharded("generate", placement, build)

    def sharded_critic_vals(self, placement, sequences) -> Callable:
        def build():
            rules = placement.activation_rules()
            cps = (placement.param_shardings(self.critic),
                   placement.param_shardings(self.value_head))
            repl = placement.replicated_sharding()
            bs = placement.batch_shardings(sequences)
            raw = self._critic_vals_raw

            def step(critic, head, sequences, gen_start):
                with sh.use_hints(rules):
                    return raw(critic, head, sequences, gen_start)
            return jax.jit(step, static_argnums=(3,),
                           in_shardings=(*cps, bs), out_shardings=repl)
        return self._sharded("critic_vals", placement, build)

    def sharded_critic_step(self, placement, batch) -> Callable:
        def build():
            rules = placement.activation_rules()
            cps = (placement.param_shardings(self.critic),
                   placement.param_shardings(self.value_head))
            repl = placement.replicated_sharding()
            os_ = self._opt_shardings(placement, cps)
            bs = placement.batch_shardings(batch)
            raw = self._critic_step_raw

            def step(cp, opt_state, batch, gen_start):
                with sh.use_hints(rules):
                    return raw(cp, opt_state, batch, gen_start)
            return jax.jit(step, static_argnums=(3,),
                           in_shardings=(cps, os_, bs),
                           out_shardings=(cps, os_, repl))
        return self._sharded("critic_step", placement, build)

    # -- engine hooks ---------------------------------------------------
    def prepare_inputs(self, prompts: np.ndarray, answers: np.ndarray,
                       rng) -> Dict[str, object]:
        G = self.rl.n_rollouts
        return {"prompts_rep": jnp.asarray(np.repeat(prompts, G, axis=0)),
                "answers_rep": np.repeat(answers, G, axis=0),
                "gen_start": prompts.shape[1], "rng": rng}

    def before_stage(self, stage_tasks, bb) -> None:
        """Materialize the shared KL/advantage batch before the training
        stage dispatches, so neither training lane's measured duration
        absorbs the cross-task prep (or the other lane's lock wait)."""
        from repro.core.workflow import TaskKind
        from repro.engine.tasks import ensure_train_batch
        if bb.get("bundle") is not None and \
                any(t.kind == TaskKind.TRAIN for t in stage_tasks):
            ensure_train_batch(self, bb)

    def fill_metrics(self) -> Dict[str, float]:
        return {"reward_mean": 0.0, "kl": 0.0, "gen_len": 0.0,
                "loss": 0.0, "pipeline_fill": 1.0, "sync_gb": 0.0}

    # ------------------------------------------------------------------
    def iteration(self, prompts: np.ndarray, answers: np.ndarray,
                  rng) -> Dict[str, float]:
        """One RL iteration over a prompt batch, executed by the engine.

        Synchronous: generate -> infer -> train -> sync (iteration-level
        barrier).  Asynchronous: generate with the PREVIOUS sync's weights
        while training on the PREVIOUS iteration's rollouts (one-step
        off-policy); the first call only produces rollouts."""
        with obs_trace.span("train.step", batch=int(prompts.shape[0])):
            res = self.engine.run_iteration(prompts, answers, rng)
        return res.metrics

    # ------------------------------------------------------------------
    def evaluate(self, prompts: np.ndarray, answers: np.ndarray,
                 rng) -> float:
        sampler = dataclasses.replace(self.sampler, greedy=True)
        ro = rollout.generate(self.gen_params, self.cfg,
                              jnp.asarray(prompts), rng, sampler)
        gen_np = np.asarray(ro["gen_tokens"])
        exact = [self.task.reward(a, g) >= 1.0
                 for a, g in zip(answers, gen_np)]
        return float(np.mean(exact))
