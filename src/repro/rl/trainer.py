"""RL trainers: synchronous PPO (6 tasks) and GRPO (4 tasks).

The iteration structure mirrors the paper's workflow graph exactly:
  actor_generation -> {reward, reference, critic} inference ->
  {actor, critic} training -> weight reshard/sync.
On a single host the tasks execute sequentially; the execution plan from
the scheduler (when provided) annotates which devices/submeshes each task
would occupy, and the weight-sync step goes through rl.sync so the
transfer volume is accounted.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import AdditionTask, EOS
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adam
from repro.rl import gae, losses, rewards as rewards_mod, rollout
from repro.rl.sync import sync_weights


@dataclasses.dataclass(frozen=True)
class RLConfig:
    algorithm: str = "grpo"          # "ppo" | "grpo"
    clip_eps: float = 0.2
    kl_beta: float = 0.02
    gamma: float = 1.0
    lam: float = 0.95
    value_coef: float = 0.5
    entropy_coef: float = 0.0
    n_rollouts: int = 4              # responses per prompt (GRPO group)
    max_new_tokens: int = 8
    temperature: float = 1.0
    whiten_advantages: bool = True
    lr: float = 1e-4
    # asynchronous (one-step off-policy) RL: generation for iteration t+1
    # overlaps training on iteration t's rollouts (§2.1); the PPO ratio
    # absorbs the one-step staleness of logp_old
    asynchronous: bool = False


class RLTrainer:
    def __init__(self, model_cfg: ModelConfig, rl_cfg: RLConfig,
                 task: AdditionTask, key, plan=None):
        self.cfg = model_cfg
        self.rl = rl_cfg
        self.task = task
        self.plan = plan
        k_actor, k_critic, k_vh = jax.random.split(key, 3)
        self.actor = T.init_params(k_actor, model_cfg)
        self.ref = jax.tree_util.tree_map(jnp.copy, self.actor)
        self.actor_opt = adam.init_adam_state(
            self.actor, adam.AdamConfig(lr=rl_cfg.lr))
        self.gen_params = self.actor  # generation replica (synced weights)
        self.sync_bytes = 0
        if rl_cfg.algorithm == "ppo":
            self.critic = T.init_params(k_critic, model_cfg)
            self.value_head = rewards_mod.init_value_head(k_vh, model_cfg)
            self.critic_opt = adam.init_adam_state(
                (self.critic, self.value_head),
                adam.AdamConfig(lr=rl_cfg.lr))
        self.sampler = rollout.SamplerConfig(
            max_new_tokens=rl_cfg.max_new_tokens,
            temperature=rl_cfg.temperature, eos_token=EOS)
        self._jit()

    # ------------------------------------------------------------------
    def _jit(self):
        cfg, rl = self.cfg, self.rl

        self._generate = jax.jit(functools.partial(
            rollout.generate, cfg=cfg, sampler=self.sampler),
            static_argnames=())

        def ref_logp(params, sequences, gen_start):
            lp, _ = rollout.sequence_logprobs(params, cfg, sequences,
                                              gen_start)
            return lp
        self._ref_logp = jax.jit(ref_logp, static_argnames=("gen_start",))

        def actor_loss(params, batch, gen_start):
            lp_new, out = rollout.sequence_logprobs(
                params, cfg, batch["sequences"], gen_start)
            pl = losses.ppo_policy_loss(
                lp_new, batch["logp_old"], batch["advantages"],
                batch["mask"], clip_eps=rl.clip_eps)
            loss = pl["loss"] + out["aux_loss"]
            if rl.entropy_coef:
                logits = out["logits"][:, gen_start - 1:-1]
                loss = loss - rl.entropy_coef * losses.entropy_bonus(
                    logits, batch["mask"])
            return loss, pl

        def actor_step(params, opt_state, batch, gen_start):
            (loss, pl), grads = jax.value_and_grad(
                actor_loss, has_aux=True)(params, batch, gen_start)
            new_params, new_opt, om = adam.adam_update(
                params, grads, opt_state, adam.AdamConfig(lr=rl.lr))
            return new_params, new_opt, {**pl, "loss": loss, **om}
        self._actor_step = jax.jit(actor_step,
                                   static_argnames=("gen_start",))

        if rl.algorithm == "ppo":
            def critic_vals(critic, head, sequences, gen_start):
                return rewards_mod.critic_values(critic, head, cfg,
                                                 sequences, gen_start)
            self._critic_vals = jax.jit(critic_vals,
                                        static_argnames=("gen_start",))

            def critic_loss(cp, batch, gen_start):
                critic, head = cp
                v = rewards_mod.critic_values(critic, head, cfg,
                                              batch["sequences"], gen_start)
                return rl.value_coef * losses.value_loss(
                    v, batch["values_old"], batch["returns"], batch["mask"],
                    clip_eps=rl.clip_eps)

            def critic_step(cp, opt_state, batch, gen_start):
                loss, grads = jax.value_and_grad(critic_loss)(
                    cp, batch, gen_start)
                new_cp, new_opt, _ = adam.adam_update(
                    cp, grads, opt_state, adam.AdamConfig(lr=rl.lr))
                return new_cp, new_opt, loss
            self._critic_step = jax.jit(critic_step,
                                        static_argnames=("gen_start",))

    # ------------------------------------------------------------------
    def iteration(self, prompts: np.ndarray, answers: np.ndarray,
                  rng) -> Dict[str, float]:
        """One RL iteration over a prompt batch.

        Synchronous: generate -> infer -> train -> sync (iteration-level
        barrier).  Asynchronous: generate with the PREVIOUS sync's weights
        while training on the PREVIOUS iteration's rollouts (one-step
        off-policy); the first call only produces rollouts."""
        rl = self.rl
        G = rl.n_rollouts
        prompts_rep = np.repeat(prompts, G, axis=0)
        answers_rep = np.repeat(answers, G, axis=0)
        P = prompts.shape[1]

        # --- task 1: actor generation (on the generation replica) ---
        ro = self._generate(self.gen_params,
                            prompts=jnp.asarray(prompts_rep), rng=rng)
        if rl.asynchronous:
            pending = getattr(self, "_pending", None)
            self._pending = (ro, answers_rep, P)
            if pending is None:
                # pipeline fill: nothing to train on yet
                return {"reward_mean": 0.0, "kl": 0.0, "gen_len": 0.0,
                        "loss": 0.0, "pipeline_fill": 1.0, "sync_gb": 0.0}
            ro, answers_rep, P = pending
        sequences = ro["sequences"]
        mask = ro["mask"]

        # --- task 2: reward inference (programmatic verifier) ---
        gen_np = np.asarray(ro["gen_tokens"])
        scores = self.task.reward_batch(answers_rep, gen_np)

        # --- task 3: reference inference ---
        lp_ref = self._ref_logp(self.ref, sequences, gen_start=P)

        # --- KL-penalised token rewards ---
        tok_rewards, kl = losses.kl_penalised_rewards(
            jnp.asarray(scores), ro["logprobs"], lp_ref, mask,
            kl_beta=rl.kl_beta)

        metrics: Dict[str, float] = {
            "reward_mean": float(scores.mean()),
            "kl": float(kl),
            "gen_len": float(np.asarray(mask).sum(1).mean()),
        }

        # --- advantages ---
        if rl.algorithm == "ppo":
            # task 4: critic inference
            values = self._critic_vals(self.critic, self.value_head,
                                       sequences, gen_start=P)
            adv, returns = gae.gae_advantages(
                tok_rewards, values * mask, mask,
                gamma=rl.gamma, lam=rl.lam)
        else:
            seq_reward = np.asarray(tok_rewards).sum(1)
            adv = gae.grpo_advantages(jnp.asarray(seq_reward), G, mask)
            returns = values = None
        if rl.whiten_advantages:
            adv = gae.whiten(adv, mask)

        batch = {"sequences": sequences, "logp_old": ro["logprobs"],
                 "advantages": adv, "mask": mask}

        # --- task 5: actor training ---
        self.actor, self.actor_opt, am = self._actor_step(
            self.actor, self.actor_opt, batch, gen_start=P)
        metrics.update({k: float(v) for k, v in am.items()})

        # --- task 6: critic training (PPO only) ---
        if rl.algorithm == "ppo":
            cbatch = dict(batch, values_old=values * mask, returns=returns)
            (self.critic, self.value_head), self.critic_opt, closs = \
                self._critic_step((self.critic, self.value_head),
                                  self.critic_opt, cbatch, gen_start=P)
            metrics["critic_loss"] = float(closs)

        # --- weight reshard/sync: training replica -> generation replica ---
        self.gen_params, nbytes = sync_weights(self.actor)
        self.sync_bytes += nbytes
        metrics["sync_gb"] = nbytes / 1e9
        return metrics

    # ------------------------------------------------------------------
    def evaluate(self, prompts: np.ndarray, answers: np.ndarray,
                 rng) -> float:
        sampler = dataclasses.replace(self.sampler, greedy=True)
        ro = rollout.generate(self.gen_params, self.cfg,
                              jnp.asarray(prompts), rng, sampler)
        gen_np = np.asarray(ro["gen_tokens"])
        exact = [self.task.reward(a, g) >= 1.0
                 for a, g in zip(answers, gen_np)]
        return float(np.mean(exact))
