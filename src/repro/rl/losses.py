"""PPO / GRPO objectives (paper §3.3 PPO formulation)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def ppo_policy_loss(logp_new, logp_old, advantages, mask, *,
                    clip_eps: float = 0.2) -> Dict[str, jnp.ndarray]:
    """Clipped surrogate. All inputs [B, T] (per generated token)."""
    ratio = jnp.exp(logp_new - logp_old)
    unclipped = ratio * advantages
    clipped = jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * advantages
    per_tok = -jnp.minimum(unclipped, clipped)
    n = jnp.maximum(mask.sum(), 1.0)
    loss = (per_tok * mask).sum() / n
    clip_frac = ((jnp.abs(ratio - 1) > clip_eps) * mask).sum() / n
    approx_kl = ((logp_old - logp_new) * mask).sum() / n
    return {"loss": loss, "clip_frac": clip_frac, "approx_kl": approx_kl,
            "ratio_mean": (ratio * mask).sum() / n}


def value_loss(values_new, values_old, returns, mask, *,
               clip_eps: float = 0.2):
    """Clipped value loss (PPO2 style). [B, T]."""
    v_clip = values_old + jnp.clip(values_new - values_old,
                                   -clip_eps, clip_eps)
    l1 = jnp.square(values_new - returns)
    l2 = jnp.square(v_clip - returns)
    n = jnp.maximum(mask.sum(), 1.0)
    return 0.5 * (jnp.maximum(l1, l2) * mask).sum() / n


def entropy_bonus(logits, mask):
    """Mean token entropy over valid positions. logits [B, T, V]."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ent = -(jnp.exp(logp) * logp).sum(-1)
    n = jnp.maximum(mask.sum(), 1.0)
    return (ent * mask).sum() / n


def kl_penalised_rewards(score, logp_actor, logp_ref, mask, *,
                         kl_beta: float = 0.02):
    """Token-level rewards: sequence score at the last valid token minus
    per-token KL penalty (paper's r_tau formulation)."""
    kl = logp_actor - logp_ref
    rewards = -kl_beta * kl * mask
    # add the scalar score at each sequence's final valid position
    last = jnp.maximum(mask.sum(axis=1).astype(jnp.int32) - 1, 0)
    rewards = rewards.at[jnp.arange(rewards.shape[0]), last].add(score)
    return rewards, (kl * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def gather_logprobs(logits, tokens):
    """log p(tokens) under logits. logits [B,T,V], tokens [B,T]."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(logp, tokens[..., None], axis=-1)[..., 0]
