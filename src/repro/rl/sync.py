"""Weight resharding / synchronization between training and generation
replicas (the C_reshard / C_sync terms of the cost model).

On the single-host runtime this is a device_put (identity layout); under a
mesh the target sharding comes from the generation task's plan.  Transfer
volume is returned so the driver can account the synchronization cost."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def sync_weights(train_params, target_shardings=None) -> Tuple[object, int]:
    """Returns (generation_params, bytes_transferred)."""
    nbytes = tree_bytes(train_params)
    if target_shardings is None:
        gen = train_params  # same devices: zero-copy handoff
    else:
        gen = jax.device_put(train_params, target_shardings)
    return gen, nbytes
