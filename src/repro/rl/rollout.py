"""Actor generation: autoregressive sampling with KV cache.

Prefill the prompt once (forward with return_cache), then lax.scan over
decode steps.  Returns sequences, per-token logprobs and the validity mask
(positions after EOS are masked out).

This is the *single-wave* reference path: every sequence decodes all
``max_new_tokens`` steps, finished-or-not (dead rows are masked, not
retired).  The continuous-batching engine (``repro.genserve``) retires
finished slots and back-fills them from a request queue; when the batch
fits in one decode wave the two paths produce identical masked outputs
under the same rng (genserve's equivalence tests pin this).  EOS and mask
semantics are shared via ``models.sampling``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import sampling
from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    max_new_tokens: int = 16
    temperature: float = 1.0
    eos_token: Optional[int] = None
    greedy: bool = False


def _sample(rng, logits, cfg: SamplerConfig):
    return sampling.sample_with_logprobs(
        rng, logits, temperature=cfg.temperature, greedy=cfg.greedy)


def generate(params, cfg: ModelConfig, prompts, rng,
             sampler: SamplerConfig) -> Dict[str, jnp.ndarray]:
    """prompts: [B, P] int32.  Returns dict with
    sequences [B, P+N], gen_tokens [B, N], logprobs [B, N], mask [B, N]."""
    B, P = prompts.shape
    N = sampler.max_new_tokens
    out = T.forward(params, cfg, {"tokens": prompts}, return_cache=True,
                    max_cache_len=P + N, remat=False)
    cache = out["cache"]
    logits0 = out["logits"][:, -1]

    rngs = jax.random.split(rng, N)
    tok0, lp0 = _sample(rngs[0], logits0, sampler)

    def step(carry, rng_t):
        cache, tok, alive = carry
        logits, cache = T.decode_step(params, cfg, tok[:, None], cache)
        nxt, lp = _sample(rng_t, logits, sampler)
        alive_next = sampling.next_alive(alive, tok, sampler.eos_token)
        return (cache, nxt, alive_next), (nxt, lp, alive_next)

    # a prompt that already ends with EOS starts dead: its first sampled
    # token is recorded but invalid (shared edge semantics with genserve)
    alive0 = sampling.initial_alive(prompts, sampler.eos_token)
    (_, _, _), (toks, lps, alives) = jax.lax.scan(
        step, (cache, tok0, alive0), rngs[1:])
    gen = jnp.concatenate([tok0[:, None], toks.T], axis=1)       # [B, N]
    logprobs = jnp.concatenate([lp0[:, None], lps.T], axis=1)
    mask = jnp.concatenate([alive0[:, None], alives.T], axis=1)
    sequences = jnp.concatenate([prompts, gen], axis=1)
    return {"sequences": sequences, "gen_tokens": gen,
            "logprobs": logprobs, "mask": mask.astype(jnp.float32)}


def sequence_logprobs(params, cfg: ModelConfig, sequences, gen_start: int):
    """Teacher-forced logprobs of the generated part. sequences [B, S].

    Returns logprobs [B, S - gen_start] for tokens at positions
    gen_start..S-1 (each predicted from the previous position)."""
    out = T.forward(params, cfg, {"tokens": sequences}, remat=False)
    logits = out["logits"][:, gen_start - 1:-1]
    targets = sequences[:, gen_start:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0], out
