"""Advantage estimation: GAE (PPO, Schulman et al. 2016) and
group-relative advantages (GRPO, Shao et al. 2024).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gae_advantages(rewards, values, mask, *, gamma: float = 1.0,
                   lam: float = 0.95):
    """rewards, values, mask: [B, T] (mask 0 after EOS).

    values[:, t] = V(s_t); bootstrap value after the last step is 0.
    Returns (advantages [B, T], returns [B, T])."""
    B, T = rewards.shape
    values_next = jnp.concatenate(
        [values[:, 1:], jnp.zeros((B, 1), values.dtype)], axis=1)
    deltas = rewards + gamma * values_next * mask - values

    def step(carry, xs):
        delta_t, mask_t = xs
        carry = delta_t + gamma * lam * mask_t * carry
        return carry, carry

    _, adv_rev = jax.lax.scan(
        step, jnp.zeros((B,), rewards.dtype),
        (deltas.T[::-1], mask.T[::-1]))
    adv = adv_rev[::-1].T * mask
    returns = adv + values * mask
    return adv, returns


def grpo_advantages(rewards, group_size: int, mask):
    """rewards: [B] sequence-level; groups of `group_size` share a prompt.

    A_i = (r_i - mean_group) / (std_group + eps), broadcast over tokens."""
    B = rewards.shape[0]
    g = rewards.reshape(B // group_size, group_size)
    mean = g.mean(axis=1, keepdims=True)
    std = g.std(axis=1, keepdims=True)
    adv = ((g - mean) / (std + 1e-4)).reshape(B)
    return adv[:, None] * mask


def whiten(adv, mask):
    """Normalize advantages over valid tokens (standard PPO trick)."""
    n = jnp.maximum(mask.sum(), 1.0)
    mean = (adv * mask).sum() / n
    var = (jnp.square(adv - mean) * mask).sum() / n
    return (adv - mean) * jax.lax.rsqrt(var + 1e-8) * mask
