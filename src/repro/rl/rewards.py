"""Reward computation: programmatic verifier (the runnable example's path)
and a learned reward-model head (separate LLM + scalar head, frozen during
RL, as in the paper's PPO workflow)."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.layers import dense_init


def init_reward_head(key, cfg: ModelConfig, dtype=jnp.float32):
    return {"w": dense_init(key, (cfg.d_model, 1), dtype=dtype)}


def reward_model_scores(params, head, cfg: ModelConfig, sequences, mask_last):
    """Scalar score per sequence = head(last hidden state).

    sequences: [B, S]; mask_last: [B] index of final valid token."""
    out = T.forward(params, cfg, {"tokens": sequences}, remat=False)
    h = out["hidden"]                                   # [B, S, d]
    idx = mask_last[:, None, None]
    last_h = jnp.take_along_axis(
        h, jnp.broadcast_to(idx, (h.shape[0], 1, h.shape[2])), axis=1)[:, 0]
    return (last_h @ head["w"])[:, 0].astype(jnp.float32)


def init_value_head(key, cfg: ModelConfig, dtype=jnp.float32):
    return {"w": dense_init(key, (cfg.d_model, 1), dtype=dtype)}


def critic_values(params, head, cfg: ModelConfig, sequences, gen_start: int):
    """Per-generated-token values V(s_t). Returns [B, S - gen_start]."""
    out = T.forward(params, cfg, {"tokens": sequences}, remat=False)
    h = out["hidden"][:, gen_start - 1:-1]  # state BEFORE each gen token
    return (h @ head["w"])[..., 0].astype(jnp.float32)
