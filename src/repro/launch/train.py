"""Training launcher: runs real LM train steps for any --arch on the host
mesh (CPU smoke scale by default, production mesh shapes via dry-run).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.configs.shapes import token_splits
from repro.data.synthetic import random_lm_batch
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim import adam


def make_batch(cfg, key, batch, seq):
    n_feat, n_tok = token_splits(cfg, seq)
    out = {}
    k1, k2 = jax.random.split(key)
    if n_feat:
        out["features"] = jax.random.normal(
            k1, (batch, n_feat, cfg.feature_dim), jnp.dtype(cfg.dtype))
    if n_tok:
        out["tokens"] = jax.random.randint(k2, (batch, n_tok), 0,
                                           cfg.vocab_size, jnp.int32)
    labels = jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size,
                                jnp.int32)
    out["labels"] = labels
    out["loss_mask"] = jnp.ones((batch, seq), jnp.dtype(cfg.dtype))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = archs.get(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    print(f"arch={cfg.name} params~{cfg.param_count():,} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    opt_cfg = adam.AdamConfig(lr=args.lr, total_steps=args.steps)
    opt_state = adam.init_adam_state(params, opt_cfg)
    train_step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg))

    with mesh:
        for step in range(args.steps):
            key, k = jax.random.split(key)
            batch = make_batch(cfg, k, args.batch, args.seq)
            t0 = time.time()
            params, opt_state, metrics = train_step(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            print(f"step {step:4d} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} ({dt:.2f}s)")
    print("done")


if __name__ == "__main__":
    main()
