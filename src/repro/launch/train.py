"""Training launcher: runs real LM train steps for any --arch on the host
mesh (CPU smoke scale by default, production mesh shapes via dry-run).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 20 --batch 8 --seq 128

With ``--rl {grpo,ppo}`` the launcher instead runs the plan-driven RL
path end-to-end: HetRL scheduler search on the chosen testbed scenario →
``Plan`` → engine execution of real RL iterations → measured vs
cost-model iteration time:

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --rl grpo --steps 10 --batch 8 --search-budget 120

``--drift <scenario>`` additionally runs the §6 elasticity loop: a named
topology drift (see ``core.topology.DRIFT_SCENARIOS``) fires mid-run, the
elastic controller reschedules with a warm-start budget at the iteration
boundary, checkpoints trainer state, and swaps the plan when the
``RedeployDecision`` says so — reporting measured-vs-predicted iteration
time per plan epoch:

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --rl grpo --steps 12 --batch 8 --drift drop_tail --drift-at 4

``--fault <scenario>`` injects a named *fault* (see
``repro.faults.FAULT_SCENARIOS``) on the engine's iteration clock:
undeclared degradations are detected purely from measured-vs-predicted
divergence (a short calibration warmup arms the monitor), transient
crashes are absorbed by bounded retry, permanent failures escalate to a
forced replan on the survivors.  Composes with ``--drift`` (declared +
undeclared drift in one run); ``--require-recover`` exits non-zero
unless the scenario's recovery mechanism actually engaged:

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --rl grpo --steps 12 --batch 8 --fault link_throttle \
        --fault-at 6 --require-recover
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.configs.shapes import token_splits
from repro.data.synthetic import random_lm_batch
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.obs import trace as obs_trace
from repro.optim import adam


def make_batch(cfg, key, batch, seq):
    n_feat, n_tok = token_splits(cfg, seq)
    out = {}
    k1, k2 = jax.random.split(key)
    if n_feat:
        out["features"] = jax.random.normal(
            k1, (batch, n_feat, cfg.feature_dim), jnp.dtype(cfg.dtype))
    if n_tok:
        out["tokens"] = jax.random.randint(k2, (batch, n_tok), 0,
                                           cfg.vocab_size, jnp.int32)
    labels = jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size,
                                jnp.int32)
    out["labels"] = labels
    out["loss_mask"] = jnp.ones((batch, seq), jnp.dtype(cfg.dtype))
    return out


def _assert_recovered(scenario: str, trainer, controller, injector) -> None:
    """--require-recover: fail loudly unless the scenario's recovery
    mechanism actually engaged (CI smoke gate)."""
    from repro.obs import metrics as obs_metrics
    snap = obs_metrics.snapshot()
    records = controller.records if controller is not None else []
    if scenario in ("link_throttle", "device_slowdown", "straggler"):
        assert any(r.reactive for r in records), (
            f"{scenario}: divergence monitor never fired a reactive "
            f"replan (records={len(records)})")
        print(f"recovered: reactive replan at iteration "
              f"{next(r.iteration for r in records if r.reactive)}")
    elif scenario == "transient_crash":
        assert snap.get("engine.task_retries", 0) > 0, \
            "transient_crash: no retries recorded"
        assert not snap.get("engine.task_failures"), \
            "transient_crash: failure escaped the retry budget"
        print(f"recovered: {int(snap['engine.task_retries'])} retries "
              f"absorbed the crash")
    elif scenario in ("permanent_crash", "device_drop"):
        assert any(r.forced for r in records), \
            f"{scenario}: no forced replan happened"
        rec = next(r for r in records if r.forced)
        print(f"recovered: forced replan at iteration {rec.iteration} "
              f"-> epoch {rec.epoch}")
    elif scenario in ("ckpt_fail",):
        assert snap.get("checkpoint.retries", 0) > 0, \
            "ckpt_fail: no checkpoint retries recorded"
        print(f"recovered: {int(snap['checkpoint.retries'])} checkpoint "
              f"retries")
    elif scenario == "ckpt_flaky":
        assert snap.get("checkpoint.failures", 0) > 0, \
            "ckpt_flaky: warn-and-continue never engaged"
        print("recovered: checkpoint degraded to warn-and-continue, "
              "training completed")
    elif scenario == "ckpt_corrupt":
        from repro.checkpoint import io as ckpt_io
        assert injector.fired("ckpt_corrupt"), \
            "ckpt_corrupt: corruption never fired"
        tree, path = ckpt_io.load_latest("results/elastic_ckpt",
                                         trainer.state_tree())
        print(f"recovered: load_latest fell back to {path}")
    elif scenario == "slot_failure":
        assert snap.get("gen.slot_failures", 0) > 0, \
            "slot_failure: no slots failed"
        print(f"recovered: {int(snap['gen.slot_failures'])} slot "
              f"failures requeued")
    else:  # chaos
        assert injector is not None and injector.log, \
            f"{scenario}: no fault events fired"
        print(f"recovered: completed under {len(injector.log)} "
              f"injected events")


def run_rl(args) -> None:
    """Scheduler search -> Plan -> engine-executed RL iterations."""
    import numpy as np

    from repro.core import topology, workflow
    from repro.core.plan import check_constraints
    from repro.core.sha import HybridScheduler
    from repro.data.synthetic import AdditionTask, PromptDataset, VOCAB_SIZE
    from repro.rl.trainer import RLConfig, RLTrainer

    import dataclasses

    cfg = archs.get(args.arch, smoke=args.smoke)
    # RL on the verifiable addition task needs its small vocab; fp32 keeps
    # the tiny-model smoke run numerically stable on CPU
    cfg = dataclasses.replace(cfg, vocab_size=VOCAB_SIZE, dtype="float32")
    task = AdditionTask(max_operand=9)
    topo = topology.build_testbed(args.scenario,
                                  counts={"A100": 4, "L4": 4})
    spec = workflow.LLMSpec.from_model_config(cfg)
    wf = workflow.make_workflow(args.rl, spec,
                                synchronous=not args.asynchronous,
                                global_batch=args.batch, n_rollouts=4,
                                seq_in=task.prompt_len,
                                seq_out=task.max_answer_len)
    sched = HybridScheduler(topo, wf, max_groupings=8,
                            max_sizes_per_grouping=4)
    r = sched.search(budget=args.search_budget)
    ok, msg = check_constraints(topo, wf, r.plan)
    assert ok, msg
    print(f"plan: grouping={r.grouping} sizes={r.sizes} "
          f"predicted {r.cost * 1e3:.3f}ms/iter ({r.evals} evals)")

    rl = RLConfig(algorithm=args.rl, n_rollouts=4,
                  max_new_tokens=task.max_answer_len, lr=args.lr,
                  asynchronous=args.asynchronous,
                  spec_k=args.spec_k, draft_arch=args.draft_arch)
    trainer = RLTrainer(cfg, rl, task, jax.random.PRNGKey(0), plan=r.plan,
                        topo=topo, wf=wf)

    # fault injection (undeclared degradations + crash scenarios)
    injector = None
    warmup = 0
    if args.fault:
        from repro.core import retry
        from repro.core.workflow import TaskKind
        from repro.faults import FaultInjector, fault_scenario
        warmup = min(3, max(args.steps - 2, 1))
        fault_at = args.fault_at if args.fault_at is not None \
            else max(args.steps // 2, warmup + 1)
        gen_task = next(t for t in range(wf.n_tasks)
                        if wf.task(t).kind == TaskKind.GEN)
        train_task = next(t for t in range(wf.n_tasks)
                          if wf.task(t).kind == TaskKind.TRAIN
                          and wf.task(t).name.startswith("actor"))
        fplan = fault_scenario(args.fault, at=fault_at, topo=topo,
                               gen_task=gen_task, train_task=train_task,
                               n_tasks=wf.n_tasks)
        print(f"faults: {fplan.describe()}")
        injector = FaultInjector(fplan)
        trainer.engine.attach_fault_injector(injector)
        trainer.engine.set_task_retry(
            retry.RetryPolicy(max_attempts=3, base_delay_s=0.05))

    controller = None
    if args.drift or args.fault:
        from repro.engine.elastic import ElasticConfig, ElasticController
        if args.drift:
            drift_at = args.drift_at if args.drift_at is not None \
                else max(args.steps // 2, 1)
            feed = topology.drift_scenario(args.drift, topo, at=drift_at)
        else:
            # constant feed: any reaction must come from the divergence
            # monitor or failure escalation, never from a declared drift
            feed = lambda it: topo  # noqa: E731
        controller = ElasticController(
            trainer, feed,
            ElasticConfig(budget=args.search_budget,
                          ckpt_dir="results/elastic_ckpt"))

    from repro.engine.executor import TaskExecutionError

    ds = iter(PromptDataset(task, batch=args.batch, seed=1))
    key = jax.random.PRNGKey(42)
    for step in range(args.steps):
        prompts, answers = next(ds)
        key, k = jax.random.split(key)
        t0 = time.time()
        try:
            m = trainer.iteration(prompts, answers, k)
        except TaskExecutionError as e:
            if controller is None:
                raise
            print(f"iter {step:4d} TASK FAILURE: {e}")
            rec = controller.handle_failure(step, e)
            print(f"  escalated: dropped dead devices, forced replan -> "
                  f"epoch={rec.epoch} "
                  f"new={rec.decision.new_cost * 1e3:.3f}ms/iter")
            m = trainer.iteration(prompts, answers, k)
        print(f"iter {step:4d} reward={m['reward_mean']:.3f} "
              f"kl={m['kl']:.3f} sync={m['sync_gb'] * 1e3:.1f}MB "
              f"({time.time() - t0:.2f}s)")
        if args.fault and step + 1 == warmup:
            # calibration warmup done: arm the divergence monitor and
            # calibrated deadlines — undeclared faults are detectable
            # from here on
            from repro.obs import calibrate as obs_cal
            cal = obs_cal.fit_from_engine(trainer.engine)
            monitor = obs_cal.DivergenceMonitor(threshold=2.0, sustain=2)
            trainer.engine.attach_divergence_monitor(monitor, cal)
            trainer.engine.set_task_deadlines(cal, slack=5.0)
            controller.monitor = monitor
            print(f"  calibrated ({cal.n_samples} samples); divergence "
                  f"monitor + deadlines armed")
        if args.fault and args.fault.startswith("ckpt"):
            controller.checkpoint_now(step)
        if controller is not None:
            rec = controller.poll(step)
            if rec is not None:
                d = rec.decision
                print(f"  drift{' (reactive)' if rec.reactive else ''}: "
                      f"reschedule in {rec.reschedule_s:.1f}s -> "
                      f"switch={d.switch} old={d.old_cost * 1e3:.3f}ms "
                      f"new={d.new_cost * 1e3:.3f}ms "
                      f"trans={d.transition_cost_s * 1e3:.3f}ms "
                      f"epoch={rec.epoch} "
                      f"ckpt={rec.ckpt_bytes / 1e6:.1f}MB")
    if injector is not None:
        fired = [r for r in injector.log if r["what"] != "activate"]
        print(f"faults fired: {len(injector.log)} events "
              f"({len(fired)} raises/corruptions)")
    if args.fault and args.require_recover:
        _assert_recovered(args.fault, trainer, controller, injector)
    if controller is not None:
        for row in trainer.engine.epoch_report():
            print(f"epoch {row['epoch']}: {row['iterations']} iters, "
                  f"measured {row['measured_iter_s'] * 1e3:.1f}ms/iter vs "
                  f"predicted {row['predicted_iter_s'] * 1e3:.3f}ms/iter")
    cmp = trainer.engine.compare_with_simulator()
    print(f"measured {cmp['measured_iter_s'] * 1e3:.1f}ms/iter vs "
          f"cost-model {cmp['predicted_iter_s'] * 1e3:.3f}ms/iter "
          f"(ratio {cmp['ratio']:.2f})")
    if args.calibrate:
        from repro.obs import calibrate as obs_cal
        c = obs_cal.fit_from_engine(trainer.engine)
        ccmp = trainer.engine.compare_with_simulator(
            cost_model=c.cost_model(trainer.engine.topo, trainer.wf))
        print(f"calibrated ({c.n_samples} samples, global scale "
              f"{c.global_scale:.3g}): ratio {ccmp['ratio']:.2f}")
    print("done")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--rl", choices=["grpo", "ppo"], default=None,
                    help="run the plan-driven RL path instead of LM steps")
    ap.add_argument("--async", dest="asynchronous", action="store_true",
                    help="one-step off-policy RL execution (with --rl)")
    ap.add_argument("--scenario", default="single_region",
                    help="testbed scenario the scheduler plans against")
    ap.add_argument("--search-budget", type=int, default=120,
                    help="scheduler budget in cost-model evaluations")
    ap.add_argument("--drift", default=None,
                    help="inject a named topology drift mid-run and react "
                         "elastically (with --rl); see "
                         "core.topology.DRIFT_SCENARIOS")
    ap.add_argument("--drift-at", type=int, default=None,
                    help="iteration the drift fires at (default steps//2)")
    ap.add_argument("--fault", default=None,
                    help="inject a named fault scenario mid-run (with "
                         "--rl); see repro.faults.FAULT_SCENARIOS; "
                         "composes with --drift")
    ap.add_argument("--fault-at", type=int, default=None,
                    help="iteration the fault fires at (default steps//2, "
                         "after the calibration warmup)")
    ap.add_argument("--require-recover", action="store_true",
                    help="exit non-zero unless the fault scenario's "
                         "recovery mechanism engaged (CI gate)")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit cost-model calibration from the measured "
                         "timeline and report the corrected measured-vs-"
                         "predicted ratio (with --rl)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="draft-model speculative decoding in GEN: "
                         "draft tokens per wave round (0 = off; "
                         "forces the genserve engine path)")
    ap.add_argument("--draft-arch", default="",
                    help="configs.archs entry for the speculative "
                         "draft ('' = scaled-down copy of --arch)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="write a Chrome-trace JSON of the run "
                         "(view in Perfetto / chrome://tracing)")
    args = ap.parse_args()
    if args.trace:
        obs_trace.enable()

    if args.rl:
        run_rl(args)
        if args.trace:
            obs_trace.export_chrome(args.trace)
            print(f"trace -> {args.trace}")
        return

    cfg = archs.get(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    print(f"arch={cfg.name} params~{cfg.param_count():,} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    opt_cfg = adam.AdamConfig(lr=args.lr, total_steps=args.steps)
    opt_state = adam.init_adam_state(params, opt_cfg)
    train_step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg))

    with mesh:
        for step in range(args.steps):
            key, k = jax.random.split(key)
            batch = make_batch(cfg, k, args.batch, args.seq)
            t0 = time.time()
            with obs_trace.span("train.step", step=step):
                params, opt_state, metrics = train_step(params, opt_state,
                                                        batch)
                loss = float(metrics["loss"])
            dt = time.time() - t0
            print(f"step {step:4d} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} ({dt:.2f}s)")
    if args.trace:
        obs_trace.export_chrome(args.trace)
        print(f"trace -> {args.trace}")
    print("done")


if __name__ == "__main__":
    main()
