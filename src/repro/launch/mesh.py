"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches JAX device state.  The dry-run entrypoint sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
everything else sees the real device count.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices exist (CPU smoke runs)."""
    n = jax.device_count()
    data = max(n // model_axis, 1)
    return jax.make_mesh((data, model_axis), ("data", "model"))


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes that carry the batch dimension."""
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


def batch_spec(mesh) -> P:
    da = data_axes(mesh)
    return P(da if len(da) > 1 else da[0])
