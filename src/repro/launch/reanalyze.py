"""Recompute the loop-aware metrics of existing dry-run JSONs from their
stored .hlo.zst files (no recompilation).

    PYTHONPATH=src python -m repro.launch.reanalyze [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import zstandard

from repro.launch import hlo_analysis


def reanalyze(result_dir: str) -> None:
    for path in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "skipped" in rec:
            continue
        hlo_path = path.replace(".json", ".hlo.zst")
        if not os.path.exists(hlo_path):
            print(f"no HLO for {os.path.basename(path)}; skipping")
            continue
        with open(hlo_path, "rb") as f:
            hlo = zstandard.ZstdDecompressor().decompress(f.read()).decode()
        la = hlo_analysis.analyze(hlo)
        rec["flops_per_device"] = float(la.flops)
        rec["bytes_per_device"] = float(la.bytes)
        rec["collective_bytes_per_device"] = {
            k: float(v) for k, v in la.collectives.items()}
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"reanalyzed {os.path.basename(path)}: "
              f"flops={la.flops:.3e} bytes={la.bytes:.3e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    reanalyze(args.dir)


if __name__ == "__main__":
    main()
