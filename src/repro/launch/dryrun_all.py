"""Driver: run the dry-run for every (arch × shape × mesh) combination in
separate subprocesses (fresh XLA state per compile, resumable via the
per-combination JSON files).

    PYTHONPATH=src python -m repro.launch.dryrun_all [--jobs 2] \
        [--out results/dryrun] [--single-only]
"""
from __future__ import annotations

import argparse
import itertools
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

from repro.configs import archs
from repro.configs.shapes import SHAPES


def combos(single_only: bool = False):
    meshes = [False] if single_only else [False, True]
    for arch in archs.ARCHS:
        for shape in SHAPES:
            for multi in meshes:
                yield arch, shape, multi


def run_one(arch: str, shape: str, multi: bool, out: str) -> str:
    tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
    path = os.path.join(out, tag + ".json")
    if os.path.exists(path):
        return f"SKIP(exists) {tag}"
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", out]
    if multi:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    t0 = time.time()
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=3600)
    dt = time.time() - t0
    if r.returncode != 0:
        tail = "\n".join(r.stderr.splitlines()[-12:])
        with open(os.path.join(out, tag + ".err"), "w") as f:
            f.write(r.stdout + "\n==== STDERR ====\n" + r.stderr)
        return f"FAIL {tag} ({dt:.0f}s)\n{tail}"
    return f"OK   {tag} ({dt:.0f}s)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--single-only", action="store_true")
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    todo = [c for c in combos(args.single_only)
            if args.arch is None or c[0] == args.arch]
    print(f"{len(todo)} combinations")
    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        for msg in ex.map(lambda c: run_one(*c, args.out), todo):
            print(msg, flush=True)


if __name__ == "__main__":
    main()
