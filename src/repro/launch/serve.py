"""Serving launcher: continuous-batching generation for any --arch
(smoke scale on CPU; the full-scale path is exercised via the dry-run).

Requests stream through the genserve engine: at most --wave sequences
decode concurrently, finished slots (EOS or budget) are recycled via
prefill injection — one-shot whole-prompt admission by default, or
*chunked prefill* (--prefill-chunk N: mixed wave-steps that ingest up to
N prompt tokens per round alongside decode, so a long prompt never
stalls the wave).  --page-size switches the KV cache to the paged
page-pool layout and --prefix-cache adds radix prefix reuse across
requests: admission matches each prompt against a prefix tree and skips
prefill on the cached prefix (--shared-prompts S makes the first S
prompt tokens identical across requests so the cache has something to
hit).  The report includes tokens/s, time-to-first-token p50/p95 (the
headline metric chunked prefill and prefix reuse move), the prefix-
cache token hit rate with the prefill tokens skipped, and the measured
mean decode-wave occupancy next to the cost model's ideal.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
        --batch 16 --wave 4 --prompt-len 32 --new-tokens 16 --prefill-chunk 8
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 16 --wave 4 --prompt-len 32 --new-tokens 16 \
        --prefill-chunk 8 --page-size 8 --prefix-cache --shared-prompts 24
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.core.plan import decode_wave, predicted_occupancy, prefill_rounds
from repro.genserve import adapter as genserve
from repro.genserve.adapter import ttft_quantiles
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.rl.rollout import SamplerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--wave", type=int, default=0,
                    help="decode slots (0 = core.plan.decode_wave(batch))")
    ap.add_argument("--decode-chunk", type=int, default=4,
                    help="decode steps per host round")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked admission: prompt tokens ingested per "
                         "mixed wave round (0 = one-shot prefill)")
    ap.add_argument("--eos-token", type=int, default=None,
                    help="retire sequences on this token id")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV cache: tokens per pool page "
                         "(0 = contiguous per-slot cache)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix reuse across requests (skips "
                         "prefill on cached prompt prefixes; implies "
                         "--page-size 8 unless given)")
    ap.add_argument("--shared-prompts", type=int, default=0,
                    help="make the first N prompt tokens identical "
                         "across requests (a shared system prompt)")
    ap.add_argument("--expect-prefix-hits", action="store_true",
                    help="exit nonzero unless the prefix-cache token "
                         "hit rate is > 0 (CI smoke)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft tokens proposed "
                         "per wave round, verified by one batched "
                         "(k+1)-wide target step (0 = off)")
    ap.add_argument("--draft-arch", default="",
                    help="configs.archs entry for the speculative "
                         "draft model (vocab/dtype follow --arch); "
                         "required with --spec-k")
    ap.add_argument("--check-spec-parity", action="store_true",
                    help="greedy only: also run the non-speculative "
                         "engine and exit nonzero unless the emitted "
                         "tokens match exactly (CI smoke)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="write a Chrome-trace JSON of the serving run "
                         "(view in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics", default=None, metavar="FILE",
                    help="dump the metrics-registry snapshot as JSON")
    args = ap.parse_args()
    if args.trace:
        obs_trace.enable()
    if args.prefix_cache and args.page_size == 0:
        args.page_size = 8
    if args.page_size and args.prefill_chunk == 0:
        # paged admission is chunked by construction
        args.prefill_chunk = min(args.prompt_len, 16)

    cfg = archs.get(args.arch, smoke=args.smoke)
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving "
                         f"(run classification via launch.train instead)")
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    draft_params, draft_cfg = None, None
    if args.spec_k > 0:
        if not args.draft_arch:
            raise SystemExit("--spec-k needs --draft-arch")
        import dataclasses as _dc
        draft_cfg = _dc.replace(archs.get(args.draft_arch, smoke=args.smoke),
                                vocab_size=cfg.vocab_size, dtype=cfg.dtype)
        draft_params = T.init_params(jax.random.PRNGKey(2), draft_cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    if args.shared_prompts > 0:
        s = min(args.shared_prompts, args.prompt_len)
        prompts = prompts.at[:, :s].set(prompts[0, :s])
    wave = args.wave or decode_wave(args.batch)
    sampler = SamplerConfig(max_new_tokens=args.new_tokens,
                            temperature=args.temperature,
                            eos_token=args.eos_token,
                            greedy=args.temperature <= 0)
    with mesh:
        gen = lambda spec=True, **kw: genserve.generate(
            params, cfg, prompts, jax.random.PRNGKey(1), sampler,
            wave=wave, fast_path=False, decode_chunk=args.decode_chunk,
            prefill_chunk=args.prefill_chunk, page_size=args.page_size,
            prefix_cache=args.prefix_cache,
            spec_k=args.spec_k if spec else 0,
            draft_params=draft_params if spec else None,
            draft_cfg=draft_cfg if spec else None, **kw)
        gen()            # warm-up: compile the engine programs
        t0 = time.time()
        ro, stats = gen()   # timed run is uninstrumented (TTFT stamping
        jax.block_until_ready(ro["sequences"])   # syncs admission)
        dt = time.time() - t0
        ro_ref = None
        if args.check_spec_parity and args.spec_k > 0:
            ro_ref, _ = gen(spec=False)
        obs_metrics.reset()   # quantiles below describe this run only
        _, ttft_stats = gen(measure_ttft=True)
    valid = float(jnp.sum(ro["mask"]))
    hit = float(stats.get("prefix_hit_rate", 0.0))
    rounds = prefill_rounds(args.prompt_len, args.prefill_chunk,
                            prefix_hit_rate=hit)
    ideal = predicted_occupancy(args.batch, wave=wave,
                                prefill_rounds=rounds,
                                max_new_tokens=args.new_tokens)
    p50, p95 = ttft_quantiles(ttft_stats)
    admission = (f"chunked (C={args.prefill_chunk})"
                 if args.prefill_chunk else "one-shot")
    if args.page_size:
        admission += f" paged (page={args.page_size})"
    if args.prefix_cache:
        admission += " +prefix-cache"
    print(f"arch={cfg.name} engine={stats['engine']} wave={stats['wave']} "
          f"batch={args.batch} admission={admission}")
    print(f"generated {ro['gen_tokens'].shape} in {dt:.2f}s "
          f"({valid / dt:.1f} valid tok/s; {stats['decode_steps']} decode "
          f"rounds, {stats['prefills']} prefill injections, "
          f"{stats.get('prefill_rounds', 0)} prefill-chunk rounds)")
    snap = obs_metrics.snapshot()
    ttft_h = snap.get("gen.ttft_s") or {}
    qw = snap.get("gen.queue_wait_s") or {}
    print(f"ttft p50={p50 * 1e3:.1f}ms p95={p95 * 1e3:.1f}ms "
          f"p99={ttft_h.get('p99', 0.0) * 1e3:.1f}ms")
    if qw.get("count"):
        print(f"queue wait p50={qw['p50'] * 1e3:.1f}ms "
              f"p95={qw['p95'] * 1e3:.1f}ms "
              f"p99={qw['p99'] * 1e3:.1f}ms "
              f"(n={qw['count']})")
    if args.prefix_cache:
        print(f"prefix cache: {hit:.1%} token hit rate "
              f"({stats['prefill_tokens_skipped']} of "
              f"{stats['prompt_tokens']} prompt tokens skipped)")
    if args.spec_k > 0:
        print(f"speculative: k={stats['spec_k']} "
              f"draft={draft_cfg.name} "
              f"accept rate {stats['accept_rate']:.1%} "
              f"({stats['spec_accepted']}/{stats['spec_proposed']} drafts; "
              f"{stats['spec_tokens']} tokens in {stats['decode_steps']} "
              f"verify rounds)")
    if args.prefill_chunk:
        print(f"busy wave occupancy (decode + prefill): "
              f"{stats['busy_occupancy']:.2f} "
              f"(cost-model ideal {ideal:.2f})")
    else:
        print(f"mean wave occupancy: {stats['mean_occupancy']:.2f} "
              f"(cost-model ideal {ideal:.2f})")
    print("sample:", ro["sequences"][0, :24].tolist())
    if args.trace:
        obs_trace.export_chrome(args.trace)
        print(f"trace -> {args.trace}")
    if args.metrics:
        obs_metrics.dump(args.metrics)
        print(f"metrics snapshot -> {args.metrics}")
    if args.expect_prefix_hits and hit <= 0.0:
        raise SystemExit("expected a nonzero prefix-cache hit rate "
                         f"(got {hit}) — shared-prompt trace not hitting")
    if ro_ref is not None:
        import numpy as _np
        same = _np.array_equal(
            _np.asarray(ro["gen_tokens"]) * _np.asarray(ro["mask"]),
            _np.asarray(ro_ref["gen_tokens"]) * _np.asarray(ro_ref["mask"]))
        if not same:
            raise SystemExit("speculative greedy decode diverged from the "
                             "non-speculative engine (token parity check)")
        print("spec parity: speculative == non-speculative (greedy)")


if __name__ == "__main__":
    main()
