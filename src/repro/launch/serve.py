"""Serving launcher: continuous-batching generation for any --arch
(smoke scale on CPU; the full-scale path is exercised via the dry-run).

Requests stream through the genserve engine: at most --wave sequences
decode concurrently, finished slots (EOS or budget) are recycled via
prefill injection — one-shot whole-prompt admission by default, or
*chunked prefill* (--prefill-chunk N: mixed wave-steps that ingest up to
N prompt tokens per round alongside decode, so a long prompt never
stalls the wave).  The report includes tokens/s, time-to-first-token
p50/p95 (the headline metric chunked prefill moves) and the measured
mean decode-wave occupancy next to the cost model's ideal.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
        --batch 16 --wave 4 --prompt-len 32 --new-tokens 16 --prefill-chunk 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.core.plan import decode_wave, predicted_occupancy, prefill_rounds
from repro.genserve import adapter as genserve
from repro.genserve.adapter import ttft_quantiles
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.rl.rollout import SamplerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--wave", type=int, default=0,
                    help="decode slots (0 = core.plan.decode_wave(batch))")
    ap.add_argument("--decode-chunk", type=int, default=4,
                    help="decode steps per host round")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked admission: prompt tokens ingested per "
                         "mixed wave round (0 = one-shot prefill)")
    ap.add_argument("--eos-token", type=int, default=None,
                    help="retire sequences on this token id")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    args = ap.parse_args()

    cfg = archs.get(args.arch, smoke=args.smoke)
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving "
                         f"(run classification via launch.train instead)")
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    wave = args.wave or decode_wave(args.batch)
    sampler = SamplerConfig(max_new_tokens=args.new_tokens,
                            temperature=args.temperature,
                            eos_token=args.eos_token,
                            greedy=args.temperature <= 0)
    with mesh:
        gen = lambda **kw: genserve.generate(
            params, cfg, prompts, jax.random.PRNGKey(1), sampler,
            wave=wave, fast_path=False, decode_chunk=args.decode_chunk,
            prefill_chunk=args.prefill_chunk, **kw)
        gen()            # warm-up: compile the engine programs
        t0 = time.time()
        ro, stats = gen()   # timed run is uninstrumented (TTFT stamping
        jax.block_until_ready(ro["sequences"])   # syncs admission)
        dt = time.time() - t0
        _, ttft_stats = gen(measure_ttft=True)
    valid = float(jnp.sum(ro["mask"]))
    rounds = prefill_rounds(args.prompt_len, args.prefill_chunk)
    ideal = predicted_occupancy(args.batch, wave=wave,
                                prefill_rounds=rounds,
                                max_new_tokens=args.new_tokens)
    p50, p95 = ttft_quantiles(ttft_stats)
    admission = (f"chunked (C={args.prefill_chunk})"
                 if args.prefill_chunk else "one-shot")
    print(f"arch={cfg.name} engine={stats['engine']} wave={stats['wave']} "
          f"batch={args.batch} admission={admission}")
    print(f"generated {ro['gen_tokens'].shape} in {dt:.2f}s "
          f"({valid / dt:.1f} valid tok/s; {stats['decode_steps']} decode "
          f"rounds, {stats['prefills']} prefill injections, "
          f"{stats.get('prefill_rounds', 0)} prefill-chunk rounds)")
    print(f"ttft p50={p50 * 1e3:.1f}ms p95={p95 * 1e3:.1f}ms")
    if args.prefill_chunk:
        print(f"busy wave occupancy (decode + prefill): "
              f"{stats['busy_occupancy']:.2f} "
              f"(cost-model ideal {ideal:.2f})")
    else:
        print(f"mean wave occupancy: {stats['mean_occupancy']:.2f} "
              f"(cost-model ideal {ideal:.2f})")
    print("sample:", ro["sequences"][0, :24].tolist())


if __name__ == "__main__":
    main()
