"""Serving launcher: continuous-batching generation for any --arch
(smoke scale on CPU; the full-scale path is exercised via the dry-run).

Requests stream through the genserve engine: at most --wave sequences
decode concurrently, finished slots (EOS or budget) are recycled via
prefill injection, and the report includes tokens/s plus the measured
mean decode-wave occupancy next to the cost model's ideal.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
        --batch 16 --wave 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.core.plan import decode_wave, predicted_occupancy
from repro.genserve import adapter as genserve
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.rl.rollout import SamplerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--wave", type=int, default=0,
                    help="decode slots (0 = core.plan.decode_wave(batch))")
    ap.add_argument("--decode-chunk", type=int, default=4,
                    help="decode steps per host round")
    ap.add_argument("--eos-token", type=int, default=None,
                    help="retire sequences on this token id")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    args = ap.parse_args()

    cfg = archs.get(args.arch, smoke=args.smoke)
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving "
                         f"(run classification via launch.train instead)")
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    wave = args.wave or decode_wave(args.batch)
    sampler = SamplerConfig(max_new_tokens=args.new_tokens,
                            temperature=args.temperature,
                            eos_token=args.eos_token,
                            greedy=args.temperature <= 0)
    with mesh:
        gen = lambda: genserve.generate(params, cfg, prompts,
                                        jax.random.PRNGKey(1), sampler,
                                        wave=wave, fast_path=False,
                                        decode_chunk=args.decode_chunk)
        gen()            # warm-up: compile the admit/chunk programs
        t0 = time.time()
        ro, stats = gen()
        jax.block_until_ready(ro["sequences"])
        dt = time.time() - t0
    valid = float(jnp.sum(ro["mask"]))
    ideal = predicted_occupancy(args.batch, wave=wave)
    print(f"arch={cfg.name} engine={stats['engine']} wave={stats['wave']} "
          f"batch={args.batch}")
    print(f"generated {ro['gen_tokens'].shape} in {dt:.2f}s "
          f"({valid / dt:.1f} valid tok/s; {stats['decode_steps']} decode "
          f"steps, {stats['prefills']} prefill injections)")
    print(f"mean wave occupancy: {stats['mean_occupancy']:.2f} "
          f"(cost-model ideal {ideal:.2f})")
    print("sample:", ro["sequences"][0, :24].tolist())


if __name__ == "__main__":
    main()
