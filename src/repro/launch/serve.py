"""Serving launcher: batched prefill + decode for any --arch (smoke scale
on CPU; the full-scale path is exercised via the dry-run).

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
        --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.models.sampling import greedy_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = archs.get(args.arch, smoke=args.smoke)
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving "
                         f"(run classification via launch.train instead)")
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    with mesh:
        t0 = time.time()
        toks = greedy_decode(params, cfg, prompts, args.new_tokens)
        toks.block_until_ready()
        dt = time.time() - t0
    tps = args.batch * args.new_tokens / dt
    print(f"arch={cfg.name} generated {toks.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s)")
    print("sample:", toks[0, :24].tolist())


if __name__ == "__main__":
    main()
