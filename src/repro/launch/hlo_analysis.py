"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, which
silently undercounts scan-over-layers models by ~n_layers×.  This module
parses the optimized HLO text, builds the computation call graph (fusions,
calls, while bodies with trip counts recovered from the loop condition),
and accumulates:

  * FLOPs        — dot_general (2*M*N*K from the printed dimension numbers)
                   plus 1 flop/element for elementwise arithmetic;
  * bytes        — operand + output bytes of every op (HBM-traffic proxy);
  * collectives  — operand bytes per collective type (all-gather,
                   all-reduce, reduce-scatter, all-to-all,
                   collective-permute), the §Roofline collective term.

All values are PER DEVICE (the compiled module is the per-device SPMD
program).  Trip counts: the largest integer constant in the while
condition computation (standard lax.scan lowering); 1 if none found.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE = {"add", "subtract", "multiply", "divide", "maximum",
                "minimum", "exponential", "tanh", "rsqrt", "sqrt", "power",
                "negate", "abs", "log", "logistic", "cosine", "sine",
                "expm1", "log1p"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _parse_shape(s: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.match(s.strip())
    if not m:
        return "f32", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


def _shape_bytes(s: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_elems(s: str) -> int:
    m = _SHAPE_RE.match(s.strip())
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    # (callee, kind) kind in {"call", "while"}; while carries (cond, body)
    calls: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    whiles: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    max_constant: int = 1


_COMP_START = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^=]*\))?\s*->.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$")
_PARAM_SIG = re.compile(r"%?([\w\.\-]+):\s*((?:\([^)]*\))|(?:[a-z0-9]+"
                        r"\[[0-9,]*\](?:\{[^}]*\})?))")


_COMP_NAME = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """Computation name -> lines.  Long headers wrap across physical
    lines; they are joined until the opening '{' is seen."""
    comps: Dict[str, List[str]] = {}
    lines = hlo.splitlines()
    i = 0
    entry = None
    n = len(lines)
    while i < n:
        line = lines[i]
        m = _COMP_NAME.match(line) if not line.startswith(" ") else None
        if m and ("->" in line or "{" not in line):
            header = line
            while not header.rstrip().endswith("{") and i + 1 < n:
                i += 1
                header += " " + lines[i].strip()
            if not header.rstrip().endswith("{"):
                i += 1
                continue
            name = m.group(2)
            if m.group(1):
                entry = name
            body = [header]
            i += 1
            while i < n:
                body.append(lines[i])
                if lines[i].strip() == "}" and not lines[i].startswith("  "):
                    break
                i += 1
            comps[name] = body
        i += 1
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _operand_names(args: str) -> List[str]:
    # strip anything after "), " attributes by cutting at the matching
    # depth; brackets/braces nest too (commas inside shapes like
    # f32[64,32,32]{2,1,0} must not split the operand list, or operand
    # indices misalign and per-operand accounting charges wrong shapes)
    depth = 0
    out = []
    cur = []
    for ch in args:
        if ch in "([{":
            depth += 1
            cur.append(ch)
        elif ch in ")]}":
            if depth == 0:
                break
            depth -= 1
            cur.append(ch)
        elif ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    names = []
    for o in out:
        o = o.strip()
        m = re.search(r"%([\w\.\-]+)\s*$", o)
        if m:
            names.append(m.group(1))
        else:
            m2 = re.match(r"([\w\.\-]+)$", o)
            if m2:
                names.append(m2.group(1))
    return names


def find_dus_root_update_bytes(lines: List[str]) -> Optional[int]:
    """If the computation's ROOT is a dynamic-update-slice (optionally
    behind a trailing convert — the CPU backend shadows bf16 buffers in
    f32 around dots; on TPU the buffer stays bf16 and the update is in
    place), return the update operand's byte size; else None."""
    shapes: Dict[str, str] = {}
    dus_update: Dict[str, Optional[int]] = {}
    if lines:
        for m in _PARAM_SIG.finditer(lines[0]):
            shapes[m.group(1)] = m.group(2)
    for line in lines[1:]:
        m = _OP_RE.match(line)
        if not m:
            continue
        name, out_shape, op, rest = m.groups()
        shapes[name] = out_shape
        ops = _operand_names(rest)
        if op == "dynamic-update-slice" and len(ops) > 1:
            dus_update[name] = _shape_bytes(shapes.get(ops[1], ""))
        if line.lstrip().startswith("ROOT"):
            if op == "dynamic-update-slice" and len(ops) > 1:
                return _shape_bytes(shapes.get(ops[1], ""))
            if op == "convert" and ops and ops[0] in dus_update:
                return dus_update[ops[0]]
    return None


_PARAM_IDX = re.compile(r"^param_(\d+)")


def fusion_param_slice_reads(lines: List[str]) -> Dict[int, int]:
    """Params of a fused computation consumed ONLY by a dynamic-slice:
    the fusion reads just the slice, not the whole operand.  Returns
    {param_index: effective_read_bytes}."""
    shapes: Dict[str, str] = {}
    param_idx: Dict[str, int] = {}
    if lines:
        for m in _PARAM_SIG.finditer(lines[0]):
            shapes[m.group(1)] = m.group(2)
            mi = _PARAM_IDX.match(m.group(1))
            if mi:
                param_idx[m.group(1)] = int(mi.group(1))
    uses: Dict[str, List[Tuple[str, str]]] = {p: [] for p in param_idx}
    for line in lines[1:]:
        m = _OP_RE.match(line)
        if not m:
            continue
        name, out_shape, op, rest = m.groups()
        shapes[name] = out_shape
        for o in _operand_names(rest):
            if o in uses:
                uses[o].append((op, out_shape))
    out: Dict[int, int] = {}
    for p, us in uses.items():
        if len(us) == 1 and us[0][0] == "dynamic-slice":
            out[param_idx[p]] = _shape_bytes(us[0][1])
    return out


def analyze_computation(lines: List[str],
                        fusion_dus: Optional[Dict[str, int]] = None,
                        fusion_slices: Optional[Dict[str, Dict[int, int]]]
                        = None) -> CompStats:
    st = CompStats()
    fusion_dus = fusion_dus or {}
    shapes: Dict[str, str] = {}
    # parameter shapes from the signature line
    if lines:
        for m in _PARAM_SIG.finditer(lines[0]):
            shapes[m.group(1)] = m.group(2)
    for line in lines[1:]:
        m = _OP_RE.match(line)
        if not m:
            cm = re.search(r"constant\((\d+)\)", line)
            if cm:
                st.max_constant = max(st.max_constant, int(cm.group(1)))
            continue
        name, out_shape, op, rest = m.groups()
        shapes[name] = out_shape
        out_bytes = _shape_bytes(out_shape)
        operands = _operand_names(rest)
        in_bytes = sum(_shape_bytes(shapes.get(o, "")) for o in operands)
        # HBM-traffic accounting: tuple plumbing is free; dynamic
        # (update-)slice touches only the slice, not the full buffer.
        if op in ("get-tuple-element", "tuple", "parameter", "constant",
                  "iota", "after-all", "partition-id", "replica-id"):
            pass
        elif op == "dynamic-slice":
            st.bytes += 2 * out_bytes
        elif op == "dynamic-update-slice":
            upd = _shape_bytes(shapes.get(operands[1], "")) \
                if len(operands) > 1 else 0
            st.bytes += 2 * upd
        elif op == "while":
            # carried tuple enters/leaves once; body accounting is separate
            st.bytes += out_bytes
        elif op == "fusion":
            mc0 = re.search(r"calls=%?([\w\.\-]+)", line)
            callee = mc0.group(1) if mc0 else None
            if callee in fusion_dus:
                # in-place (DUS-rooted) fusion: traffic = the update slice
                st.bytes += 2 * fusion_dus[callee]
            else:
                eff_in = 0
                slices = (fusion_slices or {}).get(callee, {})
                for oi, o in enumerate(operands):
                    if oi in slices:
                        eff_in += slices[oi]  # fused dynamic-slice read
                    else:
                        eff_in += _shape_bytes(shapes.get(o, ""))
                st.bytes += out_bytes + eff_in
        else:
            st.bytes += out_bytes + in_bytes

        cm = re.search(r"constant\((\d+)\)", line)
        if cm:
            st.max_constant = max(st.max_constant, int(cm.group(1)))

        if op == "dot":
            lhs = shapes.get(operands[0], "") if operands else ""
            _, lhs_dims = _parse_shape(lhs)
            mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
            k = 1
            if mc and lhs_dims:
                for d in mc.group(1).split(","):
                    if d and int(d) < len(lhs_dims):
                        k *= lhs_dims[int(d)]
            st.flops += 2.0 * _shape_elems(out_shape) * k
        elif op in _ELEMENTWISE:
            st.flops += _shape_elems(out_shape)
        elif op == "convolution":
            # rough: 2 * out_elems * (in_channels * window) — window parse
            mw = re.search(r"window=\{size=([0-9x]+)", line)
            win = 1
            if mw:
                for d in mw.group(1).split("x"):
                    win *= int(d)
            lhs = shapes.get(operands[0], "") if operands else ""
            _, ld = _parse_shape(lhs)
            cin = ld[1] if len(ld) > 1 else 1
            st.flops += 2.0 * _shape_elems(out_shape) * win * cin

        base = op
        for c in _COLLECTIVES:
            if base.startswith(c):
                if base.endswith("-done"):
                    break
                st.coll[c] += in_bytes
                break

        if op == "fusion" or op == "call":
            mc2 = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", line)
            if mc2:
                st.calls.append((mc2.group(1), op))
        elif op == "while":
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mcnd = re.search(r"condition=%?([\w\.\-]+)", line)
            if mb and mcnd:
                st.whiles.append((mcnd.group(1), mb.group(1)))
        elif op == "conditional":
            for mm in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                  r"(?:true|false)_computation=%?([\w\.\-]+))",
                                  line):
                names = (mm.group(1) or mm.group(2) or "")
                for nm in names.split(","):
                    nm = nm.strip().lstrip("%")
                    if nm:
                        st.calls.append((nm, "conditional"))
    return st


@dataclasses.dataclass
class HLOCost:
    flops: float
    bytes: float
    collectives: Dict[str, float]

    @property
    def collective_total(self) -> float:
        return sum(self.collectives.values())


def analyze(hlo: str) -> HLOCost:
    comps = split_computations(hlo)
    fusion_dus = {}
    fusion_slices = {}
    for n, ls in comps.items():
        if n == "__entry__":
            continue
        b = find_dus_root_update_bytes(ls)
        if b is not None:
            fusion_dus[n] = b
        sl = fusion_param_slice_reads(ls)
        if sl:
            fusion_slices[n] = sl
    stats = {n: analyze_computation(ls, fusion_dus, fusion_slices)
             for n, ls in comps.items() if n != "__entry__"}
    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def total(name: str, depth=0):
        if name in memo:
            return memo[name]
        if name not in stats or depth > 64:
            return 0.0, 0.0, {c: 0.0 for c in _COLLECTIVES}
        st = stats[name]
        f, b = st.flops, st.bytes
        coll = dict(st.coll)
        for callee, kind in st.calls:
            cf, cb, cc = total(callee, depth + 1)
            f += cf
            # fusion internals never materialize: their HBM traffic is the
            # fusion op's boundary bytes, already counted in this caller
            if kind != "fusion":
                b += cb
            for c in _COLLECTIVES:
                coll[c] += cc[c]
        for cond, body in st.whiles:
            trips = stats[cond].max_constant if cond in stats else 1
            cf, cb, cc = total(body, depth + 1)
            cf2, cb2, cc2 = total(cond, depth + 1)
            f += trips * (cf + cf2)
            b += trips * (cb + cb2)
            for c in _COLLECTIVES:
                coll[c] += trips * (cc[c] + cc2[c])
        memo[name] = (f, b, coll)
        return memo[name]

    entry_name = None
    for n, ls in comps.items():
        if n == "__entry__":
            continue
        if ls and ls[0].startswith("ENTRY"):
            entry_name = n
            break
    if entry_name is None:
        entry_name = max(stats, key=lambda n: stats[n].flops, default=None)
    f, b, coll = total(entry_name) if entry_name else (0.0, 0.0, {})
    return HLOCost(f, b, coll)
