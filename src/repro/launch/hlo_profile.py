"""Dry-run 'profiler': rank the stored HLO's heaviest contributors.

Since there is no real-TPU trace, the profile IS the lowered module: this
tool attributes loop-aware FLOPs / bytes / collective volume to individual
ops (multiplied through the call graph) and prints the top offenders —
the §Perf methodology's replacement for a wall-clock profile.

    PYTHONPATH=src python -m repro.launch.hlo_profile \
        results/dryrun/qwen3-0.6b__decode_32k__single.hlo.zst --top 15
"""
from __future__ import annotations

import argparse
import re
from typing import Dict, List, Tuple

from repro.launch import hlo_analysis as ha


def read_hlo(path: str) -> str:
    """Read an HLO dump; `.zst` files need zstandard, plain text does not."""
    with open(path, "rb") as f:
        data = f.read()
    if path.endswith(".zst"):
        try:
            import zstandard
        except ImportError as e:
            raise ImportError(
                "reading compressed .hlo.zst dumps requires the optional "
                "'zstandard' package; pass an uncompressed .hlo file "
                "instead") from e
        return zstandard.ZstdDecompressor().decompress(data).decode()
    return data.decode()


def op_contributions(hlo: str):
    comps = ha.split_computations(hlo)
    stats = {n: ha.analyze_computation(ls) for n, ls in comps.items()
             if n != "__entry__"}
    fusion_dus = {}
    fusion_slices = {}
    for n, ls in comps.items():
        if n != "__entry__":
            b = ha.find_dus_root_update_bytes(ls)
            if b is not None:
                fusion_dus[n] = b
            sl = ha.fusion_param_slice_reads(ls)
            if sl:
                fusion_slices[n] = sl

    # computation -> (execution multiplier, inside_fusion flag)
    mult: Dict[str, float] = {}
    in_fusion: Dict[str, bool] = {}
    entry = None
    for n, ls in comps.items():
        if n != "__entry__" and ls and ls[0].startswith("ENTRY"):
            entry = n

    def walk(name: str, m: float, fused: bool, depth=0):
        if name not in stats or depth > 64:
            return
        mult[name] = mult.get(name, 0.0) + m
        in_fusion[name] = in_fusion.get(name, True) and fused
        st = stats[name]
        for callee, kind in st.calls:
            walk(callee, m, fused or kind == "fusion", depth + 1)
        for cond, body in st.whiles:
            trips = stats[cond].max_constant if cond in stats else 1
            walk(body, m * trips, fused, depth + 1)
            walk(cond, m * trips, fused, depth + 1)

    if entry:
        walk(entry, 1.0, False)

    rows: List[Tuple[float, float, float, str, str]] = []
    for name, lines in comps.items():
        if name == "__entry__" or name not in mult:
            continue
        m = mult[name]
        fused_ctx = in_fusion.get(name, False)
        shapes: Dict[str, str] = {}
        if lines:
            for pm in ha._PARAM_SIG.finditer(lines[0]):
                shapes[pm.group(1)] = pm.group(2)
        for line in lines[1:]:
            om = ha._OP_RE.match(line)
            if not om:
                continue
            opname, out_shape, op, rest = om.groups()
            shapes[opname] = out_shape
            out_b = ha._shape_bytes(out_shape)
            operands = ha._operand_names(rest)
            in_b = sum(ha._shape_bytes(shapes.get(o, "")) for o in operands)
            fl = 0.0
            by = 0.0
            if op == "dot":
                lhs = shapes.get(operands[0], "") if operands else ""
                _, lhs_dims = ha._parse_shape(lhs)
                mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                k = 1
                if mc and lhs_dims:
                    for d in mc.group(1).split(","):
                        if d and int(d) < len(lhs_dims):
                            k *= lhs_dims[int(d)]
                fl = 2.0 * ha._shape_elems(out_shape) * k
                by = out_b + in_b
            elif op in ha._ELEMENTWISE:
                fl = ha._shape_elems(out_shape)
                by = out_b + in_b
            elif op in ("get-tuple-element", "tuple", "parameter",
                        "constant", "iota", "after-all"):
                continue
            elif op == "dynamic-slice":
                by = 2 * out_b
            elif op == "dynamic-update-slice":
                by = 2 * (ha._shape_bytes(shapes.get(operands[1], ""))
                          if len(operands) > 1 else 0)
            elif op == "fusion":
                mc0 = re.search(r"calls=%?([\w\.\-]+)", line)
                callee = mc0.group(1) if mc0 else None
                if callee in fusion_dus:
                    by = 2 * fusion_dus[callee]
                else:
                    slices = fusion_slices.get(callee, {})
                    eff_in = 0
                    for oi, o in enumerate(operands):
                        eff_in += slices[oi] if oi in slices else \
                            ha._shape_bytes(shapes.get(o, ""))
                    by = out_b + eff_in
            elif op in ("call", "while", "conditional"):
                by = out_b if op == "while" else out_b + in_b
            else:
                by = out_b + in_b
            if fused_ctx:
                by = 0.0  # fusion internals never touch HBM
            coll = 0.0
            for c in ha._COLLECTIVES:
                if op.startswith(c) and not op.endswith("-done"):
                    coll = in_b
            rows.append((fl * m, by * m, coll * m,
                         f"{op} {out_shape[:42]}", f"{name[:28]}×{m:.0f}"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("hlo_path")
    ap.add_argument("--top", type=int, default=12)
    ap.add_argument("--sort", choices=["flops", "bytes", "coll"],
                    default="bytes")
    args = ap.parse_args()
    hlo = read_hlo(args.hlo_path)
    rows = op_contributions(hlo)
    key = {"flops": 0, "bytes": 1, "coll": 2}[args.sort]
    rows.sort(key=lambda r: -r[key])
    tot = [sum(r[i] for r in rows) for i in range(3)]
    print(f"totals: flops={tot[0]:.3e} bytes={tot[1]:.3e} coll={tot[2]:.3e}")
    print(f"{'flops':>10s} {'bytes':>10s} {'coll':>10s}  op")
    for r in rows[:args.top]:
        print(f"{r[0]:10.2e} {r[1]:10.2e} {r[2]:10.2e}  {r[3]}  [{r[4]}]")


if __name__ == "__main__":
    main()
