"""Roofline analysis (§Roofline deliverable).

Reads the dry-run JSONs and derives, per (arch × shape × mesh):

    compute term    = FLOPs_per_device / peak_FLOP/s
    memory term     = bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / (links * link_bw)

(the per-device SPMD module already divides global work by chip count, so
the terms use per-device quantities directly).  Hardware constants: TPU
v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (3D-torus: 3
links usable per axis-aligned collective is conservative; we charge 1
link, the worst case, and note it).

Also reports MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs_global.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / ICI link (1 link charged: worst case)


def roofline_terms(rec: Dict) -> Optional[Dict]:
    if "skipped" in rec:
        return None
    n_dev = rec["n_devices"]
    comp = rec["flops_per_device"] / PEAK_FLOPS
    mem = rec["bytes_per_device"] / HBM_BW
    coll_b = rec["collective_bytes_per_device"]
    coll = sum(v for k, v in coll_b.items() if k != "total") / LINK_BW
    terms = {"compute_s": comp, "memory_s": mem, "collective_s": coll}
    dominant = max(terms, key=terms.get)
    # MODEL_FLOPS: tokens processed globally
    if rec["phase"] == "train":
        tokens = rec["seq_len"] * rec["global_batch"]
        factor = 6.0
    elif rec["phase"] == "prefill":
        tokens = rec["seq_len"] * rec["global_batch"]
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = rec["global_batch"]
        factor = 2.0
    model_flops = factor * rec["active_params"] * tokens
    hlo_global = rec["flops_per_device"] * n_dev
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_ratio": model_flops / hlo_global if hlo_global else 0.0,
        "bound_est_s": max(terms.values()),
    }


def load_results(result_dir: str) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def table(result_dir: str = "results/dryrun") -> str:
    rows = []
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':6s} {'comp(s)':>9s} "
           f"{'mem(s)':>9s} {'coll(s)':>9s} {'dominant':>10s} "
           f"{'useful':>7s}")
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for rec in load_results(result_dir):
        mesh = "multi" if rec.get("multi_pod") else "single"
        if "skipped" in rec:
            rows.append(f"{rec['arch']:26s} {rec['shape']:12s} {mesh:6s} "
                        f"SKIP: {rec['skipped']}")
            continue
        t = roofline_terms(rec)
        rows.append(
            f"{rec['arch']:26s} {rec['shape']:12s} {mesh:6s} "
            f"{t['compute_s']:9.4f} {t['memory_s']:9.4f} "
            f"{t['collective_s']:9.4f} {t['dominant']:>10s} "
            f"{t['useful_ratio']:7.3f}")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    print(table(args.dir))


if __name__ == "__main__":
    main()
