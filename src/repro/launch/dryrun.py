import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks device count at first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) and
extract memory / FLOP / collective statistics for the roofline analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-medium-14b \
        --shape train_4k [--multi-pod] [--out results/dryrun]

Writes one JSON per combination; repro.launch.roofline consumes them.
"""
import argparse
import json
import re
import sys
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.configs.shapes import SHAPES, InputShape, input_specs, skip_reason
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.optim import adam
from repro.parallel.sharding import use_hints

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1,
                "s8": 1, "u8": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[128,1024]' -> bytes."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of every collective op in the (S)HLO text.

    Operand shapes are resolved from each named op's definition."""
    def_shape: Dict[str, str] = {}
    defn = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
                      r"(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)")
    for line in hlo_text.splitlines():
        m = defn.match(line)
        if m:
            def_shape[m.group(1)] = m.group(2)
    out: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    op_re = re.compile(r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
                       r"([a-z\-]+)(?:-start|-done)?\(([^)]*)\)")
    for line in hlo_text.splitlines():
        m = op_re.search(line)
        if not m:
            continue
        _, op, operands = m.groups()
        base = op
        for c in _COLLECTIVES:
            if base.startswith(c):
                base = c
                break
        else:
            continue
        if "-done" in line.split("(")[0]:
            continue  # count the -start, not the -done
        total = 0
        for operand in operands.split(","):
            name = operand.strip().lstrip("%")
            name = name.split(" ")[-1].lstrip("%")
            shape = def_shape.get(name)
            if shape:
                if shape.startswith("("):
                    for part in re.findall(r"[a-z0-9]+\[[0-9,]*\]", shape):
                        total += _shape_bytes(part)
                else:
                    total += _shape_bytes(shape)
        out[base] += total
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               verbose: bool = True, save_hlo: Optional[str] = None) -> Dict:
    cfg = archs.get(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name,
                "multi_pod": multi_pod, "skipped": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    params_shape = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    p_shardings = steps_mod.param_shardings(mesh, params_shape)
    batch_shape = input_specs(cfg, shape)
    b_shardings = steps_mod.batch_shardings(mesh, batch_shape, shape)
    rules = steps_mod.activation_rules(mesh, shape)

    if shape.phase == "train":
        opt_cfg = adam.AdamConfig(
            moment_dtype="bfloat16" if cfg.param_count() > 1e11
            else "float32")
        opt_shape = jax.eval_shape(
            lambda: adam.init_adam_state(params_shape, opt_cfg))
        o_shardings = steps_mod.opt_shardings(mesh, params_shape)
        step = steps_mod.make_train_step(cfg, opt_cfg)
        in_sh = (p_shardings, o_shardings, b_shardings)
        out_sh = (p_shardings, o_shardings, None)
        args = (params_shape, opt_shape, batch_shape)
    elif shape.phase == "prefill":
        step = steps_mod.make_prefill_step(cfg, long_mode=shape.long_mode,
                                           max_cache_len=shape.seq_len)
        in_sh = (p_shardings, b_shardings)
        out_sh = None
        args = (params_shape, batch_shape)
    else:  # decode
        step = steps_mod.make_serve_step(cfg, long_mode=shape.long_mode)
        cache_sh = b_shardings["cache"]
        in_sh = (p_shardings, b_shardings)
        out_sh = (None, cache_sh)
        args = (params_shape, batch_shape)

    donate = (1,) if shape.phase == "decode" else ()
    with mesh, use_hints(rules):
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    n_dev = mesh.devices.size
    if save_hlo:
        import zstandard
        os.makedirs(save_hlo, exist_ok=True)
        tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
        with open(os.path.join(save_hlo, tag + ".hlo.zst"), "wb") as f:
            f.write(zstandard.ZstdCompressor(level=9)
                    .compress(hlo.encode()))

    # loop-aware per-device analysis (XLA cost_analysis counts while
    # bodies once; see hlo_analysis module docstring)
    from repro.launch import hlo_analysis
    la = hlo_analysis.analyze(hlo)

    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "n_devices": int(n_dev),
        "compile_s": round(time.time() - t0, 1),
        "flops_xla_raw": float(cost.get("flops", 0.0)),
        "flops_per_device": float(la.flops),
        "bytes_per_device": float(la.bytes),
        "collective_bytes_per_device": {k: float(v)
                                        for k, v in la.collectives.items()},
        "collective_bytes_raw": {k: float(v) for k, v in coll.items()},
        "params": int(cfg.param_count()),
        "active_params": int(cfg.active_param_count()),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "phase": shape.phase,
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                result[attr] = int(v)
    if verbose:
        print(json.dumps(result, indent=2))
        print(f"memory_analysis: {mem}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-save-hlo", action="store_true")
    args = ap.parse_args()

    result = dryrun_one(args.arch, args.shape, args.multi_pod,
                        save_hlo=None if args.no_save_hlo else args.out)
    os.makedirs(args.out, exist_ok=True)
    tag = f"{args.arch}__{args.shape}__{'multi' if args.multi_pod else 'single'}"
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}/{tag}.json")
    if "skipped" in result:
        print(f"SKIP: {result['skipped']}")


if __name__ == "__main__":
    main()
