"""train_step / prefill_step / serve_step builders with mesh shardings.

These are the functions the dry-run lowers for every (arch × shape × mesh)
and the real drivers (train.py / serve.py) execute on host meshes.
"""
from __future__ import annotations

import functools
import re
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import InputShape
from repro.launch.mesh import batch_spec, data_axes
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adam
from repro.parallel import sharding as sh


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(params, cfg: ModelConfig, batch, *, long_mode=False,
            remat=True):
    out = T.forward(params, cfg, batch, long_mode=long_mode, remat=remat)
    logits = out["logits"].astype(jnp.float32)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = float(nll.size)
    return nll.sum() / denom + out["aux_loss"]


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: Optional[adam.AdamConfig]
                    = None, *, remat: bool = True):
    opt_cfg = opt_cfg or adam.AdamConfig()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch, remat=remat))(params)
        new_params, new_opt, om = adam.adam_update(
            params, grads, opt_state, opt_cfg)
        return new_params, new_opt, {"loss": loss, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, *, long_mode: bool = False,
                      max_cache_len: Optional[int] = None):
    def prefill_step(params, batch):
        out = T.forward(params, cfg, batch, long_mode=long_mode,
                        return_cache=True, max_cache_len=max_cache_len,
                        remat=False)
        return out["logits"][:, -1], out["cache"]
    return prefill_step


def make_serve_step(cfg: ModelConfig, *, long_mode: bool = False):
    def serve_step(params, batch):
        logits, cache = T.decode_step(params, cfg, batch["tokens"],
                                      batch["cache"], long_mode=long_mode)
        return logits, cache
    return serve_step


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------

def _map_axes(spec: P, mesh) -> P:
    """Translate the canonical ('data','model') specs onto this mesh:
    on a multi-pod mesh, 'data' stays the within-pod axis (params are
    replicated across pods — pure DP over 'pod', DESIGN.md §4)."""
    return P(*(list(spec) + [])[:])


def param_shardings(mesh, params_shape):
    specs = sh.param_tree_specs(params_shape)
    return sh.named_shardings(mesh, specs, params_shape)


def opt_shardings(mesh, params_shape):
    pspecs = sh.param_tree_specs(params_shape)
    step = NamedSharding(mesh, P())
    mk = lambda: sh.named_shardings(mesh, pspecs, params_shape)
    return {"step": step, "m": mk(), "v": mk()}


def batch_shardings(mesh, batch_tree, shape: InputShape):
    """tokens/labels/masks: batch over data axes; cache/state leaves per
    name-specific rules (KV cache, RWKV wkv state, Mamba conv/ssm state)."""
    da = data_axes(mesh)
    data = da if len(da) > 1 else da[0]
    b1 = shape.global_batch == 1          # long-context decode
    bspec = None if b1 else data

    def spec_for(path, leaf):
        name = sh.path_str(path)
        r = leaf.ndim
        if "cache" in name:
            if name.endswith("pos"):
                return P()
            if re.search(r"/(k|v)$", name):          # [R, B, L, KV, hd]
                # Sequence-shard LARGE caches over `model` (flash-decoding
                # style): KV-head counts rarely divide the model axis, and
                # head-replicated caches force whole-cache reshards (§Perf
                # iteration 1).  Small ring-buffer (sliding-window) caches
                # stay replicated over `model`: their dynamic-slot updates
                # across a sharded sequence cost more than the reads save.
                # batch=1 long-context also shards the sequence over `data`.
                L = leaf.shape[2]
                if L <= 8192:
                    return P(None, bspec, None, None, None)
                if b1:
                    return P(None, None, (*([data] if isinstance(data, str)
                                            else list(data)), "model"),
                             None, None)
                return P(None, data, "model", None, None)
            if name.endswith("wkv"):                  # [R, B, H, N, N]
                return P(None, bspec, "model", None, None)
            if name.endswith("ssm"):                  # [R, B, di, ds]
                return P(None, bspec, "model", None)
            if name.endswith("conv"):                 # [R, B, dc-1, di]
                return P(None, bspec, None, "model")
            if r == 3:                                # shift states [R,B,d]
                return P(None, bspec, None)
            return P(*([None] * r))
        if r == 0:
            return P()
        return P(*([bspec] + [None] * (r - 1)))

    specs = jax.tree_util.tree_map_with_path(spec_for, batch_tree)
    return sh.named_shardings(mesh, specs, batch_tree)


def activation_rules(mesh, shape: InputShape):
    da = data_axes(mesh)
    return sh.default_activation_rules(
        data_axes=da, model_axis="model",
        seq_shard=(shape.phase == "train"))
