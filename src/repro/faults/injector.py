"""Deterministic fault injection on the engine's iteration clock.

A ``FaultPlan`` is a seeded, sorted list of ``FaultEvent``s; a
``FaultInjector`` binds one to a running ``Engine`` and fires each event
at its iteration.  Two families:

*Undeclared degradations* (the environment silently gets worse — the
topology the scheduler believes in is deliberately NOT updated):

- ``link_throttle``   selected links lose bandwidth / gain latency.
  Measured task durations are dilated by the cost-model ratio of the
  *hidden* degraded topology over the believed one, for the live plan —
  so only tasks whose communication actually crosses the throttled
  links slow down, and a replanned placement that avoids them runs at
  full speed again.  Detection must come from the
  ``DivergenceMonitor``, exactly the ROADMAP's reactive-elasticity
  remainder.
- ``device_slowdown`` a device class loses compute/HBM throughput
  (same hidden-cost-ratio dilation).
- ``straggler``       one task dilates by a flat factor for a window.

*Hard failures* (the execution path must retry / escalate / recover):

- ``transient_crash`` a task raises ``TransientTaskFault`` on its first
  ``n_failures`` attempts of the fire iteration, then succeeds —
  exercising the engine's bounded retry.
- ``permanent_crash`` a task raises ``PermanentTaskFault`` every
  attempt; carries the device ids presumed dead so the caller can
  escalate to ``drop_devices`` → forced replan.  Cleared automatically
  once the engine moves to a new plan epoch (the replan "replaced" the
  dead worker).
- ``device_drop``     like ``permanent_crash`` but keyed on explicit
  device ids: any task scheduled on them fails permanently.
- ``slot_failure``    genserve decode slots die mid-wave at given round
  indices; the decoder requeues the in-flight requests.
- ``ckpt_fail``       checkpoint writes raise ``TransientError`` for
  the first ``n_failures`` attempts (or every attempt if
  ``n_failures < 0``), exercising elastic checkpoint retry and the
  warn-and-continue degradation.
- ``ckpt_corrupt``    the next checkpoint file written is corrupted
  in place after the write, exercising the crc32 + ``load_latest``
  fallback chain.

Every fired event appends to ``FaultInjector.log`` — the deterministic
record the fault-determinism tests compare across seeded runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import retry
from repro.core import topology as topo_mod
from repro.core.costmodel import CostModel
from repro.obs import metrics as obs_metrics

FAULT_KINDS = ("link_throttle", "device_slowdown", "straggler",
               "transient_crash", "permanent_crash", "device_drop",
               "slot_failure", "ckpt_fail", "ckpt_corrupt")


class TransientTaskFault(retry.TransientError):
    """An injected task failure expected to succeed on retry."""

    def __init__(self, task: int, name: str, attempt: int):
        super().__init__(f"injected transient fault: task {task} "
                         f"({name}), attempt {attempt}")
        self.task = task
        self.task_name = name
        self.attempt = attempt


class PermanentTaskFault(retry.PermanentError):
    """An injected task failure retrying cannot fix; ``devices`` names
    the workers presumed dead (escalate to drop + replan)."""

    def __init__(self, task: int, name: str,
                 devices: Tuple[int, ...]):
        super().__init__(f"injected permanent fault: task {task} "
                         f"({name}), devices {list(devices)} presumed dead")
        self.task = task
        self.task_name = name
        self.devices = devices


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``at`` is the first engine iteration it is
    active; ``until`` the first iteration it is no longer active (None =
    forever).  Which other fields matter depends on ``kind`` (see module
    docstring)."""
    kind: str
    at: int
    until: Optional[int] = None
    task: Optional[int] = None            # crash/straggler target
    devices: Tuple[int, ...] = ()         # device_drop / permanent_crash
    device_class: Optional[str] = None    # device_slowdown
    factor: float = 1.0                   # severity (dilation / slowdown)
    bw_factor: float = 1.0                # link_throttle
    lat_factor: float = 1.0
    regions: Optional[Tuple[str, ...]] = None
    pairs: Optional[Tuple[Tuple[int, int], ...]] = None
    fraction: float = 1.0
    n_failures: int = 1                   # transient/ckpt failure count
    slot_rounds: Tuple[Tuple[int, Tuple[int, ...]], ...] = ()
    note: str = ""

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"options: {list(FAULT_KINDS)}")

    def active(self, iteration: int) -> bool:
        return self.at <= iteration and \
            (self.until is None or iteration < self.until)

    def describe(self) -> Dict[str, Any]:
        d = {"kind": self.kind, "at": self.at}
        if self.until is not None:
            d["until"] = self.until
        for k in ("task", "device_class", "note"):
            v = getattr(self, k)
            if v not in (None, ""):
                d[k] = v
        if self.devices:
            d["devices"] = list(self.devices)
        if self.kind in ("straggler", "device_slowdown"):
            d["factor"] = self.factor
        if self.kind == "link_throttle":
            d["bw_factor"] = self.bw_factor
            d["lat_factor"] = self.lat_factor
        if self.kind in ("transient_crash", "ckpt_fail"):
            d["n_failures"] = self.n_failures
        if self.slot_rounds:
            d["slot_rounds"] = [[r, list(s)] for r, s in self.slot_rounds]
        return d


@dataclasses.dataclass
class FaultPlan:
    """A deterministic, iteration-sorted fault schedule."""
    events: List[FaultEvent]
    seed: int = 0

    def __post_init__(self):
        self.events = sorted(self.events, key=lambda e: (e.at, e.kind))

    def describe(self) -> List[Dict[str, Any]]:
        return [e.describe() for e in self.events]

    @classmethod
    def generate(cls, seed: int, *, n_events: int = 3,
                 first_iteration: int = 2, every: int = 3,
                 n_tasks: int = 4, window: int = 2) -> "FaultPlan":
        """Seeded chaos mix: ``n_events`` draws over the recoverable
        fault kinds with random targets/severities.  Same seed ⇒ same
        plan, byte for byte — the determinism contract the fault tests
        pin down."""
        rng = np.random.default_rng(seed)
        kinds = ["link_throttle", "device_slowdown", "straggler",
                 "transient_crash"]
        events = []
        it = first_iteration
        for _ in range(n_events):
            kind = kinds[int(rng.integers(len(kinds)))]
            task = int(rng.integers(n_tasks))
            if kind == "link_throttle":
                events.append(FaultEvent(
                    kind, it, until=it + window,
                    bw_factor=float(rng.uniform(0.02, 0.2)),
                    lat_factor=float(rng.uniform(5.0, 20.0)),
                    fraction=float(rng.uniform(0.5, 1.0)),
                    note="chaos"))
            elif kind == "device_slowdown":
                events.append(FaultEvent(
                    kind, it, until=it + window,
                    device_class=["A100", "L4", "L40S"][
                        int(rng.integers(3))],
                    factor=float(rng.uniform(0.2, 0.6)), note="chaos"))
            elif kind == "straggler":
                events.append(FaultEvent(
                    kind, it, until=it + 1, task=task,
                    factor=float(rng.uniform(1.5, 4.0)), note="chaos"))
            else:
                events.append(FaultEvent(
                    kind, it, until=it + 1, task=task,
                    n_failures=int(rng.integers(1, 3)), note="chaos"))
            it += every
        return cls(events, seed=seed)


class FaultInjector:
    """Binds a ``FaultPlan`` to an ``Engine`` and fires its events on
    the engine's iteration clock.  All state is derived from the plan +
    the engine's public iteration/epoch counters, so two runs with the
    same seed produce the same ``log``."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.engine = None
        self.log: List[Dict[str, Any]] = []
        self._iter = -1
        self._active: List[FaultEvent] = []
        self._announced: set = set()
        self._consumed: set = set()        # permanent events already healed
        self._fired_perm: Dict[int, int] = {}  # event idx -> epoch fired in
        self._dilation_cache: Optional[tuple] = None
        self._ckpt_attempts: Dict[int, int] = {}

    # -- lifecycle -----------------------------------------------------
    def bind(self, engine) -> "FaultInjector":
        self.engine = engine
        return self

    def begin_iteration(self, iteration: int) -> None:
        """Advance the fault clock; called by the engine at the top of
        every ``run_iteration``."""
        self._iter = iteration
        epoch = self.engine.ctx.epoch if self.engine is not None else 0
        # a plan swap heals fired permanent faults: the dead worker is no
        # longer part of the plan, so the event stops firing
        for idx, fired_epoch in list(self._fired_perm.items()):
            if epoch != fired_epoch:
                self._consumed.add(idx)
                del self._fired_perm[idx]
        self._active = [e for i, e in enumerate(self.plan.events)
                        if e.active(iteration) and i not in self._consumed]
        for i, e in enumerate(self.plan.events):
            if e.active(iteration) and i not in self._announced:
                self._announced.add(i)
                self._record("activate", e)

    def _record(self, what: str, event: FaultEvent, **extra) -> None:
        entry = {"what": what, "iteration": self._iter}
        entry.update(event.describe())
        entry.update(extra)
        self.log.append(entry)
        obs_metrics.counter(f"faults.{event.kind}").inc()

    # -- hidden environment (undeclared degradations) ------------------
    def hidden_topology(self, base) -> Any:
        """The environment's TRUE topology: ``base`` with every active
        undeclared degradation applied.  The engine/scheduler never see
        this directly — it exists to price dilation and to let
        benchmarks evaluate plans against ground truth."""
        topo = base
        for e in self._active:
            if e.kind == "link_throttle":
                topo = topo_mod.degrade_links(
                    topo, bw_factor=e.bw_factor, lat_factor=e.lat_factor,
                    pairs=list(e.pairs) if e.pairs else None,
                    regions=list(e.regions) if e.regions else None,
                    fraction=e.fraction, seed=self.plan.seed)
            elif e.kind == "device_slowdown":
                topo = topo_mod.scale_compute(
                    topo, e.factor, device_class=e.device_class,
                    ids=list(e.devices) if e.devices else None)
        return topo

    def dilation(self, task: int) -> float:
        """Replay-time dilation factor (≥ 1) for ``task`` under the
        currently active undeclared degradations.  Link throttles and
        device slowdowns dilate by the cost-model ratio hidden/believed
        for the live plan (so a post-replan placement that avoids the
        damage stops dilating); stragglers dilate by their flat factor."""
        d = 1.0
        for e in self._active:
            if e.kind == "straggler" and (e.task is None or e.task == task):
                d *= max(e.factor, 1.0)
        if self.engine is None or self.engine.topo is None:
            return d
        if any(e.kind in ("link_throttle", "device_slowdown")
               for e in self._active):
            d *= self._cost_ratio(task)
        return d

    def _cost_ratio(self, task: int) -> float:
        eng = self.engine
        key = (eng.ctx.epoch,
               tuple(id(e) for e in self._active
                     if e.kind in ("link_throttle", "device_slowdown")))
        if self._dilation_cache is None or self._dilation_cache[0] != key:
            believed = eng.topo
            hidden = self.hidden_topology(believed)
            cm_b = CostModel(believed, eng.wf)
            cm_h = CostModel(hidden, eng.wf)
            ratios = {}
            for t in range(eng.wf.n_tasks):
                base = cm_b.task_cost(eng.plan, t).total
                degr = cm_h.task_cost(eng.plan, t).total
                ratios[t] = max(degr / base, 1.0) if base > 0 else 1.0
            self._dilation_cache = (key, ratios)
        return self._dilation_cache[1].get(task, 1.0)

    # -- hard failures --------------------------------------------------
    def before_task(self, task: int, attempt: int) -> None:
        """Raise the scheduled fault for ``task`` (if any) just before
        the engine runs its executor on ``attempt`` (0-based)."""
        eng = self.engine
        name = eng.wf.task(task).name if eng is not None else str(task)
        for i, e in enumerate(self.plan.events):
            if i in self._consumed or not e.active(self._iter):
                continue
            if e.kind == "transient_crash" and e.task == task:
                if attempt < e.n_failures:
                    self._record("raise_transient", e, attempt=attempt)
                    raise TransientTaskFault(task, name, attempt)
            elif e.kind == "permanent_crash" and e.task == task:
                devices = e.devices or self._default_dead_devices(task)
                self._fired_perm[i] = eng.ctx.epoch if eng else 0
                self._record("raise_permanent", e,
                             dead_devices=list(devices))
                raise PermanentTaskFault(task, name, tuple(devices))
            elif e.kind == "device_drop":
                assigned = set()
                if eng is not None:
                    assigned = {int(d) for d in
                                eng.plan.assignment[task].reshape(-1)}
                dead = tuple(sorted(assigned & set(e.devices)))
                if dead:
                    self._fired_perm[i] = eng.ctx.epoch if eng else 0
                    self._record("raise_permanent", e,
                                 dead_devices=list(dead))
                    raise PermanentTaskFault(task, name, dead)

    def _default_dead_devices(self, task: int) -> Tuple[int, ...]:
        """A permanent crash with no explicit devices kills the highest-
        id worker assigned to the task (one dead replica, the smallest
        escalation that still forces a replan)."""
        if self.engine is None:
            return ()
        devs = [int(d) for d in
                self.engine.plan.assignment[task].reshape(-1)]
        return (max(devs),) if devs else ()

    # -- genserve slot failures -----------------------------------------
    def gen_slot_failures(self) -> Optional[Dict[int, List[int]]]:
        """Decode-round -> slot-ids map for active slot_failure events
        (consumed by ``genserve.serve``); None when none are active."""
        out: Dict[int, List[int]] = {}
        for i, e in enumerate(self.plan.events):
            if e.kind != "slot_failure" or i in self._consumed \
                    or not e.active(self._iter):
                continue
            for rnd, slots in e.slot_rounds:
                out.setdefault(int(rnd), []).extend(int(s) for s in slots)
            self._consumed.add(i)          # fire once per activation
            self._record("slot_failure", e)
        return out or None

    # -- checkpoint faults ----------------------------------------------
    def maybe_fail_checkpoint(self, attempt: int) -> None:
        """Raise ``retry.TransientError`` for active ckpt_fail events:
        the first ``n_failures`` attempts fail (every attempt when
        ``n_failures < 0`` — the persistently-broken path)."""
        for i, e in enumerate(self.plan.events):
            if e.kind != "ckpt_fail" or i in self._consumed \
                    or not e.active(self._iter):
                continue
            seen = self._ckpt_attempts.get(i, 0)
            self._ckpt_attempts[i] = seen + 1
            if e.n_failures < 0 or seen < e.n_failures:
                self._record("ckpt_fail", e, attempt=attempt)
                raise retry.TransientError(
                    f"injected checkpoint write failure (attempt {attempt})")

    def maybe_corrupt_checkpoint(self, path: str) -> bool:
        """Corrupt the just-written checkpoint at ``path`` in place (one
        active ckpt_corrupt event fires once).  Returns True if the file
        was damaged."""
        import os
        for i, e in enumerate(self.plan.events):
            if e.kind != "ckpt_corrupt" or i in self._consumed \
                    or not e.active(self._iter):
                continue
            self._consumed.add(i)
            try:
                size = os.path.getsize(path)
                with open(path, "r+b") as f:
                    # flip bytes in the middle of the payload
                    f.seek(size // 2)
                    chunk = f.read(16)
                    f.seek(size // 2)
                    f.write(bytes(b ^ 0xFF for b in chunk))
            except OSError:
                return False
            self._record("ckpt_corrupt", e, path=path)
            return True
        return False

    # -- reporting -------------------------------------------------------
    def fired(self, kind: Optional[str] = None) -> List[Dict[str, Any]]:
        return [r for r in self.log
                if kind is None or r["kind"] == kind]
