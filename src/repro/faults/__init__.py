"""Fault-injection harness: seeded fault plans fired on the engine's
iteration clock, plus the exceptions the execution path hardens against.

    from repro.faults import FaultPlan, FaultInjector, fault_scenario

    inj = FaultInjector(fault_scenario("link_throttle", at=4))
    engine.attach_fault_injector(inj)

See ``repro.faults.injector`` for the event catalogue and
``repro.faults.scenarios`` for the named scenarios the launcher's
``--fault`` flag accepts.
"""
from repro.faults.injector import (FAULT_KINDS, FaultEvent, FaultInjector,
                                   FaultPlan, PermanentTaskFault,
                                   TransientTaskFault)
from repro.faults.scenarios import FAULT_SCENARIOS, fault_scenario

__all__ = ["FAULT_KINDS", "FAULT_SCENARIOS", "FaultEvent", "FaultInjector",
           "FaultPlan", "PermanentTaskFault", "TransientTaskFault",
           "fault_scenario"]
