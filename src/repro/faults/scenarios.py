"""Named fault scenarios for the launcher / benchmarks / CI.

``fault_scenario(name, ...)`` returns a ``FaultPlan``; compose with a
declared ``--drift`` schedule freely — the injector only dilates /
raises, the drift schedule only feeds topologies, and both key on the
same engine iteration clock.
"""
from __future__ import annotations

from typing import Optional

from repro.core.topology import Topology
from repro.faults.injector import FaultEvent, FaultPlan

FAULT_SCENARIOS = ["link_throttle", "device_slowdown", "straggler",
                   "transient_crash", "permanent_crash", "device_drop",
                   "slot_failure", "ckpt_fail", "ckpt_flaky",
                   "ckpt_corrupt", "chaos"]


def fault_scenario(name: str, *, at: int = 3, until: Optional[int] = None,
                   seed: int = 0, topo: Optional[Topology] = None,
                   gen_task: int = 0, train_task: int = 1,
                   n_tasks: int = 4) -> FaultPlan:
    """Build the named ``FaultPlan``:

      link_throttle   — undeclared: cross-machine links /20 bw, ×10 lat
                        from ``at`` on (detected only via divergence);
      device_slowdown — undeclared: L4-class compute/HBM ×0.3 from
                        ``at``;
      straggler       — GEN task dilates ×3 for two iterations;
      transient_crash — train task fails twice at ``at`` then succeeds
                        (bounded retry absorbs it);
      permanent_crash — train task fails every attempt at ``at``; the
                        highest-id assigned worker is presumed dead
                        (escalate: drop + forced replan);
      device_drop     — the last device dies at ``at``: any task
                        scheduled on it fails permanently;
      slot_failure    — two decode slots die at round 2 of the GEN wave
                        at iteration ``at`` (requests requeue);
      ckpt_fail       — checkpoint writes fail twice then succeed;
      ckpt_flaky      — checkpoint writes fail persistently (warn-and-
                        continue degradation);
      ckpt_corrupt    — the next checkpoint written at/after ``at`` is
                        corrupted on disk (load_latest must fall back);
      chaos           — seeded mix via ``FaultPlan.generate``.
    """
    if name == "link_throttle":
        return FaultPlan([FaultEvent(
            "link_throttle", at, until=until, bw_factor=0.05,
            lat_factor=10.0, note="undeclared cross-machine throttle")],
            seed=seed)
    if name == "device_slowdown":
        return FaultPlan([FaultEvent(
            "device_slowdown", at, until=until, device_class="L4",
            factor=0.3, note="undeclared L4 thermal throttle")], seed=seed)
    if name == "straggler":
        return FaultPlan([FaultEvent(
            "straggler", at, until=until if until is not None else at + 2,
            task=gen_task, factor=3.0, note="GEN straggler")], seed=seed)
    if name == "transient_crash":
        return FaultPlan([FaultEvent(
            "transient_crash", at, until=at + 1, task=train_task,
            n_failures=2, note="train step crashes twice")], seed=seed)
    if name == "permanent_crash":
        return FaultPlan([FaultEvent(
            "permanent_crash", at, task=train_task,
            note="train worker dies")], seed=seed)
    if name == "device_drop":
        devices = (topo.n - 1,) if topo is not None else ()
        return FaultPlan([FaultEvent(
            "device_drop", at, devices=devices,
            note=f"device {list(devices)} dies")], seed=seed)
    if name == "slot_failure":
        return FaultPlan([FaultEvent(
            "slot_failure", at, until=at + 1,
            slot_rounds=((2, (0, 1)),),
            note="two decode slots die at round 2")], seed=seed)
    if name == "ckpt_fail":
        return FaultPlan([FaultEvent(
            "ckpt_fail", at, until=until, n_failures=2,
            note="checkpoint write fails twice")], seed=seed)
    if name == "ckpt_flaky":
        return FaultPlan([FaultEvent(
            "ckpt_fail", at, until=until, n_failures=-1,
            note="checkpoint path persistently broken")], seed=seed)
    if name == "ckpt_corrupt":
        return FaultPlan([FaultEvent(
            "ckpt_corrupt", at, until=until,
            note="checkpoint corrupted on disk")], seed=seed)
    if name == "chaos":
        return FaultPlan.generate(seed, first_iteration=at,
                                  n_tasks=n_tasks)
    raise ValueError(f"unknown fault scenario {name!r}; "
                     f"options: {FAULT_SCENARIOS}")
