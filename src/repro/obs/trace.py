"""Structured span tracer with Chrome-trace export.

Spans are nestable per thread and exception-safe::

    from repro.obs import trace
    with trace.span("gen.round", wave=i) as sp:
        ...
        sp.set("admitted", n)

When tracing is disabled (the default) ``span`` returns a shared no-op
span: the cost is one attribute read and one method call, so
instrumentation can stay unconditionally in hot host loops.  Enabled
spans cost two ``perf_counter_ns`` reads and two list appends on a
per-thread buffer (no lock on the hot path).

Export formats:

* ``export_chrome(path)`` — Chrome-trace/Perfetto JSON (``ph: B/E``
  duration events, microsecond timestamps).  Load in
  ``chrome://tracing`` or https://ui.perfetto.dev.
* ``report()`` — human summary table aggregated per span name.

``validate_chrome`` schema-checks a trace object (required fields,
per-thread monotonic timestamps, matched B/E nesting); ``python -m
repro.obs.trace <file>`` runs it from the command line (CI uses this on
smoke-emitted traces).

Environment: ``REPRO_TRACE=<path>`` enables tracing at import time and
registers an atexit hook exporting to ``<path>`` (``REPRO_TRACE=1``
exports to ``trace.json``); ``REPRO_TRACE=0`` / unset leaves tracing
off.
"""
from __future__ import annotations

import atexit
import itertools
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["span", "enable", "disable", "reset", "is_enabled",
           "export_chrome", "report", "validate_chrome", "Tracer"]


class _NullSpan:
    """Shared do-nothing span handed out while tracing is disabled."""

    __slots__ = ()
    id = 0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, key: str, value) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Span:
    """One live span: a context manager recording [t0, t1) on exit."""

    __slots__ = ("name", "args", "id", "parent_id", "_tracer", "_buf",
                 "t0_ns", "t1_ns", "seq_b")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self.name = name
        self.args = args
        self._tracer = tracer
        self.id = next(tracer._ids)
        self.parent_id = 0
        self.t0_ns = 0
        self.t1_ns = 0
        self.seq_b = 0

    def set(self, key: str, value) -> None:
        self.args[key] = value

    def __enter__(self) -> "Span":
        tr = self._tracer
        buf = tr._thread_buffer()
        stack = buf.stack
        self.parent_id = stack[-1].id if stack else 0
        stack.append(self)
        self._buf = buf
        self.seq_b = next(tr._seq)
        self.t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.t1_ns = time.perf_counter_ns()
        seq_e = next(self._tracer._seq)
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        buf = self._buf
        # pop self even if a child span leaked (exception unwound past it)
        while buf.stack and buf.stack[-1] is not self:
            buf.stack.pop()
        if buf.stack:
            buf.stack.pop()
        buf.records.append((self.name, self.id, self.parent_id,
                            self.t0_ns, self.t1_ns, self.seq_b, seq_e,
                            self.args))
        return False


class _ThreadBuffer:
    __slots__ = ("tid", "stack", "records")

    def __init__(self, tid: int):
        self.tid = tid
        self.stack: List[Span] = []
        self.records: List[tuple] = []


class Tracer:
    """Span recorder.  One process-wide instance (``repro.obs.trace``
    module functions) is the normal entry point; tests may instantiate
    their own."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._ids = itertools.count(1)
        self._seq = itertools.count(1)
        self._lock = threading.Lock()
        self._local = threading.local()
        self._buffers: List[_ThreadBuffer] = []
        self._epoch_ns = time.perf_counter_ns()

    # -- recording -------------------------------------------------------
    def _thread_buffer(self) -> _ThreadBuffer:
        buf = getattr(self._local, "buf", None)
        if buf is None:
            buf = _ThreadBuffer(threading.get_ident())
            self._local.buf = buf
            with self._lock:
                self._buffers.append(buf)
        return buf

    def span(self, name: str, **args):
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, name, args)

    def current_span_id(self) -> int:
        """Id of the innermost live span on this thread (0 when none)."""
        if not self.enabled:
            return 0
        buf = getattr(self._local, "buf", None)
        if buf is None or not buf.stack:
            return 0
        return buf.stack[-1].id

    # -- control ---------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        with self._lock:
            for buf in self._buffers:
                buf.records.clear()
                buf.stack.clear()
        self._epoch_ns = time.perf_counter_ns()

    # -- export ----------------------------------------------------------
    def _all_records(self) -> List[Tuple[int, tuple]]:
        """(tid, record) for every finished span, across threads."""
        with self._lock:
            return [(buf.tid, rec) for buf in self._buffers
                    for rec in list(buf.records)]

    def n_spans(self) -> int:
        return len(self._all_records())

    def chrome_events(self) -> List[Dict[str, Any]]:
        """Chrome-trace B/E duration events.  Events are ordered by the
        global begin/end sequence numbers taken while recording, which
        reproduces the true push/pop interleaving — per-thread nesting
        is valid by construction and timestamps are non-decreasing per
        thread (perf_counter is monotonic)."""
        pid = os.getpid()
        epoch = self._epoch_ns
        seq_events: List[Tuple[int, Dict[str, Any]]] = []
        for tid, (name, sid, parent, t0, t1, seq_b, seq_e, args) in \
                self._all_records():
            common = {"name": name, "cat": name.split(".")[0],
                      "pid": pid, "tid": tid}
            ev_args = {"span_id": sid}
            if parent:
                ev_args["parent_id"] = parent
            for k, v in args.items():
                ev_args[k] = v if isinstance(v, (int, float, str, bool,
                                                 type(None))) else repr(v)
            seq_events.append((seq_b, dict(common, ph="B",
                                           ts=(t0 - epoch) / 1e3,
                                           args=ev_args)))
            seq_events.append((seq_e, dict(common, ph="E",
                                           ts=(t1 - epoch) / 1e3)))
        seq_events.sort(key=lambda p: p[0])
        return [ev for _, ev in seq_events]

    def export_chrome(self, path: str) -> str:
        obj = {"traceEvents": self.chrome_events(),
               "displayTimeUnit": "ms"}
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(obj, f)
        return path

    def report(self) -> str:
        """Per-span-name aggregate: count, total/mean/max milliseconds."""
        agg: Dict[str, List[float]] = {}
        for _tid, (name, *_rest) in self._all_records():
            t0, t1 = _rest[2], _rest[3]
            agg.setdefault(name, []).append((t1 - t0) / 1e6)
        if not agg:
            return "(no spans recorded)"
        rows = sorted(((sum(v), name, v) for name, v in agg.items()),
                      reverse=True)
        w = max(len(name) for _, name, _ in rows)
        out = [f"{'span':<{w}}  {'count':>6}  {'total_ms':>10}  "
               f"{'mean_ms':>9}  {'max_ms':>9}"]
        for total, name, v in rows:
            out.append(f"{name:<{w}}  {len(v):>6}  {total:>10.2f}  "
                       f"{total / len(v):>9.3f}  {max(v):>9.3f}")
        return "\n".join(out)


# ---------------------------------------------------------------------------
# Module-level singleton
# ---------------------------------------------------------------------------

TRACER = Tracer()


def span(name: str, **args):
    return TRACER.span(name, **args)


def current_span_id() -> int:
    return TRACER.current_span_id()


def enable() -> None:
    TRACER.enable()


def disable() -> None:
    TRACER.disable()


def reset() -> None:
    TRACER.reset()


def is_enabled() -> bool:
    return TRACER.enabled


def export_chrome(path: str) -> str:
    return TRACER.export_chrome(path)


def report() -> str:
    return TRACER.report()


# ---------------------------------------------------------------------------
# Validation (schema check for CI and tests)
# ---------------------------------------------------------------------------

_VALID_PH = {"B", "E", "X", "M", "C", "i", "I"}


def validate_chrome(obj) -> List[str]:
    """Schema-check a Chrome-trace object; returns a list of problems
    (empty = valid).  Checks: traceEvents list present, required fields
    per event, known phase, per-(pid, tid) non-decreasing timestamps and
    matched B/E pairs with identical names."""
    errors: List[str] = []
    if isinstance(obj, list):
        events = obj
    elif isinstance(obj, dict) and isinstance(obj.get("traceEvents"), list):
        events = obj["traceEvents"]
    else:
        return ["trace must be a list or a dict with a traceEvents list"]
    stacks: Dict[Tuple[Any, Any], List[str]] = {}
    last_ts: Dict[Tuple[Any, Any], float] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        missing = [k for k in ("name", "ph", "ts", "pid", "tid")
                   if k not in ev]
        if ev.get("ph") == "M":
            missing = [k for k in ("name", "ph", "pid") if k not in ev]
        if missing:
            errors.append(f"event {i}: missing fields {missing}")
            continue
        if ev["ph"] not in _VALID_PH:
            errors.append(f"event {i}: unknown phase {ev['ph']!r}")
            continue
        if ev["ph"] not in ("B", "E"):
            continue
        key = (ev["pid"], ev["tid"])
        ts = float(ev["ts"])
        if ts < last_ts.get(key, float("-inf")):
            errors.append(f"event {i}: ts {ts} decreases on tid {key}")
        last_ts[key] = ts
        stack = stacks.setdefault(key, [])
        if ev["ph"] == "B":
            stack.append(ev["name"])
        else:
            if not stack:
                errors.append(f"event {i}: E without matching B "
                              f"({ev['name']!r})")
            elif stack[-1] != ev["name"]:
                errors.append(f"event {i}: E {ev['name']!r} closes "
                              f"B {stack[-1]!r}")
                stack.pop()
            else:
                stack.pop()
    for key, stack in stacks.items():
        if stack:
            errors.append(f"tid {key}: unclosed spans {stack}")
    return errors


def validate_file(path: str) -> List[str]:
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: {e}"]
    return validate_chrome(obj)


def _main(argv: List[str]) -> int:
    import sys
    if not argv:
        sys.stderr.write("usage: python -m repro.obs.trace <trace.json>\n")
        return 2
    status = 0
    for path in argv:
        errors = validate_file(path)
        if errors:
            status = 1
            sys.stdout.write(f"{path}: INVALID\n")
            for e in errors[:20]:
                sys.stdout.write(f"  {e}\n")
        else:
            with open(path) as f:
                n = len(json.load(f).get("traceEvents", []))
            sys.stdout.write(f"{path}: valid chrome trace ({n} events, "
                             f"{n // 2} spans)\n")
    return status


# ---------------------------------------------------------------------------
# Environment hookup
# ---------------------------------------------------------------------------

def _env_setup() -> None:
    val = os.environ.get("REPRO_TRACE", "")
    if val in ("", "0"):
        return
    TRACER.enable()
    path = val if val not in ("1", "true", "yes") else "trace.json"

    def _dump():
        if TRACER.n_spans():
            TRACER.export_chrome(path)

    atexit.register(_dump)


_env_setup()


if __name__ == "__main__":
    import sys
    sys.exit(_main(sys.argv[1:]))
