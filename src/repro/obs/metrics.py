"""Process-wide metrics registry: counters, gauges, histograms.

Replaces the ad-hoc stats dicts scattered through the engine and
launchers with one queryable registry::

    from repro.obs import metrics
    metrics.counter("gen.tokens").inc(n)
    metrics.gauge("gen.queue_depth").set(len(queue))
    metrics.histogram("gen.ttft_s").observe(dt)
    snap = metrics.snapshot()

``snapshot()`` returns a flat JSON-able dict: counters and gauges fold
to their value, histograms to ``{count, sum, mean, min, max, p50, p95,
p99}``.  All instruments are create-on-first-use and live for the
process; ``reset()`` clears them (tests, repeated benchmark phases).

Thread safety: counter increments take a per-counter lock; histogram
``observe`` appends to a list (atomic under the GIL); gauge ``set`` is
a plain assignment.  Histograms keep a bounded reservoir
(``_HIST_CAP`` most-recent values) so a long-running server cannot grow
without bound.

``REPRO_METRICS=<path>`` registers an atexit hook dumping a snapshot as
JSON (the CI smokes upload it as a workflow artifact).

Fault/recovery namespace (``repro.faults`` + the hardened engine path):

    faults.<kind>            injected events fired, per fault kind
    engine.task_retries      transient task failures absorbed by retry
    engine.task_failures     failures beyond the retry budget
    engine.deadline_misses   tasks beyond predicted × slack (calibrated)
    checkpoint.retries       checkpoint writes that needed a retry
    checkpoint.failures      checkpoint writes abandoned (warn-and-go-on)
    elastic.forced_replans   failure escalations (drop + forced swap)
    gen.slot_failures        decode slots declared dead (requeued)
    gen.cancelled            requests explicitly cancelled
"""
from __future__ import annotations

import atexit
import contextlib
import json
import os
import threading
import time
from typing import Dict, List, Union

__all__ = ["counter", "gauge", "histogram", "snapshot", "reset", "timer",
           "Counter", "Gauge", "Histogram", "Registry", "REGISTRY"]

_HIST_CAP = 65536


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: Union[int, float] = 1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-set value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: Union[int, float]) -> None:
        self.value = float(v)


class Histogram:
    """Value distribution with quantile summaries."""

    __slots__ = ("name", "_values", "count", "sum")

    def __init__(self, name: str):
        self.name = name
        self._values: List[float] = []
        self.count = 0
        self.sum = 0.0

    def observe(self, v: Union[int, float]) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self._values.append(v)
        if len(self._values) > _HIST_CAP:
            del self._values[:len(self._values) - _HIST_CAP]

    def quantile(self, q: float) -> float:
        """Linear-interpolated quantile over the retained reservoir."""
        vals = sorted(self._values)
        if not vals:
            return 0.0
        if len(vals) == 1:
            return vals[0]
        pos = q * (len(vals) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(vals) - 1)
        frac = pos - lo
        return vals[lo] * (1 - frac) + vals[hi] * frac

    def summary(self) -> Dict[str, float]:
        if not self._values:
            return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0,
                    "max": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {"count": self.count, "sum": self.sum,
                "mean": self.sum / max(self.count, 1),
                "min": min(self._values), "max": max(self._values),
                "p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


class Registry:
    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[str, object] = {}

    def _get(self, name: str, cls):
        inst = self._instruments.get(name)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(name)
                if inst is None:
                    inst = cls(name)
                    self._instruments[name] = inst
        if not isinstance(inst, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(inst).__name__}, not {cls.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> Dict[str, object]:
        out: Dict[str, object] = {}
        with self._lock:
            items = list(self._instruments.items())
        for name, inst in sorted(items):
            if isinstance(inst, Histogram):
                out[name] = inst.summary()
            else:
                out[name] = inst.value
        return out

    def reset(self) -> None:
        with self._lock:
            self._instruments.clear()


REGISTRY = Registry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)


@contextlib.contextmanager
def timer(name: str):
    """Time a block into ``histogram(name)`` (seconds): the recovery
    benchmarks wrap detection/replan/restore windows with it."""
    t0 = time.monotonic()
    try:
        yield
    finally:
        histogram(name).observe(time.monotonic() - t0)


def snapshot() -> Dict[str, object]:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()


def dump(path: str) -> str:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(snapshot(), f, indent=2, sort_keys=True)
    return path


def _env_setup() -> None:
    path = os.environ.get("REPRO_METRICS", "")
    if path in ("", "0"):
        return

    def _dump():
        if REGISTRY._instruments:
            dump(path if path not in ("1", "true", "yes")
                 else "metrics.json")

    atexit.register(_dump)


_env_setup()
