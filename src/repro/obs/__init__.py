"""Unified observability layer: span tracing, metrics, calibration.

Three pieces, all host-side and dependency-free (no JAX imports at
module level), cheap enough to leave on in production smokes:

``repro.obs.trace``
    Structured span tracer.  ``with trace.span("gen.round", wave=i):``
    records a nestable, exception-safe span on the current thread;
    spans export as Chrome-trace/Perfetto JSON (``export_chrome``) or a
    human summary table (``report``).  Disabled spans cost one attribute
    read.  ``REPRO_TRACE=<path>`` enables tracing at import and dumps
    the trace at interpreter exit.

``repro.obs.metrics``
    Process-wide registry of counters / gauges / histograms replacing
    the ad-hoc stats dicts: ``metrics.counter("gen.tokens").inc(n)``,
    ``metrics.histogram("gen.ttft_s").observe(dt)``.  ``snapshot()``
    returns a JSON-able dict (histograms fold to count/sum/mean/
    p50/p95/p99/max).  ``REPRO_METRICS=<path>`` dumps a snapshot at
    interpreter exit.

``repro.obs.calibrate``
    Fits per-device-class ``CostModel`` scale factors from a measured
    ``Event`` timeline (HetRL §4.1 profiling, closing the wall-clock
    gap the ROADMAP flags), plus a ``DivergenceMonitor`` that turns
    sustained measured/predicted drift per task into the reactive
    elasticity signal.

Span naming scheme (dotted, category = first segment):

    engine.iteration       one Engine.run_iteration call
    engine.stage           one workflow stage dispatch
    task.<name>            one task executor (task.generation, ...)
    engine.sync            post-train weight sync to the GEN replica
    engine.swap            Engine.apply_plan plan transition
    gen.round              one genserve host round (admit+decode)
    gen.admit              one-shot admission program
    gen.install            chunked-admission install (+ prefix match)
    gen.mixed              mixed wave-step scan (decode + prefill)
    gen.decode             pure decode chunk scan
    elastic.poll           ElasticController drift reaction
    elastic.reschedule     scheduler warm-start search
    elastic.checkpoint     pre-swap checkpoint write
    train.step             one RLTrainer.train_step

Metric naming scheme (dotted namespaces):

    gen.*       tokens, ttft_s, queue_wait_s, queue_depth,
                wave_occupancy, prefix_hits / prefix_tokens,
                sjf_skips, sjf_aged_admissions
    pagepool.*  utilization, leaked_pages
    engine.*    iter_wall_s, sync_s, plan_epoch, staleness, swaps
    elastic.*   reschedule_s, checkpoint_bytes, drift_events
    calib.*     scale.<device-class>, sync_scale, local_tflops,
                local_hbm_gbps

``REPRO_OBS_STRICT=1`` upgrades observability warnings (page-pool
refcount leaks at decoder teardown) to hard errors.
"""
from __future__ import annotations

__all__ = ["metrics", "trace", "calibrate"]

# submodules import on first attribute access, keeping ``python -m
# repro.obs.trace`` free of the runpy double-import warning
_SUBMODULES = ("metrics", "trace", "calibrate")


def __getattr__(name: str):
    if name in _SUBMODULES:
        import importlib
        return importlib.import_module(f"repro.obs.{name}")
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
