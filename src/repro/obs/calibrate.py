"""Cost-model calibration from measured ``Event`` timelines + the
reactive-drift divergence monitor (HetRL §4.1 profiling, §6 loop).

The analytical ``core.costmodel.CostModel`` prices the *declared*
topology — fictional A100/L4/TPU specs — while execution folds every
plan device onto the local host, so raw measured-vs-predicted iteration
ratios sit at ~10⁴–10⁵×: internally consistent (plans rank correctly)
but not in wall-clock units.  Calibration closes the gap the way the
paper's profiler does — measure a few real task executions per device
class, fit one scale factor per class (geometric mean of
measured/predicted per-task ratios), and return a
``CalibratedCostModel`` that plugs straight into ``simulate`` /
``Engine.compare_with_simulator(cost_model=...)``.

``DivergenceMonitor`` consumes per-iteration (measured, predicted) task
durations and flags tasks whose EWMA log-ratio stays beyond a threshold
for ``sustain`` consecutive iterations — the reactive topology-drift
signal ``engine.elastic`` can poll instead of (or alongside) a declared
``DriftSchedule``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.costmodel import CostModel, TaskCost
from repro.core.plan import Plan
from repro.core.topology import Topology
from repro.core.workflow import RLWorkflow

from repro.obs import metrics

# pseudo-task id plan swaps replay (engine.executor.MIGRATION_TASK);
# excluded from fitting — migrations are priced by transition_cost, not
# task_cost
_MIGRATION_TASK = -1


# ---------------------------------------------------------------------------
# Measured durations out of an Event timeline
# ---------------------------------------------------------------------------

def measured_task_durations(timeline: Sequence,
                            ) -> Dict[Tuple[int, int], float]:
    """Per-(iteration, task) durations from matched start/end events.

    Replayed engine timelines and simulated timelines share the
    ``Event`` dataclass, so this works on either; migration
    pseudo-events are skipped."""
    starts: Dict[Tuple[int, int, int], float] = {}
    out: Dict[Tuple[int, int], float] = {}
    for e in timeline:
        if e.task == _MIGRATION_TASK:
            continue
        key = (e.iteration, e.task, e.epoch)
        if e.kind == "start":
            starts[key] = e.time
        elif e.kind == "end" and key in starts:
            out[(e.iteration, e.task)] = e.time - starts.pop(key)
    return out


def _geomean(vals: Sequence[float]) -> float:
    vals = [v for v in vals if v > 0]
    if not vals:
        return 1.0
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


# ---------------------------------------------------------------------------
# Calibration fit
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Calibration:
    """Per-device-class scale factors measured / predicted.

    ``scale_for(cls)`` falls back to the global scale for classes never
    measured (every class folds onto the same local host here, so the
    global geomean is the right prior)."""
    class_scale: Dict[str, float]
    global_scale: float
    sync_scale: float
    n_samples: int
    local_tflops: float = 0.0
    local_hbm_gbps: float = 0.0
    # per-coefficient fit (opt-in): device class -> separate scale
    # factors for the compute, communication and HBM components of a
    # task cost; absent classes fall back to the uniform class scale
    class_coeff: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)

    def scale_for(self, device_class: str) -> float:
        return self.class_scale.get(device_class, self.global_scale)

    def coeff_for(self, device_class: str) -> Dict[str, float]:
        """(comp, comm, hbm) scale factors for a class — the
        per-coefficient fit when one exists, else the uniform class
        scale applied to all three."""
        c = self.class_coeff.get(device_class)
        if c:
            return c
        s = self.scale_for(device_class)
        return {"comp": s, "comm": s, "hbm": s}

    def cost_model(self, topo: Topology, wf: RLWorkflow
                   ) -> "CalibratedCostModel":
        return CalibratedCostModel(topo, wf, self)

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    def publish_metrics(self) -> None:
        for cls, s in self.class_scale.items():
            metrics.gauge(f"calib.scale.{cls}").set(s)
        for cls, c in self.class_coeff.items():
            for name, s in c.items():
                metrics.gauge(f"calib.coeff.{cls}.{name}").set(s)
        metrics.gauge("calib.global_scale").set(self.global_scale)
        metrics.gauge("calib.sync_scale").set(self.sync_scale)
        if self.local_tflops:
            metrics.gauge("calib.local_tflops").set(self.local_tflops)
        if self.local_hbm_gbps:
            metrics.gauge("calib.local_hbm_gbps").set(self.local_hbm_gbps)


def device_class_of(topo: Topology, plan: Plan, t: int) -> str:
    """Device class a task's measurement calibrates: the spec name of
    its first assigned device (groups are homogeneous in the testbeds;
    mixed groups calibrate their leading device's class)."""
    d = int(plan.assignment[t].reshape(-1)[0])
    return topo.devices[d].spec.name


def fit_calibration(topo: Topology, wf: RLWorkflow, plan: Plan,
                    timeline: Sequence, *, skip_iterations: int = 1,
                    sync_s: Optional[Sequence[float]] = None,
                    measure_local: bool = False,
                    per_coefficient: bool = False) -> Calibration:
    """Fit per-device-class scales from a measured timeline.

    ``skip_iterations`` drops the first iterations (jit compilation
    dominates them); ``sync_s`` is the engine's measured weight-sync
    durations, fitting the reshard/sync coefficient separately;
    ``measure_local=True`` additionally microbenches the local device
    (matmul TFLOP/s + HBM GB/s via ``core.profiler``) and records the
    numbers on the result — the ground truth a physical deployment
    would feed per-class into ``GPUSpec`` directly.

    ``per_coefficient=True`` additionally fits *separate* comp / comm /
    HBM scale factors per class (nonnegative least squares of measured
    totals against the predicted component split) — one uniform scale
    cannot capture a class whose links degraded but whose FLOPs didn't.
    Classes whose observations are degenerate (all from one component
    mix, or a component never exercised) keep the uniform scale for
    the unidentifiable coefficients."""
    cm = CostModel(topo, wf)
    pred_tc = {t: cm.task_cost(plan, t) for t in range(wf.n_tasks)}
    predicted = {t: tc.total for t, tc in pred_tc.items()}
    measured = measured_task_durations(timeline)
    by_class: Dict[str, List[float]] = {}
    all_ratios: List[float] = []
    for (it, t), dur in measured.items():
        if it < skip_iterations or t not in predicted:
            continue
        pred = predicted[t]
        if pred <= 0 or dur <= 0:
            continue
        ratio = dur / pred
        by_class.setdefault(device_class_of(topo, plan, t),
                            []).append(ratio)
        all_ratios.append(ratio)
    class_scale = {cls: _geomean(rs) for cls, rs in by_class.items()}
    global_scale = _geomean(all_ratios)

    class_coeff: Dict[str, Dict[str, float]] = {}
    if per_coefficient:
        rows: Dict[str, List[Tuple[List[float], float]]] = {}
        for (it, t), dur in measured.items():
            if it < skip_iterations or t not in pred_tc or dur <= 0:
                continue
            tc = pred_tc[t]
            row = [tc.comp + tc.bubble, tc.tp + tc.pp + tc.dp, tc.hbm]
            if sum(row) <= 0:
                continue
            rows.setdefault(device_class_of(topo, plan, t),
                            []).append((row, dur))
        for cls, obs in rows.items():
            A = np.array([r for r, _ in obs], dtype=float)
            y = np.array([d for _, d in obs], dtype=float)
            coeff, *_ = np.linalg.lstsq(A, y, rcond=None)
            coeff = np.maximum(coeff, 0.0)
            if float(coeff.sum()) <= 0.0:
                continue        # degenerate: keep the uniform scale
            # a component the class never exercises (zero column)
            # carries no signal — pin it to the uniform scale
            uni = class_scale.get(cls, global_scale)
            coeff = np.where(A.sum(axis=0) <= 0.0, uni, coeff)
            class_coeff[cls] = {"comp": float(coeff[0]),
                                "comm": float(coeff[1]),
                                "hbm": float(coeff[2])}

    sync_scale = global_scale
    if sync_s:
        actor_train = _actor_train_task(wf)
        pred_sync = (cm.c_reshard(plan, actor_train) if wf.synchronous
                     else cm.c_sync(plan, actor_train, 0))
        meas = [s for s in sync_s if s > 0]
        if meas and pred_sync > 0:
            sync_scale = _geomean(meas) / pred_sync

    local_tflops = local_hbm = 0.0
    if measure_local:
        from repro.core import profiler
        local_tflops = profiler.calibrate_local_device()
        local_hbm = profiler.calibrate_local_hbm()

    cal = Calibration(class_scale, global_scale, sync_scale,
                      n_samples=len(all_ratios),
                      local_tflops=local_tflops,
                      local_hbm_gbps=local_hbm,
                      class_coeff=class_coeff)
    cal.publish_metrics()
    return cal


def fit_from_engine(engine, *, skip_iterations: int = 1,
                    measure_local: bool = False,
                    per_coefficient: bool = False) -> Calibration:
    """Fit from a live engine's replayed timeline (current epoch's plan
    and topology; the engine records wall-clock sync durations)."""
    if engine.topo is None:
        raise ValueError("engine was built without a Topology")
    return fit_calibration(engine.topo, engine.wf, engine.plan,
                           engine.timeline, skip_iterations=skip_iterations,
                           sync_s=getattr(engine, "sync_durations", None),
                           measure_local=measure_local,
                           per_coefficient=per_coefficient)


def _actor_train_task(wf: RLWorkflow) -> int:
    from repro.core.workflow import TaskKind
    return next(t for t in range(wf.n_tasks)
                if wf.task(t).kind == TaskKind.TRAIN
                and wf.task(t).name.startswith("actor"))


class CalibratedCostModel(CostModel):
    """CostModel with measured per-device-class scale factors applied.

    Drop-in for every ``cost_model=`` parameter (``simulate``,
    ``Engine.compare_with_simulator``, ``Engine.epoch_report``): task
    costs scale by their device class's factor, reshard/sync by the
    separately fitted sync scale."""

    def __init__(self, topo: Topology, wf: RLWorkflow,
                 calibration: Calibration):
        super().__init__(topo, wf)
        self.calibration = calibration

    def task_cost(self, plan: Plan, t: int) -> TaskCost:
        tc = super().task_cost(plan, t)
        cls = device_class_of(self.topo, plan, t)
        if cls in self.calibration.class_coeff:
            c = self.calibration.class_coeff[cls]
            out = TaskCost(total=0.0, comp=tc.comp * c["comp"],
                           tp=tc.tp * c["comm"], pp=tc.pp * c["comm"],
                           dp=tc.dp * c["comm"], hbm=tc.hbm * c["hbm"],
                           bubble=tc.bubble * c["comp"])
            out.total = (out.comp + out.tp + out.pp + out.dp
                         + out.hbm + out.bubble)
            return out
        s = self.calibration.scale_for(cls)
        return TaskCost(total=tc.total * s, comp=tc.comp * s,
                        tp=tc.tp * s, pp=tc.pp * s, dp=tc.dp * s,
                        hbm=tc.hbm * s, bubble=tc.bubble * s)

    def c_reshard(self, plan: Plan, actor_train: int) -> float:
        return super().c_reshard(plan, actor_train) \
            * self.calibration.sync_scale

    def c_sync(self, plan: Plan, actor_train: int,
               actor_gen: int) -> float:
        return super().c_sync(plan, actor_train, actor_gen) \
            * self.calibration.sync_scale


# ---------------------------------------------------------------------------
# Divergence monitor (reactive drift signal)
# ---------------------------------------------------------------------------

class DivergenceMonitor:
    """Flags tasks whose measured/predicted ratio drifts and stays
    drifted.

    Per task, an EWMA of the log-ratio is kept; once ``|ewma| >
    log(threshold)`` for ``sustain`` consecutive observations the task
    is *drifted* and the monitor arms its fire latch.  ``consume()``
    reads-and-clears the latch — the elastic controller polls it once
    per iteration and treats a fire like observed topology drift
    (re-run the scheduler against reality instead of waiting for a
    declared ``DriftSchedule`` entry).

    A calibrated cost model should produce the predictions fed in:
    against uncalibrated predictions the constant 10⁴× offset saturates
    the threshold immediately and the signal is meaningless.
    """

    def __init__(self, threshold: float = 3.0, sustain: int = 3,
                 alpha: float = 0.5):
        assert threshold > 1.0 and sustain >= 1 and 0 < alpha <= 1
        self.threshold = threshold
        self.sustain = sustain
        self.alpha = alpha
        self._ewma: Dict[int, float] = {}
        self._streak: Dict[int, int] = {}
        self._drifted: Dict[int, bool] = {}
        self._fired = False
        self.fire_count = 0

    def observe(self, task: int, measured_s: float,
                predicted_s: float) -> bool:
        """Feed one task measurement; returns True when this observation
        newly pushed the task into the drifted state."""
        if measured_s <= 0 or predicted_s <= 0:
            return False
        lr = math.log(measured_s / predicted_s)
        prev = self._ewma.get(task)
        ewma = lr if prev is None \
            else self.alpha * lr + (1 - self.alpha) * prev
        self._ewma[task] = ewma
        if abs(ewma) > math.log(self.threshold):
            self._streak[task] = self._streak.get(task, 0) + 1
        else:
            self._streak[task] = 0
            self._drifted[task] = False
        newly = (self._streak[task] >= self.sustain
                 and not self._drifted.get(task, False))
        if newly:
            self._drifted[task] = True
            self._fired = True
            self.fire_count += 1
            metrics.counter("elastic.drift_events").inc()
        return newly

    def observe_iteration(self, measured: Dict[int, float],
                          predicted: Dict[int, float]) -> List[int]:
        """Feed a whole iteration's task durations; returns the tasks
        that newly drifted."""
        return [t for t, m in sorted(measured.items())
                if t in predicted and self.observe(t, m, predicted[t])]

    def drifted_tasks(self) -> List[int]:
        return sorted(t for t, d in self._drifted.items() if d)

    def ratio(self, task: int) -> float:
        """Current EWMA measured/predicted ratio for a task (1.0 when
        unobserved)."""
        return math.exp(self._ewma.get(task, 0.0))

    def consume(self) -> bool:
        """Read-and-clear the fire latch."""
        fired, self._fired = self._fired, False
        return fired


# ---------------------------------------------------------------------------
# Measured-topology inference (reactive replan target)
# ---------------------------------------------------------------------------

def infer_drifted_topology(topo: Topology, wf: RLWorkflow, plan: Plan,
                           monitor: DivergenceMonitor, *,
                           min_ratio: float = 1.5) -> Optional[Topology]:
    """Turn a fired ``DivergenceMonitor`` into the *measured* topology
    the scheduler should replan against.

    The believed topology is wrong somewhere — the monitor tells us
    which tasks run slower than predicted, and the cost model tells us
    how much of each task is communication vs compute.  Attribution per
    drifted task with EWMA ratio ``r``:

    * communication-bound drift (the task moves bytes across machines):
      assume the slowdown lives on the links.  A bandwidth scale ``f``
      satisfying ``comm / f = comm + (r - 1) * total`` (extra time goes
      to the comm term) is applied to every cross-machine link between
      the task's machines, latency scaled by ``1/f``.
    * compute-bound drift (no cross-machine communication): the task's
      device class lost throughput; its compute/HBM scales by ``1/r``.

    This is deliberately coarse — it does not recover the exact hidden
    degradation, only a topology under which the scheduler prices the
    observed slowdown and routes around it.  Returns None when nothing
    is drifted beyond ``min_ratio``."""
    from repro.core import topology as topo_mod

    cm = CostModel(topo, wf)
    out = topo
    changed = False
    for t in monitor.drifted_tasks():
        r = monitor.ratio(t)
        if r < min_ratio:
            continue
        tc = cm.task_cost(plan, t)
        comm = tc.tp + tc.pp + tc.dp
        devs = [int(d) for d in plan.assignment[t].reshape(-1)]
        cross_pairs = [(a, b) for i, a in enumerate(devs)
                       for b in devs[i + 1:]
                       if topo.devices[a].machine != topo.devices[b].machine]
        if comm > 0 and cross_pairs and tc.total > 0:
            extra = (r - 1.0) * tc.total
            f = comm / (comm + extra)
            out = topo_mod.degrade_links(out, bw_factor=f,
                                         lat_factor=1.0 / f,
                                         pairs=cross_pairs)
            changed = True
        else:
            cls = device_class_of(topo, plan, t)
            out = topo_mod.scale_compute(out, 1.0 / r, device_class=cls)
            changed = True
    return out if changed else None
