"""Model assembly: init, forward (train/prefill), single-token decode.

The layer stack is a lax.scan over `R = n_layers / len(pattern)` repeats of
the (possibly heterogeneous) pattern super-block, with jax.checkpoint at
super-block granularity.  Block params are stacked over the leading [R] dim.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.models import attention as attn_mod
from repro.models import cache as cache_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.config import FFN, Mixer, ModelConfig
from repro.models.layers import (apply_ffn, embed_features, embed_tokens,
                                 init_embeddings, init_ffn, init_rmsnorm,
                                 rmsnorm, sinusoidal_positions, token_shift,
                                 unembed)
from repro.parallel.sharding import hint


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, spec) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    p = {"norm1": init_rmsnorm(cfg.d_model, dt),
         "norm2": init_rmsnorm(cfg.d_model, dt)}
    if spec.mixer == Mixer.ATTENTION:
        p["attn"] = attn_mod.init_attention(ks[0], cfg, dt)
    elif spec.mixer == Mixer.MAMBA:
        p["mamba"] = mamba_mod.init_mamba(ks[0], cfg, dt)
    elif spec.mixer == Mixer.RWKV6:
        p["rwkv"] = rwkv_mod.init_rwkv(ks[0], cfg, dt)
    if spec.moe:
        p["moe"] = moe_mod.init_moe(ks[1], cfg, spec.ffn, dt)
    else:
        p["ffn"] = init_ffn(ks[1], cfg, spec.ffn, dt)
    return p


def _init_superblock(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, len(cfg.pattern))
    return {f"layer{j}": _init_layer(ks[j], cfg, spec)
            for j, spec in enumerate(cfg.pattern)}


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    cfg.validate()
    k_emb, k_blocks = jax.random.split(key)
    R = cfg.n_pattern_repeats
    block_keys = jax.random.split(k_blocks, R)
    blocks = jax.vmap(lambda k: _init_superblock(k, cfg))(block_keys)
    return {
        "embed": init_embeddings(k_emb, cfg, _dtype(cfg)),
        "blocks": blocks,
        "final_norm": init_rmsnorm(cfg.d_model, _dtype(cfg)),
    }


def count_params(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Embedding of mixed inputs
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, inputs: Dict[str, Any]):
    """inputs: {"tokens": [B,S_t]} and/or {"features": [B,S_f,feat]}.

    Features (audio frames / image patches) are prepended to the token
    embeddings — the modality-frontend carve-out per spec."""
    parts = []
    if "features" in inputs and inputs["features"] is not None:
        parts.append(embed_features(params["embed"], cfg, inputs["features"]))
    if "tokens" in inputs and inputs["tokens"] is not None:
        parts.append(embed_tokens(params["embed"], cfg, inputs["tokens"]))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if cfg.is_encoder_only:
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]
    return x


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _layer_forward(p, cfg: ModelConfig, spec, x, positions, *, long_mode,
                   want_cache, max_cache_len):
    """Returns (x, aux, cache_entry_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    entry = None
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.mixer == Mixer.ATTENTION:
        window = cache_mod.effective_window(cfg, spec, long_mode)
        q, k, v = attn_mod.qkv_project(p["attn"], cfg, h, positions)
        q = hint(q, "attn_q")
        k = hint(k, "attn_kv")
        y = attn_mod.multihead_attention(
            q, k, v, positions, positions, causal=cfg.causal, window=window,
            cap=cfg.attn_softcap)
        B, S = h.shape[:2]
        y = y.reshape(B, S, -1) @ p["attn"]["wo"]
        if want_cache:
            L = cache_mod.kv_cache_len(cfg, spec, max_cache_len, long_mode)
            hd = cfg.resolved_head_dim
            ck = jnp.zeros((B, L, cfg.n_kv_heads, hd), _dtype(cfg))
            cv = jnp.zeros_like(ck)
            ck, cv = cache_mod.prefill_kv(ck, cv, k, v, window)
            entry = {"k": ck, "v": cv}
    elif spec.mixer == Mixer.MAMBA:
        if want_cache:
            y, st = mamba_mod.apply_mamba(p["mamba"], cfg, h,
                                          return_state=True)
            entry = st
        else:
            y = mamba_mod.apply_mamba(p["mamba"], cfg, h)
    elif spec.mixer == Mixer.RWKV6:
        if want_cache:
            y, st = rwkv_mod.apply_rwkv(p["rwkv"], cfg, h, return_state=True)
            entry = st
        else:
            y = rwkv_mod.apply_rwkv(p["rwkv"], cfg, h)
    else:
        raise ValueError(spec.mixer)
    # name the mixer output so the remat policy can save it: recomputing
    # attention/SSM scans in the backward pass doubles their HBM traffic
    # (§Perf jamba iteration)
    y = checkpoint_name(y, "mixer_out")
    x = hint(x + y, "residual")

    h = rmsnorm(p["norm2"], x, cfg.norm_eps)
    if spec.moe:
        y, a = moe_mod.apply_moe(p["moe"], cfg, spec.ffn, h)
        aux = aux + a
    else:
        shifted = token_shift(h) if spec.ffn == FFN.RWKV_CHANNEL else None
        y = apply_ffn(p["ffn"], cfg, spec.ffn, h, shifted=shifted)
        if want_cache and spec.ffn == FFN.RWKV_CHANNEL:
            entry = dict(entry or {})
            entry["cm_shift"] = h[:, -1, :]
    x = hint(x + y, "residual")
    return x, aux, entry


def forward(params, cfg: ModelConfig, inputs: Dict[str, Any], *,
            long_mode: bool = False, return_cache: bool = False,
            max_cache_len: Optional[int] = None, remat: bool = True,
            remat_policy: str = "save_mixer"):
    """Full-sequence forward. Returns dict with logits [B,S,V], aux_loss,
    and (optionally) a decode cache primed with the sequence."""
    x = embed_inputs(params, cfg, inputs)
    B, S, _ = x.shape
    positions = jnp.arange(S)
    x = hint(x, "residual")
    max_cache_len = max_cache_len or S

    def superblock(x, block_params):
        aux = jnp.zeros((), jnp.float32)
        entries = {}
        for j, spec in enumerate(cfg.pattern):
            x, a, entry = _layer_forward(
                block_params[f"layer{j}"], cfg, spec, x, positions,
                long_mode=long_mode, want_cache=return_cache,
                max_cache_len=max_cache_len)
            aux = aux + a
            if entry is not None:
                entries[f"layer{j}"] = entry
        return x, (aux, entries)

    if remat and remat_policy == "save_mixer":
        policy = jax.checkpoint_policies.save_only_these_names("mixer_out")
        block_fn = jax.checkpoint(superblock, policy=policy)
    elif remat:
        block_fn = jax.checkpoint(superblock)
    else:
        block_fn = superblock

    def scan_body(x, bp):
        x, out = block_fn(x, bp)
        return x, out

    x, (auxes, entries) = jax.lax.scan(scan_body, x, params["blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = hint(unembed(params["embed"], cfg, x), "logits")
    out = {"logits": logits, "aux_loss": jnp.sum(auxes), "hidden": x}
    if return_cache:
        cache = {"blocks": entries, "pos": jnp.asarray(S, jnp.int32)}
        out["cache"] = cache
    return out


# ---------------------------------------------------------------------------
# Chunked prefill (bounded prompt-ingestion steps against a live cache)
# ---------------------------------------------------------------------------

def _layer_prefill_chunk(p, cfg: ModelConfig, spec, x, cache_entry, pos,
                         valid, n_valid, *, long_mode):
    """x: [B,C,d] — one bounded prompt chunk per slot.  Returns
    (x, new_cache_entry).

    `pos` [B] is each slot's chunk-start cursor; `valid` [B,C] marks the
    real tokens (a slot's final chunk may be partial; a slot with
    n_valid == 0 passes through with its state untouched).  Attention
    runs against the *pre-write* cache concatenated with the fresh chunk
    k/v — causal-within-chunk plus the ragged-cache bias over per-row
    absolute positions — then writes the chunk at per-row cursors, so a
    sequence of chunk steps reproduces the one-shot prefill exactly
    (ring windows included: every in-window position is still resident
    when the chunk that needs it arrives)."""
    new_entry = {}
    B, C = x.shape[:2]
    q_pos = jnp.asarray(pos, jnp.int32)[:, None] + jnp.arange(C)     # [B,C]
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.mixer == Mixer.ATTENTION:
        window = cache_mod.effective_window(cfg, spec, long_mode)
        q, k, v = attn_mod.qkv_project(p["attn"], cfg, h, q_pos)
        ck0, cv0 = cache_entry["k"], cache_entry["v"]
        L = ck0.shape[1]
        # cache contents *before* this chunk (cursor pos-1); pos == 0
        # rows see an all-invalid cache
        k_pos_c, valid_c = cache_mod.ring_slot_positions(L, window, pos - 1)
        y = attn_mod.multihead_attention(
            q, jnp.concatenate([ck0.astype(k.dtype), k], axis=1),
            jnp.concatenate([cv0.astype(v.dtype), v], axis=1),
            q_pos, jnp.concatenate([k_pos_c, q_pos], axis=1),
            causal=True, window=window, cap=cfg.attn_softcap,
            k_valid=jnp.concatenate([valid_c, valid], axis=1))
        ck, cv = cache_mod.write_kv(ck0, cv0, k, v, pos, window, valid=valid)
        new_entry.update(k=ck, v=cv)
        y = y.reshape(B, C, -1) @ p["attn"]["wo"]
    elif spec.mixer == Mixer.MAMBA:
        st = {kk: cache_entry[kk] for kk in ("conv", "ssm")}
        y, st_new = mamba_mod.apply_mamba(p["mamba"], cfg, h, state=st,
                                          return_state=True, valid=valid)
        new_entry.update(st_new)
    elif spec.mixer == Mixer.RWKV6:
        st = {"tm_shift": cache_entry["tm_shift"], "wkv": cache_entry["wkv"]}
        y, st_new = rwkv_mod.apply_rwkv(p["rwkv"], cfg, h, state=st,
                                        return_state=True, valid=valid)
        new_entry.update(st_new)
    else:
        raise ValueError(spec.mixer)
    x = x + y

    h = rmsnorm(p["norm2"], x, cfg.norm_eps)
    if spec.moe:
        y, _ = moe_mod.apply_moe(p["moe"], cfg, spec.ffn, h)
    else:
        if spec.ffn == FFN.RWKV_CHANNEL:
            shifted = jnp.concatenate(
                [cache_entry["cm_shift"][:, None, :].astype(h.dtype),
                 h[:, :-1]], axis=1)
            y = apply_ffn(p["ffn"], cfg, spec.ffn, h, shifted=shifted)
            idx = jnp.clip(n_valid - 1, 0, C - 1)
            new_entry["cm_shift"] = jnp.take_along_axis(
                h, idx[:, None, None], axis=1)[:, 0]
        else:
            y = apply_ffn(p["ffn"], cfg, spec.ffn, h)
    return x + y, new_entry


def prefill_chunk_step(params, cfg: ModelConfig, tokens, cache, *,
                       n_valid, long_mode: bool = False):
    """One bounded prompt-ingestion step: tokens [B, C] int32 chunks.

    ``cache["pos"]`` is a [B] vector of per-slot prefill cursors (tokens
    already ingested); ``n_valid`` [B] counts the real tokens in each
    row's chunk (0 = slot idle this round: its cache entry passes
    through untouched — the caller merges rows, see genserve).  Returns
    (last_logits [B, V], new_cache): `last_logits` is each row's logit
    at its last valid chunk token — the first-token sampling point when
    a slot's final chunk lands — and `new_cache["pos"]` advances by
    ``n_valid``.  A sequence of chunk steps over a prompt is
    numerically equivalent to the one-shot ``forward(return_cache)``
    prefill (the genserve parity tests pin this)."""
    assert not cfg.is_encoder_only, "encoder-only models have no decode path"
    pos = jnp.asarray(cache["pos"], jnp.int32)
    B, C = tokens.shape
    n_valid = jnp.asarray(n_valid, jnp.int32)
    valid = jnp.arange(C)[None, :] < n_valid[:, None]
    x = embed_tokens(params["embed"], cfg, tokens)
    x = hint(x, "decode_residual")

    def scan_body(x, inp):
        bp, centry = inp
        new_entries = {}
        for j, spec in enumerate(cfg.pattern):
            x, ne = _layer_prefill_chunk(bp[f"layer{j}"], cfg, spec, x,
                                         centry[f"layer{j}"], pos, valid,
                                         n_valid, long_mode=long_mode)
            new_entries[f"layer{j}"] = ne
        return x, new_entries

    x, new_blocks = jax.lax.scan(scan_body, x,
                                 (params["blocks"], cache["blocks"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    idx = jnp.clip(n_valid - 1, 0, C - 1)
    h_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    logits = unembed(params["embed"], cfg, h_last)
    new_cache = {"blocks": new_blocks, "pos": pos + n_valid}
    return logits, new_cache


# ---------------------------------------------------------------------------
# Speculative verify (one batched step over a candidate chunk, no commit)
# ---------------------------------------------------------------------------

def _layer_verify_chunk(p, cfg: ModelConfig, spec, x, cache_entry, pos, *,
                        long_mode):
    """x: [B,C,d] — the candidate chunk [t0, d_1..d_{C-1}] per slot.
    Returns (x, {"k","v"} fresh chunk projections, *uncommitted*).

    Identical attention pattern to `_layer_prefill_chunk` (pre-write
    cache concat fresh chunk, causal-within-chunk, ragged-cache bias at
    per-row cursors) but the cache is left untouched: the caller learns
    the accept length from the returned logits and commits only the
    accepted prefix via `cache.write_kv` with a short validity mask —
    rejected speculative k/v never lands, so ring windows stay exact."""
    if spec.mixer != Mixer.ATTENTION:
        raise ValueError(
            "speculative verify requires attention-only targets "
            f"(got {spec.mixer}: recurrent state cannot roll back "
            "rejected tokens)")
    if spec.ffn == FFN.RWKV_CHANNEL:
        raise ValueError("speculative verify cannot roll back the "
                         "rwkv_channel cm_shift state")
    B, C = x.shape[:2]
    q_pos = jnp.asarray(pos, jnp.int32)[:, None] + jnp.arange(C)     # [B,C]
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    window = cache_mod.effective_window(cfg, spec, long_mode)
    q, k, v = attn_mod.qkv_project(p["attn"], cfg, h, q_pos)
    ck0, cv0 = cache_entry["k"], cache_entry["v"]
    L = ck0.shape[1]
    k_pos_c, valid_c = cache_mod.ring_slot_positions(L, window, pos - 1)
    chunk_valid = jnp.ones((B, C), bool)
    y = attn_mod.multihead_attention(
        q, jnp.concatenate([ck0.astype(k.dtype), k], axis=1),
        jnp.concatenate([cv0.astype(v.dtype), v], axis=1),
        q_pos, jnp.concatenate([k_pos_c, q_pos], axis=1),
        causal=True, window=window, cap=cfg.attn_softcap,
        k_valid=jnp.concatenate([valid_c, chunk_valid], axis=1))
    x = x + y.reshape(B, C, -1) @ p["attn"]["wo"]

    h = rmsnorm(p["norm2"], x, cfg.norm_eps)
    if spec.moe:
        y, _ = moe_mod.apply_moe(p["moe"], cfg, spec.ffn, h)
    else:
        y = apply_ffn(p["ffn"], cfg, spec.ffn, h)
    return x + y, {"k": k, "v": v}


def verify_chunk_step(params, cfg: ModelConfig, tokens, cache, *,
                      long_mode: bool = False):
    """One batched target step over a [B, C] candidate chunk
    ([t0, d_1..d_{C-1}] at each row's ``cache["pos"]`` cursor).

    Returns (logits [B, C, V], deltas): logits at *every* chunk
    position (position j scores the token following the candidate
    prefix up to j — the accept test and the bonus/corrected draw both
    read from here), and ``deltas`` — the per-layer fresh-chunk
    ``{"k","v"}`` projections, NOT written to the cache.  After the
    caller computes the accept length it commits the accepted prefix
    with ``cache.write_kv(ck, cv, deltas.k, deltas.v, pos, window,
    valid=<short mask>)`` — the same variable-length chunk-write
    machinery chunked prefill uses.  Requires an attention-only config
    (see `cache.supports_speculative_target`)."""
    assert not cfg.is_encoder_only, "encoder-only models have no decode path"
    pos = jnp.asarray(cache["pos"], jnp.int32)
    x = embed_tokens(params["embed"], cfg, tokens)
    x = hint(x, "decode_residual")

    def scan_body(x, inp):
        bp, centry = inp
        deltas = {}
        for j, spec in enumerate(cfg.pattern):
            x, d = _layer_verify_chunk(bp[f"layer{j}"], cfg, spec, x,
                                       centry[f"layer{j}"], pos,
                                       long_mode=long_mode)
            deltas[f"layer{j}"] = d
        return x, deltas

    x, deltas = jax.lax.scan(scan_body, x,
                             (params["blocks"], cache["blocks"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], cfg, x)
    return logits, deltas


# ---------------------------------------------------------------------------
# Decode (single token, serve_step)
# ---------------------------------------------------------------------------

def _layer_decode(p, cfg: ModelConfig, spec, x, cache_entry, pos, *,
                  long_mode):
    """x: [B,1,d]. Returns (x, new_cache_entry).

    `pos` is a scalar (all rows decode the same position — the
    single-sequence / full-batch rollout path) or a [B] vector of
    per-row cache positions (batched wave decode: every slot keeps its
    own RoPE phase, ring offset and validity mask)."""
    new_entry = {}
    per_slot = jnp.ndim(pos) > 0
    q_pos = (jnp.asarray(pos, jnp.int32)[:, None] if per_slot
             else jnp.full((1,), pos, jnp.int32))      # [B,1] or [1]
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.mixer == Mixer.ATTENTION:
        window = cache_mod.effective_window(cfg, spec, long_mode)
        q, k, v = attn_mod.qkv_project(p["attn"], cfg, h, q_pos)
        ck, cv = cache_mod.write_kv(cache_entry["k"], cache_entry["v"],
                                    k, v, pos, window)
        new_entry.update(k=ck, v=cv)
        L = ck.shape[1]
        k_pos, valid = cache_mod.ring_slot_positions(L, window, pos)
        y = attn_mod.multihead_attention(
            q, ck, cv, q_pos, k_pos,
            causal=True, window=window, cap=cfg.attn_softcap, k_valid=valid)
        y = y.reshape(x.shape[0], 1, -1) @ p["attn"]["wo"]
    elif spec.mixer == Mixer.MAMBA:
        st = {k: cache_entry[k] for k in ("conv", "ssm")}
        y, st_new = mamba_mod.apply_mamba(p["mamba"], cfg, h, state=st,
                                          return_state=True)
        new_entry.update(st_new)
    elif spec.mixer == Mixer.RWKV6:
        st = {"tm_shift": cache_entry["tm_shift"], "wkv": cache_entry["wkv"]}
        y, st_new = rwkv_mod.apply_rwkv(p["rwkv"], cfg, h, state=st,
                                        return_state=True)
        new_entry.update(st_new)
    x = x + y

    h = rmsnorm(p["norm2"], x, cfg.norm_eps)
    if spec.moe:
        y, _ = moe_mod.apply_moe(p["moe"], cfg, spec.ffn, h)
    else:
        if spec.ffn == FFN.RWKV_CHANNEL:
            shifted = cache_entry["cm_shift"][:, None, :].astype(h.dtype)
            y = apply_ffn(p["ffn"], cfg, spec.ffn, h, shifted=shifted)
            new_entry["cm_shift"] = h[:, -1, :]
        else:
            y = apply_ffn(p["ffn"], cfg, spec.ffn, h)
    return x + y, new_entry


def decode_step(params, cfg: ModelConfig, tokens, cache, *,
                long_mode: bool = False):
    """tokens: [B, 1] int32. Returns (logits [B, V], new_cache).

    ``cache["pos"]`` is a scalar (every row at the same position) or a
    [B] vector of per-row positions — the natively batched fast path the
    genserve wave decode uses: ragged KV lengths, RoPE phases and
    ring-window validity are expressed per-row, so one fused attention
    call covers a whole wave of recycled slots."""
    assert not cfg.is_encoder_only, "encoder-only models have no decode step"
    pos = cache["pos"]
    x = embed_tokens(params["embed"], cfg, tokens)
    x = hint(x, "decode_residual")

    def scan_body(x, inp):
        bp, centry = inp
        new_entries = {}
        for j, spec in enumerate(cfg.pattern):
            x, ne = _layer_decode(bp[f"layer{j}"], cfg, spec, x,
                                  centry[f"layer{j}"], pos,
                                  long_mode=long_mode)
            new_entries[f"layer{j}"] = ne
        return x, new_entries

    x, new_blocks = jax.lax.scan(scan_body, x,
                                 (params["blocks"], cache["blocks"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], cfg, x)[:, 0]
    new_cache = {"blocks": new_blocks, "pos": pos + 1}
    return logits, new_cache
