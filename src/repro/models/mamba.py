"""Mamba (selective SSM) mixer — Jamba-style block.

Recurrence: h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t ;
            y_t = C_t . h_t + D * x_t
with input-dependent dt, B, C (selectivity).

Training/prefill executes a *chunked* scan: lax.scan over time chunks with
jax.lax.associative_scan inside each chunk, so the materialized state tensor
is bounded by [B, chunk, d_inner, d_state].  Decode keeps per-layer
(conv, ssm) states and performs a single recurrence step.

The Pallas kernel (repro.kernels.mamba_scan) implements the chunk-local scan.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init

_CHUNK = 64


def dt_rank(cfg: ModelConfig) -> int:
    return max(cfg.d_model // 16, 8)


def init_mamba(key, cfg: ModelConfig, dtype):
    d, di, ds = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    dc, r = cfg.mamba_d_conv, dt_rank(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real init for A
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": dense_init(ks[1], (dc, di), dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, r + 2 * ds), dtype=dtype),
        "dt_proj": dense_init(ks[3], (r, di), dtype=dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(~0.01)
        "A_log": jnp.log(a).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], (di, d), dtype=dtype),
    }


def _causal_conv(x, w, b, state=None, n_valid=None):
    """Depthwise causal conv. x: [B,S,di], w: [dc,di].

    Returns (y, new_state) where state is the trailing dc-1 inputs.
    ``n_valid`` ([B] int) makes the state window end at each row's last
    *valid* input instead of the chunk end (partial chunked-prefill
    chunks: trailing invalid tokens must not enter the carried state)."""
    dc = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    if dc <= 1:
        new_state = None
    elif n_valid is None:
        new_state = xp[:, -(dc - 1):, :]
    else:
        # xp row layout: [dc-1 carried inputs | chunk]; the dc-1 inputs
        # ending at the last valid token start at index n_valid
        idx = n_valid[:, None] + jnp.arange(dc - 1)
        new_state = jnp.take_along_axis(xp, idx[..., None], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(dc)) + b
    return y, new_state


def _ssm_params(params, cfg, u):
    """u: [B,S,di] -> dt [B,S,di], Bm/Cm [B,S,ds], A [di,ds]."""
    r, ds = dt_rank(cfg), cfg.mamba_d_state
    dbc = u @ params["x_proj"]
    dt = jax.nn.softplus(dbc[..., :r] @ params["dt_proj"]
                         + params["dt_bias"].astype(jnp.float32))
    Bm = dbc[..., r:r + ds]
    Cm = dbc[..., r + ds:]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    return dt.astype(jnp.float32), Bm, Cm, A


def _chunk_scan(dt_c, B_c, x_c, A, h0):
    """One chunk of the recurrence via associative scan.

    dt_c: [B,c,di], B_c: [B,c,ds], x_c: [B,c,di], h0: [B,di,ds].
    Returns (ys_h [B,c,di,ds] hidden states, h_end)."""
    da = jnp.exp(dt_c[..., None] * A)                       # [B,c,di,ds]
    dbx = (dt_c * x_c)[..., None] * B_c[:, :, None, :]      # [B,c,di,ds]
    # include h0 by folding it into the first step's additive term
    dbx = dbx.at[:, 0].add(da[:, 0] * h0)

    def combine(a, b):
        return a[0] * b[0], b[0] * a[1] + b[1]

    _, hs = jax.lax.associative_scan(combine, (da, dbx), axis=1)
    return hs, hs[:, -1]


def ssm_scan(dt, Bm, Cm, x, A, D, h0, chunk=_CHUNK):
    """Full selective scan. Shapes: dt,x [B,S,di]; Bm,Cm [B,S,ds].

    Returns (y [B,S,di], h_end [B,di,ds])."""
    Bsz, S, di = x.shape
    ds = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        x_p = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    else:
        x_p = x
    n = (S + pad) // chunk
    as_chunks = lambda t: t.reshape(Bsz, n, chunk, *t.shape[2:]) \
        .transpose(1, 0, 2, *range(3, t.ndim + 1))

    def step(h, inputs):
        dt_c, B_c, C_c, x_c = inputs
        hs, h_end = _chunk_scan(dt_c.astype(jnp.float32),
                                B_c.astype(jnp.float32),
                                x_c.astype(jnp.float32), A, h)
        y_c = jnp.einsum("bcds,bcs->bcd", hs, C_c.astype(jnp.float32))
        return h_end, y_c

    h_init = h0 if h0 is not None else jnp.zeros((Bsz, di, ds), jnp.float32)
    h_end, ys = jax.lax.scan(
        step, h_init, (as_chunks(dt), as_chunks(Bm), as_chunks(Cm),
                       as_chunks(x_p)))
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, n * chunk, di)[:, :S]
    y = y + x.astype(jnp.float32) * D
    return y, h_end


def apply_mamba(params, cfg: ModelConfig, x,
                state: Optional[dict] = None,
                return_state: bool = False, valid=None):
    """x: [B,S,d]. state: {"conv": [B,dc-1,di], "ssm": [B,di,ds]}.

    ``valid`` ([B,S] bool) marks real tokens in a chunked-prefill chunk:
    invalid (trailing) tokens freeze the recurrence — dt is zeroed so
    the SSM state passes through unchanged, and the conv window ends at
    each row's last valid input.  Outputs at invalid positions are
    garbage and must be discarded by the caller."""
    di = cfg.mamba_d_inner
    xz = x @ params["in_proj"]
    u, z = xz[..., :di], xz[..., di:]
    conv_state = state["conv"] if state is not None else None
    n_valid = jnp.sum(valid, axis=1) if valid is not None else None
    u, new_conv = _causal_conv(u, params["conv_w"], params["conv_b"],
                               conv_state, n_valid=n_valid)
    u = jax.nn.silu(u)
    dt, Bm, Cm, A = _ssm_params(params, cfg, u)
    if valid is not None:
        dt = dt * valid[..., None]     # exp(0*A)=1, 0*B*x=0: state frozen
    D = params["D"].astype(jnp.float32)
    h0 = state["ssm"] if state is not None else None
    y, h_end = ssm_scan(dt, Bm, Cm, u, A, D, h0,
                        chunk=min(_CHUNK, x.shape[1]))
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]
    if return_state:
        return y, {"conv": new_conv, "ssm": h_end}
    return y


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner),
                          dtype),
        "ssm": jnp.zeros((batch, cfg.mamba_d_inner, cfg.mamba_d_state),
                         jnp.float32),
    }
