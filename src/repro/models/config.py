"""Model configuration covering all assigned architecture families.

One `ModelConfig` dataclass expresses dense GQA transformers, MoE, SSM
(Mamba), linear attention (RWKV6), hybrid interleaves, encoder-only audio
backbones, and VLM decoders with a stubbed modality frontend.

The layer stack is described by a repeating *pattern* of `LayerSpec`s: the
full model is ``pattern`` repeated ``n_layers / len(pattern)`` times. This
lets us scan over homogeneous super-blocks (jamba's 1:7 attn:mamba period,
gemma2's local/global alternation) while keeping the HLO small.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Sequence, Tuple


class Mixer(str, enum.Enum):
    """Sequence-mixing block type."""

    ATTENTION = "attention"
    MAMBA = "mamba"
    RWKV6 = "rwkv6"


class FFN(str, enum.Enum):
    """Channel-mixing block type."""

    SWIGLU = "swiglu"        # gated SiLU (llama/phi3/mixtral/jamba/pixtral)
    GEGLU = "geglu"          # gated GELU (gemma2)
    SQUARED_RELU = "squared_relu"  # nemotron-4
    GELU = "gelu"            # hubert / vanilla
    RWKV_CHANNEL = "rwkv_channel"  # rwkv6 channel mix (squared relu + recept.)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer in the repeating pattern."""

    mixer: Mixer = Mixer.ATTENTION
    ffn: FFN = FFN.SWIGLU
    moe: bool = False
    # attention-only knobs
    window: Optional[int] = None  # sliding-window size; None = full attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int               # 0 for attention-free architectures
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # pattern of layer specs, repeated to n_layers
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    # attention knobs
    rope: bool = True
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    attn_softcap: Optional[float] = None     # gemma2: 50.0
    final_softcap: Optional[float] = None    # gemma2: 30.0
    causal: bool = True                      # False for encoder-only (hubert)
    # MoE knobs
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # Mamba knobs
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # RWKV6 knobs
    rwkv_head_dim: int = 64
    # embedding frontend: "tokens" (LM), "features" (audio/vlm stub input)
    frontend: str = "tokens"
    feature_dim: int = 0        # dim of precomputed frame/patch embeddings
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # long-context mode: replaces full-attention layers' window (see DESIGN §3)
    long_mode_window: Optional[int] = None
    dtype: str = "bfloat16"
    # citation for the config source
    source: str = ""

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def n_pattern_repeats(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern length {len(self.pattern)}")
        return self.n_layers // len(self.pattern)

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def attention_free(self) -> bool:
        return all(s.mixer != Mixer.ATTENTION for s in self.pattern)

    @property
    def has_moe(self) -> bool:
        return any(s.moe for s in self.pattern)

    @property
    def has_mamba(self) -> bool:
        return any(s.mixer == Mixer.MAMBA for s in self.pattern)

    @property
    def has_rwkv(self) -> bool:
        return any(s.mixer == Mixer.RWKV6 for s in self.pattern)

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    def supports_long_context(self) -> bool:
        """Sub-quadratic (per-token decode O(window) / O(1)) variant exists."""
        if self.attention_free or self.has_mamba:
            return True
        # dense archs qualify only with a sliding-window variant
        all_windowed = all(
            s.window is not None or s.mixer != Mixer.ATTENTION
            for s in self.pattern)
        return all_windowed or self.long_mode_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (matches init_params within emb ties)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        if self.frontend == "features":
            total += self.feature_dim * d
        for spec in self.pattern:
            per = 2 * d  # two rmsnorm scales
            if spec.mixer == Mixer.ATTENTION:
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                per += q + kv + o
                if self.qk_norm:
                    per += 2 * hd
            elif spec.mixer == Mixer.MAMBA:
                di, ds = self.mamba_d_inner, self.mamba_d_state
                r = max(d // 16, 8)            # dt_rank
                per += d * 2 * di              # in_proj (x and z)
                per += (self.mamba_d_conv + 1) * di  # depthwise conv + bias
                per += di * (r + 2 * ds)       # x_proj -> dt, B, C
                per += r * di + di             # dt_proj + bias
                per += di * ds + di            # A_log + D
                per += di * d                  # out_proj
            elif spec.mixer == Mixer.RWKV6:
                # r,k,v,g,o projections + decay lora + mixes/bonus/norm
                per += 5 * d * d + 2 * 64 * d + 9 * d
            if spec.moe:
                per += d * self.n_experts  # router
                per += self.n_experts * self._ffn_params(spec.ffn)
            else:
                per += self._ffn_params(spec.ffn)
            total += per * self.n_pattern_repeats
        total += d  # final norm
        return total

    def _ffn_params(self, ffn: FFN) -> int:
        d, f = self.d_model, self.d_ff
        if ffn in (FFN.SWIGLU, FFN.GEGLU):
            return 3 * d * f
        if ffn == FFN.RWKV_CHANNEL:
            return 2 * d * f + d * d  # key, value, receptance
        return 2 * d * f

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if not self.has_moe:
            return self.param_count()
        total = self.param_count()
        for spec in self.pattern:
            if spec.moe:
                inactive = (self.n_experts - self.top_k) * \
                    self._ffn_params(spec.ffn)
                total -= inactive * self.n_pattern_repeats
        return total

    def validate(self) -> None:
        assert self.d_model > 0 and self.n_layers > 0
        assert self.n_layers % len(self.pattern) == 0
        if not self.attention_free:
            assert self.n_heads > 0 and self.n_kv_heads > 0
            assert self.n_heads % self.n_kv_heads == 0
        if self.has_moe:
            assert 0 < self.top_k <= self.n_experts
        if self.has_rwkv:
            assert self.d_model % self.rwkv_head_dim == 0
        if self.frontend == "features":
            assert self.feature_dim > 0


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256,
            n_experts: int = 4, vocab: int = 512) -> ModelConfig:
    """Smoke-test variant of the same family: ≤2 layers, d_model ≤ 512,
    ≤4 experts, smaller ffn/vocab, same pattern structure."""
    pat_len = len(cfg.pattern)
    # keep the pattern but shrink repeats; if pattern longer than n_layers,
    # truncate the pattern itself (keeping at least one of each mixer kind).
    if pat_len > n_layers:
        kinds = []
        seen = set()
        for s in cfg.pattern:
            key = (s.mixer, s.moe, s.window is not None)
            if key not in seen:
                seen.add(key)
                kinds.append(s)
        pattern = tuple(kinds[:n_layers])
        if len(pattern) < n_layers and n_layers % len(pattern) != 0:
            n_layers = len(pattern)
    else:
        pattern = cfg.pattern
        n_layers = max(pat_len, (n_layers // pat_len) * pat_len)
    n_heads = 0 if cfg.attention_free else min(cfg.n_heads, 4)
    n_kv = 0 if cfg.attention_free else min(cfg.n_kv_heads, max(1, n_heads // 2))
    if n_heads and n_heads % max(n_kv, 1):
        n_kv = 1
    # shrink windows so smoke seqs exercise the masking path
    pattern = tuple(
        dataclasses.replace(s, window=(16 if s.window is not None else None))
        for s in pattern)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=(64 if not cfg.attention_free else 0),
        d_ff=d_model * 2,
        vocab_size=vocab,
        pattern=pattern,
        n_experts=min(cfg.n_experts, n_experts) if cfg.has_moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.has_moe else 0,
        rwkv_head_dim=32,
        feature_dim=(64 if cfg.frontend == "features" else 0),
        long_mode_window=(16 if cfg.long_mode_window is not None else None),
        dtype="float32",
    )
