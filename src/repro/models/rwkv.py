"""RWKV6 ("Finch") time-mix with data-dependent decay.

Per head (key dim N = head_dim):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state: [N, N])
    y_t = r_t . (diag(u) k_t v_t^T + S_{t-1})
with per-channel per-token decay w_t in (0,1) produced by a low-rank MLP of
the token-shifted input (the data-dependent decay that distinguishes RWKV6
from RWKV5).

Training/prefill uses the chunked linear-attention algorithm: within a chunk
the quadratic form with cumulative-decay ratios, across chunks a recurrent
state update — all in log-decay space for numerical stability.  Decode is a
single recurrence step.

The Pallas kernel (repro.kernels.rwkv6_scan) implements the chunk-local part.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, token_shift
from repro.parallel.sharding import hint

_CHUNK = 64
_DECAY_LORA = 64


def init_rwkv(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    H, hd = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    return {
        "mu": jnp.full((5, d), 0.5, dtype),  # r,k,v,g,w token-shift mixes
        "w_r": dense_init(ks[0], (d, d), dtype=dtype),
        "w_k": dense_init(ks[1], (d, d), dtype=dtype),
        "w_v": dense_init(ks[2], (d, d), dtype=dtype),
        "w_g": dense_init(ks[3], (d, d), dtype=dtype),
        "w_o": dense_init(ks[4], (d, d), dtype=dtype),
        "decay_w1": dense_init(ks[5], (d, _DECAY_LORA), dtype=dtype),
        "decay_w2": dense_init(ks[6], (_DECAY_LORA, d), dtype=dtype),
        "decay_base": jnp.full((d,), -6.0, dtype),
        "bonus_u": dense_init(ks[7], (H, hd), scale=0.5, dtype=dtype),
        "out_norm": {"scale": jnp.ones((d,), dtype)},
    }


def _project(params, cfg: ModelConfig, x, shifted):
    """Returns r,k,v,g [B,S,H,hd] and log-decay logw [B,S,H,hd] (<0)."""
    B, S, d = x.shape
    H, hd = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    mu = params["mu"]
    mix = lambda i: x + (shifted - x) * mu[i]
    heads = lambda t: t.reshape(B, S, H, hd)
    r = heads(mix(0) @ params["w_r"])
    k = heads(mix(1) @ params["w_k"])
    v = heads(mix(2) @ params["w_v"])
    g = jax.nn.silu(mix(3) @ params["w_g"])
    dlora = jnp.tanh(mix(4) @ params["decay_w1"]) @ params["decay_w2"]
    logw = -jnp.exp(
        (params["decay_base"] + dlora).astype(jnp.float32))  # [B,S,d] < 0
    return r, k, v, g, heads(logw)


def _chunk_form(r, k, v, logw, u, S0):
    """One chunk, all heads. r,k,v,logw: [B,c,H,N] fp32; S0: [B,H,N,N].

    Returns (y [B,c,H,N], S_end)."""
    B, c, H, N = r.shape
    cum = jnp.cumsum(logw, axis=1)                    # inclusive cumsum
    cum_ex = cum - logw                               # exclusive
    total = cum[:, -1]                                # [B,H,N]

    # inter-chunk: y_inter[t] = (r_t * exp(cum_ex_t)) @ S0
    r_dec = r * jnp.exp(cum_ex)
    y_inter = jnp.einsum("bchn,bhnm->bchm", r_dec, S0)

    # intra-chunk: scores[t,s] = sum_n r[t,n] k[s,n] exp(cum_ex_t - cum_s)
    #   valid s < t; diagonal uses the bonus u instead of decay.
    ratio = cum_ex[:, :, None] - cum[:, None, :]      # [B,t,s,H,N]
    mask = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])
    att = jnp.einsum("bthn,bshn,btshn->btsh",
                     r, k, jnp.exp(jnp.minimum(ratio, 0.0)))
    att = att * mask[None, :, :, None]
    y_intra = jnp.einsum("btsh,bshm->bthm", att, v)
    diag = jnp.einsum("bthn,hn,bthn->bth", r, u, k)
    y_intra = y_intra + diag[..., None] * v

    # state update: S_end = diag(exp(total)) S0 + sum_s exp(total-cum_s) k_s v_s^T
    k_dec = k * jnp.exp(total[:, None] - cum)
    S_end = jnp.exp(total)[..., None] * S0 + \
        jnp.einsum("bshn,bshm->bhnm", k_dec, v)
    return y_inter + y_intra, S_end


def rwkv_scan(r, k, v, logw, u, state0, chunk=_CHUNK):
    """r,k,v,logw: [B,S,H,N]; u: [H,N]; state0: [B,H,N,N] or None."""
    B, S, H, N = r.shape
    pad = (-S) % chunk
    padt = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if pad:
        r, k, v, logw = padt(r), padt(k), padt(v), padt(logw)
    n = (S + pad) // chunk
    chunks = lambda t: t.reshape(B, n, chunk, H, N).transpose(1, 0, 2, 3, 4)
    if state0 is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)

    def step(S_c, inp):
        rc, kc, vc, wc = inp
        y, S_new = _chunk_form(rc, kc, vc, wc, u, S_c)
        return S_new, y

    S_end, ys = jax.lax.scan(step, state0,
                             (chunks(r), chunks(k), chunks(v), chunks(logw)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, n * chunk, H, N)[:, :S]
    return y, S_end


def apply_rwkv(params, cfg: ModelConfig, x,
               state: Optional[dict] = None, return_state: bool = False,
               valid=None):
    """x: [B,S,d]. state: {"tm_shift":[B,d], "wkv":[B,H,N,N]}.

    ``valid`` ([B,S] bool) marks real tokens in a chunked-prefill chunk:
    invalid (trailing) tokens freeze the recurrence — their decay is
    forced to 1 and their key contribution to 0, so the wkv state passes
    through unchanged, and ``tm_shift`` carries each row's last *valid*
    token.  Outputs at invalid positions are garbage and must be
    discarded by the caller."""
    B, S, d = x.shape
    H, N = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    if state is not None:
        shifted = jnp.concatenate(
            [state["tm_shift"][:, None, :].astype(x.dtype), x[:, :-1]], axis=1)
        wkv0 = state["wkv"]
    else:
        shifted = token_shift(x)
        wkv0 = None
    r, k, v, g, logw = _project(params, cfg, x, shifted)
    if valid is not None:
        vm = valid[:, :, None, None]
        k = k * vm                       # no state / attention contribution
        logw = jnp.where(vm, logw, 0.0)  # decay 1: state passes through
    r = hint(r, "rwkv_heads")
    k = hint(k, "rwkv_heads")
    v = hint(v, "rwkv_heads")
    f32 = lambda t: t.astype(jnp.float32)
    u = params["bonus_u"].astype(jnp.float32)
    y, wkv_end = rwkv_scan(f32(r), f32(k), f32(v), logw, u, wkv0,
                           chunk=min(_CHUNK, S))
    # per-head group norm
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, S, d) * params["out_norm"]["scale"].astype(y.dtype)
    y = (y.astype(x.dtype) * g) @ params["w_o"]
    if return_state:
        if valid is None:
            tm = x[:, -1, :]
        else:
            idx = jnp.clip(jnp.sum(valid, axis=1) - 1, 0, S - 1)
            tm = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
        return y, {"tm_shift": tm, "wkv": wkv_end}
    return y


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H, N = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    return {
        "tm_shift": jnp.zeros((batch, cfg.d_model), dtype),
        "cm_shift": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, N, N), jnp.float32),
    }
