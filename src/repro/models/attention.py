"""Attention: GQA, RoPE, sliding windows, logit softcap, qk-norm.

``multihead_attention`` dispatch table:

  impl     shape       path
  ------   ---------   -----------------------------------------------
  jnp      small       ``plain_attention`` — materializes the full
                       score matrix (Sq * Sk <= _CHUNK_THRESHOLD**2).
  jnp      large       ``chunked_attention`` — flash-style blockwise
                       online softmax (lax.scan over KV blocks nested in
                       a scan over Q blocks).  Never materializes more
                       than [B, H, q_chunk, k_chunk] scores; required
                       for 32k+ prefill.
  pallas   Sq > 1      ``kernels.flash_attention`` — fused TPU kernel
                       for training / prefill self-attention
                       (contiguous arange positions).
  pallas   Sq == 1     ``kernels.decode_attention`` — flash-decode: one
                       query token per slot against a (possibly ring)
                       KV cache, ragged lengths and window validity
                       folded into a per-slot additive [B, L] bias.

Positions may be shared 1-D arrays ([Sq] / [Sk]) or per-row 2-D arrays
([B, Sq] / [B, Sk]) — the latter is what the batched wave decode uses so
recycled slots at distinct cache positions share one kernel launch.  The
jnp paths are the parity oracles for both Pallas kernels.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, rmsnorm, softcap

_IMPL = "jnp"  # "jnp" | "pallas"
# use the chunked (flash-style) path when S_q * S_k exceeds thr**2 —
# materializing full score matrices at train_4k scale dominated the HBM
# roofline term (§Perf granite iteration 4)
_CHUNK_THRESHOLD = 2048
_Q_CHUNK = 1024
_K_CHUNK = 1024

NEG_INF = -1e30


def set_attention_impl(impl: str) -> None:
    global _IMPL
    assert impl in ("jnp", "pallas")
    _IMPL = impl


def get_attention_impl() -> str:
    return _IMPL


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype=dtype),
        "wkv": dense_init(ks[1], (d, 2 * cfg.n_kv_heads * hd), dtype=dtype),
        "wo": dense_init(ks[2], (cfg.n_heads * hd, d), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), dtype)}
        p["k_norm"] = {"scale": jnp.ones((hd,), dtype)}
    return p


def qkv_project(params, cfg: ModelConfig, x, positions):
    """x: [B, S, d] -> q [B,S,H,hd], k,v [B,S,KV,hd] (roped, normed)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, hd)
    kv = (x @ params["wkv"]).reshape(B, S, 2 * cfg.n_kv_heads, hd)
    k, v = kv[:, :, :cfg.n_kv_heads], kv[:, :, cfg.n_kv_heads:]
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Score-level helpers
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int],
               k_valid=None):
    """Additive bias [..., Sq, Sk] from absolute positions."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok &= diff >= 0
    if window is not None:
        ok &= diff < window
    if k_valid is not None:
        ok &= k_valid[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _gqa_scores(q, k, scale):
    """q: [B,Sq,H,hd], k: [B,Sk,KV,hd] -> [B,H,Sq,Sk] (fp32).

    fp32 accumulation via preferred_element_type — no fp32
    materialization of the (potentially cache-sized) k operand."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, Sq, KV, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    return s.reshape(B, KV * g, Sq, -1)


def _gqa_values(probs, v):
    """probs: [B,H,Sq,Sk] fp32, v: [B,Sk,KV,hd] -> [B,Sq,H,hd] fp32."""
    B, H, Sq, Sk = probs.shape
    KV = v.shape[2]
    g = H // KV
    pg = probs.reshape(B, KV, g, Sq, Sk)
    o = jnp.einsum("bkgqs,bskh->bqkgh", pg, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, -1)


# ---------------------------------------------------------------------------
# Plain attention (small sequences)
# ---------------------------------------------------------------------------

def plain_attention(q, k, v, q_pos, k_pos, *, causal, window,
                    cap: Optional[float], k_valid=None):
    hd = q.shape[-1]
    scores = _gqa_scores(q, k, 1.0 / jnp.sqrt(hd).astype(jnp.float32))
    scores = softcap(scores, cap)
    bias = _mask_bias(q_pos, k_pos, causal=causal, window=window,
                      k_valid=k_valid)
    if bias.ndim == 2:          # shared positions: [Sq, Sk]
        bias = bias[None, None]
    elif bias.ndim == 3:        # per-row positions: [B, Sq, Sk]
        bias = bias[:, None]
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_values(probs, v).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention
# ---------------------------------------------------------------------------

def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def chunked_attention(q, k, v, q_pos, k_pos, *, causal, window,
                      cap: Optional[float], q_chunk=_Q_CHUNK,
                      k_chunk=_K_CHUNK, k_valid=None):
    """Blockwise online-softmax attention. Shapes as plain_attention.

    q_pos/k_pos: [S] int32 absolute positions (shared across batch).
    """
    B, Sq, H, hd = q.shape
    out_dtype = q.dtype
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, k.shape[1])
    if k_valid is None:
        k_valid = jnp.ones((k.shape[1],), bool)

    q, _ = _pad_to(q, 1, q_chunk)
    q_pos_p, _ = _pad_to(q_pos, 0, q_chunk)
    k, Sk0 = _pad_to(k, 1, k_chunk)
    v, _ = _pad_to(v, 1, k_chunk)
    k_pos_p, _ = _pad_to(k_pos, 0, k_chunk)
    k_valid_p = jnp.pad(k_valid, (0, k.shape[1] - Sk0))

    nq, nk = q.shape[1] // q_chunk, k.shape[1] // k_chunk
    KV = k.shape[2]
    g = H // KV
    scale = 1.0 / float(hd) ** 0.5

    qb = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    qpb = q_pos_p.reshape(nq, q_chunk)
    kb = k.reshape(B, nk, k_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, k_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    kpb = k_pos_p.reshape(nk, k_chunk)
    kvb = k_valid_p.reshape(nk, k_chunk)

    def q_block(carry, q_in):
        qi, qp = q_in
        qg = qi.reshape(B, q_chunk, KV, g, hd).astype(jnp.float32)

        def kv_block(state, k_in):
            m, l, acc = state
            ki, vi, kp, kval = k_in
            s = jnp.einsum("bqkgh,bskh->bkgqs", qg,
                           ki.astype(jnp.float32)) * scale
            s = softcap(s, cap)
            bias = _mask_bias(qp, kp, causal=causal, window=window,
                              k_valid=kval)          # [q_chunk, k_chunk]
            s = s + bias[None, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, KV, g, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, g, q_chunk), jnp.float32),
                jnp.zeros((B, KV, g, q_chunk, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_block, init, (kb, vb, kpb, kvb))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        o = o.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, H, hd)
        return carry, o.astype(out_dtype)

    _, ob = jax.lax.scan(q_block, 0, (qb, qpb))
    out = ob.transpose(1, 0, 2, 3, 4).reshape(B, -1, H, hd)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# Top-level dispatch
# ---------------------------------------------------------------------------

def decode_bias(q_pos, k_pos, *, causal=True, window=None, k_valid=None,
                batch=None):
    """Per-slot additive [B, Sk] bias for single-token (Sq == 1) decode.

    Collapses causal / window / slot-validity masking against the cache
    positions into the flash-decode kernel's bias operand."""
    bias = _mask_bias(q_pos, k_pos, causal=causal, window=window,
                      k_valid=k_valid)                 # [..., 1, Sk]
    bias = bias[..., 0, :]
    if batch is not None:
        bias = jnp.broadcast_to(bias, (batch, bias.shape[-1]))
    return bias


def multihead_attention(q, k, v, q_pos, k_pos, *, causal=True, window=None,
                        cap=None, k_valid=None, force_impl=None):
    impl = force_impl or _IMPL
    Sq, Sk = q.shape[1], k.shape[1]
    if impl == "pallas" and Sq == 1:
        from repro.kernels import ops as kernel_ops
        bias = decode_bias(q_pos, k_pos, causal=causal, window=window,
                           k_valid=k_valid, batch=q.shape[0])
        out = kernel_ops.flash_decode_attention(q[:, 0], k, v, bias,
                                                cap=cap)
        return out[:, None]
    if impl == "pallas" and Sq > 1:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.flash_attention(
            q, k, v, q_pos, k_pos, causal=causal, window=window, cap=cap,
            k_valid=k_valid)
    # per-row positions / validity (chunked prefill against a ragged
    # cache): the blockwise path only supports shared 1-D positions, so
    # per-row shapes stay on the plain path (chunks are small: Sq == C)
    per_row = (jnp.ndim(q_pos) > 1 or jnp.ndim(k_pos) > 1
               or (k_valid is not None and jnp.ndim(k_valid) > 1))
    if per_row or Sq * Sk <= _CHUNK_THRESHOLD ** 2:
        return plain_attention(q, k, v, q_pos, k_pos, causal=causal,
                               window=window, cap=cap, k_valid=k_valid)
    return chunked_attention(q, k, v, q_pos, k_pos, causal=causal,
                             window=window, cap=cap, k_valid=k_valid)


def attention_block(params, cfg: ModelConfig, spec, x, positions,
                    k_valid=None):
    """Full-sequence (train / prefill) attention layer body."""
    q, k, v = qkv_project(params, cfg, x, positions)
    window = spec.window
    out = multihead_attention(
        q, k, v, positions, positions, causal=cfg.causal, window=window,
        cap=cfg.attn_softcap, k_valid=k_valid)
    B, S = x.shape[:2]
    return out.reshape(B, S, -1) @ params["wo"]
