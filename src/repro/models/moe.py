"""Mixture-of-Experts FFN — GShard-style grouped one-hot einsum dispatch.

Tokens are split into groups of <= `group` tokens; within each group the
top-k routing builds a [g, E, C] one-hot dispatch tensor via cumulative
position counting, and dispatch/combine are pure einsums:

    buf  = einsum('zgec,zgd->zecd', dispatch, x)
    out  = expert_ffn(buf)                      # batched over [Gn, E]
    y    = einsum('zgec,zecd->zgd', combine, out)

Einsums partition cleanly under GSPMD (group dim follows the batch
sharding); scatter/gather-based dispatch triggered involuntary full
rematerialization + whole-buffer all-reduces (§Perf granite iterations
1-2, EXPERIMENTS.md).  Tokens beyond per-group capacity are dropped
(GShard semantics); the router aux loss balances load.  Expert weights
are FSDP-sharded at rest and gathered to (replicated, TP-on-ffn) at use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import FFN, ModelConfig
from repro.models.layers import dense_init

_GROUP = 512


def init_moe(key, cfg: ModelConfig, kind: FFN, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    if kind in (FFN.SWIGLU, FFN.GEGLU):
        experts = {
            "w_gate": dense_init(ks[0], (E, d, f), dtype=dtype),
            "w_up": dense_init(ks[1], (E, d, f), dtype=dtype),
            "w_down": dense_init(ks[2], (E, f, d), dtype=dtype),
        }
    else:
        experts = {
            "w_up": dense_init(ks[0], (E, d, f), dtype=dtype),
            "w_down": dense_init(ks[1], (E, f, d), dtype=dtype),
        }
    return {"router": dense_init(ks[3], (d, E), scale=0.02, dtype=dtype),
            "experts": experts}


def _capacity(group_tokens: int, cfg: ModelConfig) -> int:
    cap = int(group_tokens * cfg.top_k / cfg.n_experts
              * cfg.capacity_factor)
    return max(cap, cfg.top_k)


def _expert_ffn(experts, cfg: ModelConfig, kind: FFN, h, sfx: str = ""):
    """h: [Gn, E, C, d] -> [Gn, E, C, d], batched per expert."""
    from repro.parallel.sharding import hint
    up = jnp.einsum("zecd,edf->zecf", h, experts["w_up"])
    up = hint(up, "moe_hidden" + sfx)
    if kind in (FFN.SWIGLU, FFN.GEGLU):
        gate = jnp.einsum("zecd,edf->zecf", h, experts["w_gate"])
        act = jax.nn.silu(gate) if kind == FFN.SWIGLU else \
            jax.nn.gelu(gate, approximate=True)
        mid = act * up
    elif kind == FFN.SQUARED_RELU:
        mid = jnp.square(jax.nn.relu(up))
    else:
        mid = jax.nn.gelu(up, approximate=True)
    mid = hint(mid, "moe_hidden" + sfx)
    return jnp.einsum("zecf,efd->zecd", mid, experts["w_down"])


def _dense_decode_moe(params, cfg: ModelConfig, kind: FFN, x):
    """Single-token decode: evaluate ALL experts densely and mask by the
    top-k gates.  At S=1 the step is weight-read bound (every expert's
    weights stream from HBM regardless), so dense evaluation beats
    dispatch machinery — the grouped one-hot path is training-optimal
    but inflates decode (§Perf mixtral notes)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(B, d)
    logits = (xt @ params["router"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(gates, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    w_mask = jnp.zeros((B, E), jnp.float32)
    for k in range(K):
        w_mask = w_mask + top_w[:, k, None] * jax.nn.one_hot(
            top_e[:, k], E, dtype=jnp.float32)

    up = jnp.einsum("bd,edf->bef", xt, params["experts"]["w_up"])
    if kind in (FFN.SWIGLU, FFN.GEGLU):
        gate = jnp.einsum("bd,edf->bef", xt, params["experts"]["w_gate"])
        act = jax.nn.silu(gate) if kind == FFN.SWIGLU else \
            jax.nn.gelu(gate, approximate=True)
        mid = act * up
    elif kind == FFN.SQUARED_RELU:
        mid = jnp.square(jax.nn.relu(up))
    else:
        mid = jax.nn.gelu(up, approximate=True)
    out = jnp.einsum("bef,efd->bed", mid, params["experts"]["w_down"])
    y = jnp.einsum("bed,be->bd", out, w_mask.astype(out.dtype))
    aux = jnp.zeros((), jnp.float32)
    return y.reshape(B, S, d).astype(x.dtype), aux


def apply_moe(params, cfg: ModelConfig, kind: FFN, x):
    """x: [B, S, d] -> (y, aux_loss)."""
    from repro.parallel.sharding import hint
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    if S == 1:
        return _dense_decode_moe(params, cfg, kind, x)
    # group within a sequence; at decode (S == 1) group across batch
    # slices instead — per-token groups would inflate capacity slots to
    # E*top_k per token (16x tokens for mixtral) — while keeping >= 16
    # groups so the group dim still shards over the data axis
    g = min(_GROUP, S) if S > 1 else max(min(B // 16, _GROUP), 1)
    assert (B * S) % g == 0
    Gn = B * S // g
    C = _capacity(g, cfg)
    xg = x.reshape(Gn, g, d)

    logits = (xg @ params["router"]).astype(jnp.float32)    # [Gn, g, E]
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(gates, K)                  # [Gn, g, K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # --- position-in-expert via cumulative counting over the K slots ---
    dispatch = jnp.zeros((Gn, g, E, C), x.dtype)
    combine = jnp.zeros((Gn, g, E, C), jnp.float32)
    counts = jnp.zeros((Gn, E), jnp.float32)
    for k in range(K):
        oh = jax.nn.one_hot(top_e[..., k], E, dtype=jnp.float32)  # [Gn,g,E]
        pos = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]
        within = (pos < C).astype(jnp.float32) * oh
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C,
                                dtype=jnp.float32)          # [Gn,g,E,C]
        sel = within[..., None] * pos_oh
        dispatch = dispatch + sel.astype(x.dtype)
        combine = combine + top_w[..., k, None, None] * sel
        counts = counts + oh.sum(axis=1)

    # expert-parallel when E can cover the model axis; TP-on-ffn otherwise
    ep = E >= 16
    sfx = "_ep" if ep else ""
    experts = {
        k2: hint(w, ("moe_w_out" if k2 in ("w_down", "w_value")
                     else "moe_w_in") + sfx)
        for k2, w in params["experts"].items()}
    if ep:
        dispatch = hint(dispatch, "moe_onehot_ep")
        combine = hint(combine, "moe_onehot_ep")

    buf = jnp.einsum("zgec,zgd->zecd", dispatch, xg)
    buf = hint(buf, "moe_buffer" + sfx)
    out = _expert_ffn(experts, cfg, kind, buf, sfx)
    y = jnp.einsum("zgec,zecd->zgd", combine.astype(x.dtype), out)

    # --- aux load-balancing loss (Switch-style) ---
    me = gates.reshape(-1, E).mean(axis=0)
    ce = jax.nn.one_hot(top_e[..., 0].reshape(-1), E,
                        dtype=jnp.float32).mean(axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux
