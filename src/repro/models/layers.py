"""Shared neural-net building blocks (pure functional JAX, no flax).

Parameters are nested dicts of jnp arrays. Every block has an
``init_*(key, cfg, ...) -> params`` and an ``apply`` function.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import FFN, ModelConfig


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0])
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def softcap(x, cap: Optional[float]):
    """Gemma2-style logit soft capping."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                      # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int, dtype=jnp.float32):
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    half = d_model // 2
    freq = jnp.exp(-math.log(10_000.0) * jnp.arange(half) / half)[None, :]
    ang = pos * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embeddings(key, cfg: ModelConfig, dtype):
    keys = jax.random.split(key, 3)
    p = {}
    p["tokens"] = dense_init(keys[0], (cfg.vocab_size, cfg.d_model),
                             scale=0.02, dtype=dtype)
    if cfg.frontend == "features":
        p["feature_proj"] = dense_init(
            keys[1], (cfg.feature_dim, cfg.d_model), dtype=dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[2], (cfg.d_model, cfg.vocab_size),
                                  dtype=dtype)
    return p


def embed_tokens(params, cfg: ModelConfig, tokens):
    emb = jnp.take(params["tokens"], tokens, axis=0)
    if cfg.frontend == "tokens":
        emb = emb * jnp.asarray(math.sqrt(cfg.d_model), emb.dtype)
    return emb


def embed_features(params, cfg: ModelConfig, features):
    return features.astype(params["feature_proj"].dtype) @ params["feature_proj"]


def unembed(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        logits = x @ params["tokens"].T
    else:
        logits = x @ params["lm_head"]
    return softcap(logits, cfg.final_softcap)


# ---------------------------------------------------------------------------
# Feed-forward variants
# ---------------------------------------------------------------------------

def init_ffn(key, cfg: ModelConfig, kind: FFN, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if kind in (FFN.SWIGLU, FFN.GEGLU):
        return {"w_gate": dense_init(ks[0], (d, f), dtype=dtype),
                "w_up": dense_init(ks[1], (d, f), dtype=dtype),
                "w_down": dense_init(ks[2], (f, d), dtype=dtype)}
    if kind == FFN.RWKV_CHANNEL:
        return {"w_key": dense_init(ks[0], (d, f), dtype=dtype),
                "w_value": dense_init(ks[1], (f, d), dtype=dtype),
                "w_recept": dense_init(ks[2], (d, d), dtype=dtype),
                "mu_k": jnp.full((d,), 0.5, dtype),
                "mu_r": jnp.full((d,), 0.5, dtype)}
    # GELU / SQUARED_RELU two-matrix MLP
    return {"w_up": dense_init(ks[0], (d, f), dtype=dtype),
            "w_down": dense_init(ks[1], (f, d), dtype=dtype)}


def apply_ffn(params, cfg: ModelConfig, kind: FFN, x, *, shifted=None):
    """``shifted``: previous-token tensor for RWKV channel mix."""
    if kind == FFN.SWIGLU:
        return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) \
            @ params["w_down"]
    if kind == FFN.GEGLU:
        return (jax.nn.gelu(x @ params["w_gate"], approximate=True)
                * (x @ params["w_up"])) @ params["w_down"]
    if kind == FFN.SQUARED_RELU:
        h = jax.nn.relu(x @ params["w_up"])
        return jnp.square(h) @ params["w_down"]
    if kind == FFN.GELU:
        return jax.nn.gelu(x @ params["w_up"], approximate=True) \
            @ params["w_down"]
    if kind == FFN.RWKV_CHANNEL:
        assert shifted is not None
        xk = x + (shifted - x) * params["mu_k"]
        xr = x + (shifted - x) * params["mu_r"]
        k = jnp.square(jax.nn.relu(xk @ params["w_key"]))
        return jax.nn.sigmoid(xr @ params["w_recept"]) * (k @ params["w_value"])
    raise ValueError(kind)


def token_shift(x):
    """RWKV-style shift: x[t] -> x[t-1] (zeros at t=0). x: [B, S, d]."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
