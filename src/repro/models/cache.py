"""Decode-time state: KV caches (full + ring-buffer windowed), SSM states,
RWKV states.  Cache leaves for the scanned layer stack carry a leading
[R] repeats dim so decode can scan over blocks with per-repeat cache slices.

Every block-cache leaf is laid out ``[R, B, ...]`` with the batch (decode
*slot*) dim at axis 1; ``gather_slots`` / ``scatter_slots`` exploit this
to move whole per-slot cache rows in and out, which is what the
continuous-batching engine (``repro.genserve``) uses to recycle decode
slots: a retired slot's rows are simply overwritten by the freshly
prefilled rows of the next request.

Paged layout (``init_paged_cache`` + the ``paged_*`` helpers): attention
k/v leaves become a shared *page pool* ``[R, n_pages, page_size, KV, hd]``
addressed through a per-slot block table ``[B, max_pages]`` (entry j of a
row maps cache positions ``[j*page_size, (j+1)*page_size)``; the sentinel
value ``n_pages`` marks an unmapped entry).  ``paged_view`` gathers each
slot's pages into exactly the contiguous ``[R, B, L, KV, hd]`` view the
existing decode / prefill-chunk programs consume — everything below the
gather (batched-jnp attention, the Pallas flash-decode kernel, ring
windows, GQA) is untouched — and ``paged_update_decode`` /
``paged_update_chunk`` scatter just the freshly written token k/v back
into the pool (an exact delta: values are extracted from the contiguous
view *after* ``write_kv`` applied its ring/clamp semantics, so a paged
run is token-for-token the contiguous run).  Recurrent (Mamba/RWKV)
state and ``cm_shift`` stay per-slot ``[R, B, ...]``: O(1) state has no
pages to share.  An identity block table (``identity_block_table``) makes
the pool a mere reshape of today's contiguous layout — the exact-parity
fallback.  Page refcounts, copy-on-write and prefix-cache admission are
host-side concerns (``repro.genserve.pagepool``); this module only
provides the device-side indirection (including ``copy_pages`` for the
COW copies the host schedules).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import FFN, Mixer, ModelConfig
from repro.models import mamba as mamba_mod
from repro.models import rwkv as rwkv_mod


def effective_window(cfg: ModelConfig, spec, long_mode: bool) -> Optional[int]:
    """Window for an attention layer; long mode caps global layers."""
    if spec.window is not None:
        return spec.window
    if long_mode and cfg.long_mode_window is not None:
        return cfg.long_mode_window
    return None


def kv_cache_len(cfg: ModelConfig, spec, max_seq: int,
                 long_mode: bool) -> int:
    w = effective_window(cfg, spec, long_mode)
    return min(w, max_seq) if w is not None else max_seq


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
               long_mode: bool = False, dtype=jnp.bfloat16):
    """Cache pytree: {"blocks": {"layer{j}": leaves [R, B, ...]}, "pos": i32}."""
    R = cfg.n_pattern_repeats
    hd = cfg.resolved_head_dim
    blocks = {}
    for j, spec in enumerate(cfg.pattern):
        if spec.mixer == Mixer.ATTENTION:
            L = kv_cache_len(cfg, spec, max_seq, long_mode)
            layer = {
                "k": jnp.zeros((R, batch, L, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((R, batch, L, cfg.n_kv_heads, hd), dtype),
            }
        elif spec.mixer == Mixer.MAMBA:
            st = mamba_mod.init_mamba_state(cfg, batch)
            layer = {k: jnp.broadcast_to(v, (R,) + v.shape)
                     for k, v in st.items()}
        elif spec.mixer == Mixer.RWKV6:
            st = rwkv_mod.init_rwkv_state(cfg, batch)
            layer = {k: jnp.broadcast_to(v, (R,) + v.shape)
                     for k, v in st.items()}
        else:
            raise ValueError(spec.mixer)
        if spec.ffn.value == "rwkv_channel":
            layer["cm_shift"] = jnp.zeros((R, batch, cfg.d_model), dtype)
        blocks[f"layer{j}"] = layer
    return {"blocks": blocks, "pos": jnp.zeros((), jnp.int32)}


def _slot_axes_mask(mask, leaf):
    """Broadcast a [B]-shaped slot mask over a [R, B, ...] cache leaf."""
    return mask.reshape((1, mask.shape[0]) + (1,) * (leaf.ndim - 2))


def gather_slots(blocks, idx):
    """Extract per-slot cache rows: leaves [R, B, ...] -> [R, len(idx), ...].

    `idx` is an int array of slot indices; works under jit (idx traced)."""
    return jax.tree_util.tree_map(lambda l: jnp.take(l, idx, axis=1), blocks)


def scatter_slots(dst_blocks, src_blocks, slot_mask):
    """Overwrite slot rows of `dst_blocks` with `src_blocks` where
    `slot_mask` [B] is True.  Both pytrees have leaves [R, B, ...]; this
    is the whole-row replacement the genserve engine performs at prefill
    injection, so no stale KV/SSM state from the previous occupant can
    leak into a recycled slot."""
    return jax.tree_util.tree_map(
        lambda dst, src: jnp.where(_slot_axes_mask(slot_mask, dst),
                                   src.astype(dst.dtype), dst),
        dst_blocks, src_blocks)


def zero_slots(blocks, slot_mask):
    """Zero the cache rows of slots where `slot_mask` [B] is True.

    Chunked admission starts a slot's prefill from zero-initialized
    state (recurrent mixers accumulate chunk by chunk), so a freshly
    installed slot must not inherit its previous occupant's SSM/RWKV
    state or cm_shift — the KV rows are zeroed too for hygiene (their
    stale contents are already masked by position validity)."""
    return jax.tree_util.tree_map(
        lambda l: jnp.where(_slot_axes_mask(slot_mask, l),
                            jnp.zeros((), l.dtype), l),
        blocks)


def ring_slot_positions(cache_len: int, window: Optional[int], pos):
    """Absolute position stored in each cache slot at decode step `pos`.

    Full cache (window None): slot i holds position i (valid if i <= pos).
    Ring cache: slot i holds the largest p' <= pos with p' % W == i.

    `pos` may be a scalar (shared position, returns [L]) or a [B] vector
    of per-slot positions (batched decode, returns [B, L])."""
    idx = jnp.arange(cache_len)
    p = jnp.asarray(pos, jnp.int32)[..., None]      # [1] or [B, 1]
    if window is None:
        valid = idx <= p
        k_pos = jnp.broadcast_to(idx, valid.shape)
    else:
        W = cache_len
        k_pos = p - ((p - idx) % W)
        valid = k_pos >= 0
    return k_pos, valid


def write_kv(cache_k, cache_v, k_new, v_new, pos, window: Optional[int],
             valid=None):
    """Write a chunk of Sq consecutive tokens' k/v starting at `pos`.

    cache_k: [B, L, KV, hd]; k_new: [B, Sq, KV, hd].  `pos` is a scalar
    (all rows write at the same start position) or a [B] vector of
    per-row start positions (batched wave decode / chunked prefill:
    every slot writes at its own cursor — token j of row b lands at
    absolute position pos[b] + j).  `valid` optionally masks individual
    chunk tokens ([B, Sq] bool): invalid tokens leave the cache
    untouched (the chunked-prefill partial-last-chunk case).

    Ring caches (window not None) wrap at slot p % L; when a chunk spans
    more than one lap of the ring, only the latest token per slot
    survives (matching sequential writes).  Full caches clamp
    out-of-range positions to the last slot (the single-token decode
    guard), keeping the first such token — for a whole-sequence write
    this is exactly the keep-first-L truncation of the former
    ``prefill_kv`` special case (Sq == S, pos == 0), which this
    function now subsumes."""
    B, C = k_new.shape[:2]
    L = cache_k.shape[1]
    p = jnp.asarray(pos)
    if p.ndim == 0 and C == 1 and valid is None:
        # scalar single-token decode fast path
        slot = p % L if window is not None else jnp.minimum(p, L - 1)
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype), (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype), (0, slot, 0, 0))
        return cache_k, cache_v
    if p.ndim == 0 and valid is None:
        # statically-known scalar start (the one-shot prefill and any
        # within-capacity chunk): a contiguous slice update beats the
        # general scatter — take it whenever the chunk provably neither
        # overflows the cache nor wraps the ring
        try:
            start = int(p)
        except (TypeError, jax.errors.TracerIntegerConversionError):
            start = None
        if start is not None:
            if window is None and start + C <= L:
                slot0 = start
            elif window is not None and start % L + C <= L:
                slot0 = start % L
            else:
                slot0 = None
            if slot0 is not None:
                cache_k = jax.lax.dynamic_update_slice(
                    cache_k, k_new.astype(cache_k.dtype), (0, slot0, 0, 0))
                cache_v = jax.lax.dynamic_update_slice(
                    cache_v, v_new.astype(cache_v.dtype), (0, slot0, 0, 0))
                return cache_k, cache_v
    positions = jnp.broadcast_to(
        jnp.asarray(p, jnp.int32).reshape((-1, 1)) + jnp.arange(C), (B, C))
    if valid is None:
        keep = jnp.ones((B, C), bool)
    else:
        keep = valid
    if window is not None:
        # within-chunk ring overwrites: a token is superseded when a
        # later valid token maps to the same slot (positions congruent
        # mod L) — drop it so scatter order cannot matter
        last = jnp.max(jnp.where(keep, positions, -1), axis=1, keepdims=True)
        keep = keep & (positions + L > last)
        slot = positions % L
    else:
        # full cache: clamp past-the-end positions to the last slot and
        # keep only the first such token per row (sequentially, later
        # clamped writes would land on top — but the one-shot prefill
        # semantics this subsumes keep the first L tokens)
        over = keep & (positions >= L - 1)
        first_over = jnp.min(jnp.where(over, positions, 2 ** 30), axis=1,
                             keepdims=True)
        keep = keep & ((positions < L - 1) | (positions == first_over))
        slot = jnp.minimum(positions, L - 1)
    slot = jnp.where(keep, slot, L)       # out of bounds -> update dropped
    rows = jnp.broadcast_to(jnp.arange(B)[:, None], (B, C))
    cache_k = cache_k.at[rows, slot].set(k_new.astype(cache_k.dtype),
                                         mode="drop")
    cache_v = cache_v.at[rows, slot].set(v_new.astype(cache_v.dtype),
                                         mode="drop")
    return cache_k, cache_v


def prefill_kv(cache_k, cache_v, k, v, window: Optional[int]):
    """Fill cache from a one-shot prefill pass. k: [B, S, KV, hd].

    Thin alias over the generalized ``write_kv`` (chunk write starting
    at position 0): full caches keep the first L tokens, ring caches the
    last L — identical layout to writing the sequence token by token."""
    return write_kv(cache_k, cache_v, k, v, 0, window)


# ---------------------------------------------------------------------------
# Paged KV layout: page pools + per-slot block tables
# ---------------------------------------------------------------------------


def supports_prefix_sharing(cfg: ModelConfig, *, long_mode: bool = False) -> bool:
    """Whether prompt-prefix pages may be shared between slots.

    Sharing is only sound when every layer is full-window attention:

    * a ring (windowed) layer keeps wrapping new tokens onto old slots,
      so a shared prefix page would be clobbered by whichever slot
      decodes first — the other sharers would silently read its tokens;
    * recurrent layers (Mamba/RWKV) summarize the prefix into O(1)
      state, and the mixed wave-step has no way to snapshot that state
      at an arbitrary prompt boundary for a later request to adopt.

    The paged *layout* itself still supports windows and recurrent
    state (each slot's pages stay private); only cross-slot reuse is
    gated on this predicate."""
    return all(
        spec.mixer == Mixer.ATTENTION
        and effective_window(cfg, spec, long_mode) is None
        for spec in cfg.pattern)


def supports_speculative_target(cfg: ModelConfig, *,
                                long_mode: bool = False) -> bool:
    """Whether `cfg` can serve as a speculative-decoding *target*.

    The verify step commits only the accepted prefix of each candidate
    chunk (a validity-masked ``write_kv``), so every layer's state must
    be expressible as positional KV that can simply not-be-written:

    * attention layers qualify — ring windows included, because the
      verify step computes fresh chunk k/v without touching the cache
      and the post-accept commit writes exactly ``accept_len`` entries;
    * recurrent mixers (Mamba/RWKV) fold every token into O(1) state
      that cannot be rolled back past a rejection;
    * the rwkv_channel FFN's ``cm_shift`` is the same problem in the
      channel-mix path."""
    return all(
        spec.mixer == Mixer.ATTENTION and spec.ffn != FFN.RWKV_CHANNEL
        for spec in cfg.pattern)


def supports_speculative_draft(cfg: ModelConfig, *,
                               long_mode: bool = False) -> bool:
    """Whether `cfg` can serve as a speculative-decoding *draft*.

    The draft decodes autoregressively *before* acceptance is known, so
    its cache takes writes that may later be rolled back by resetting
    the position cursor.  That is only sound for full (non-ring)
    attention caches: a stale entry at slot >= pos is masked invalid by
    ``ring_slot_positions`` and overwritten before it is ever attended
    to (``_layer_decode`` writes at pos first), whereas a ring write
    wraps onto older in-window slots that a rollback cannot restore.
    Recurrent state and cm_shift are unrecoverable for the same reason
    as the target."""
    return supports_speculative_target(cfg, long_mode=long_mode) and all(
        effective_window(cfg, spec, long_mode) is None
        for spec in cfg.pattern)


def max_pages_per_slot(cfg: ModelConfig, max_seq: int, page_size: int, *,
                       long_mode: bool = False) -> int:
    """Block-table width: pages needed by the largest attention cache."""
    mps = [-(-kv_cache_len(cfg, spec, max_seq, long_mode) // page_size)
           for spec in cfg.pattern if spec.mixer == Mixer.ATTENTION]
    return max(mps, default=0)


def identity_block_table(n_slots: int, max_pages: int) -> np.ndarray:
    """Block table mapping slot b's page j to pool page b*max_pages + j.

    With a pool of exactly ``n_slots * max_pages`` pages this makes the
    paged layout a pure reshape of the contiguous one — the no-sharing
    parity fallback (and the layout ``init_paged_cache`` defaults to)."""
    return np.arange(n_slots * max_pages, dtype=np.int32).reshape(
        n_slots, max_pages)


def init_paged_cache(cfg: ModelConfig, n_slots: int, max_seq: int, *,
                     page_size: int, n_pages: int = 0,
                     long_mode: bool = False, dtype=jnp.bfloat16):
    """Paged cache pytree: same shape as ``init_cache`` except attention
    k/v leaves are page pools ``[R, n_pages, page_size, KV, hd]`` shared
    by all slots; recurrent state and cm_shift stay per-slot
    ``[R, n_slots, ...]``.  ``n_pages=0`` sizes the pool for the
    identity block table (``n_slots * max_pages_per_slot``)."""
    if n_pages <= 0:
        n_pages = n_slots * max_pages_per_slot(
            cfg, max_seq, page_size, long_mode=long_mode)
    R = cfg.n_pattern_repeats
    hd = cfg.resolved_head_dim
    blocks = {}
    for j, spec in enumerate(cfg.pattern):
        if spec.mixer == Mixer.ATTENTION:
            layer = {
                "k": jnp.zeros((R, n_pages, page_size, cfg.n_kv_heads, hd),
                               dtype),
                "v": jnp.zeros((R, n_pages, page_size, cfg.n_kv_heads, hd),
                               dtype),
            }
        elif spec.mixer == Mixer.MAMBA:
            st = mamba_mod.init_mamba_state(cfg, n_slots)
            layer = {k: jnp.broadcast_to(v, (R,) + v.shape)
                     for k, v in st.items()}
        elif spec.mixer == Mixer.RWKV6:
            st = rwkv_mod.init_rwkv_state(cfg, n_slots)
            layer = {k: jnp.broadcast_to(v, (R,) + v.shape)
                     for k, v in st.items()}
        else:
            raise ValueError(spec.mixer)
        if spec.ffn.value == "rwkv_channel":
            layer["cm_shift"] = jnp.zeros((R, n_slots, cfg.d_model), dtype)
        blocks[f"layer{j}"] = layer
    return {"blocks": blocks, "pos": jnp.zeros((), jnp.int32)}


def paged_view(cfg: ModelConfig, blocks, btab, max_seq: int, *,
               page_size: int, long_mode: bool = False,
               identity: bool = False):
    """Gather each slot's pages into the contiguous blocks view
    (attention leaves ``[R, B, L, KV, hd]``) the existing decode /
    prefill-chunk programs consume.  ``btab`` is ``[B, max_pages]``
    int32; unmapped entries hold the sentinel ``n_pages`` and gather
    zeros (their positions are masked by validity anyway).

    ``identity=True`` is the static fast path for pools that are known
    (at trace time) to be addressed by ``identity_block_table`` forever
    — i.e. paged layout without prefix sharing, where slot ``s`` owns
    pages ``[s*MP, (s+1)*MP)`` by construction.  The gather collapses
    to a reshape+slice of the pool (XLA fuses it into the consumer), so
    the indirection fallback costs ~nothing over the contiguous layout.
    Only valid when the pool holds exactly ``B * MP`` pages."""
    out = {}
    B = btab.shape[0]
    for j, spec in enumerate(cfg.pattern):
        name = f"layer{j}"
        layer = blocks[name]
        if spec.mixer != Mixer.ATTENTION:
            out[name] = layer
            continue
        L = kv_cache_len(cfg, spec, max_seq, long_mode)
        mp = -(-L // page_size)

        def g(pool):
            if identity:
                assert pool.shape[1] == B * btab.shape[1], \
                    "identity view needs a pool of exactly B*MP pages"
                t = pool.reshape(pool.shape[0], B,
                                 btab.shape[1] * page_size, *pool.shape[3:])
                return t[:, :, :L]
            t = jnp.take(pool, btab[:, :mp], axis=1, mode="fill",
                         fill_value=0)
            t = t.reshape(t.shape[0], t.shape[1], mp * page_size,
                          *t.shape[4:])
            return t[:, :, :L]

        new = dict(layer)
        new["k"] = g(layer["k"])
        new["v"] = g(layer["v"])
        out[name] = new
    return out


def _merge_per_slot(layer, new, mask):
    """Emit-masked per-slot merge for non-pool leaves of a layer dict."""
    return {
        kk: jnp.where(_slot_axes_mask(mask, leaf),
                      new[kk].astype(leaf.dtype), leaf)
        for kk, leaf in layer.items()
    }


def paged_update_decode(cfg: ModelConfig, blocks, new_blocks, btab, pos,
                        emit, max_seq: int, *, page_size: int,
                        long_mode: bool = False):
    """Scatter a decode step's per-row token delta back into the pools.

    ``new_blocks`` is the contiguous view *after* ``decode_step`` ran on
    it; ``pos`` is the [B] pre-increment per-slot position the token was
    written at (ring slot pos % L, full-cache slot min(pos, L-1) — the
    same mapping ``write_kv`` used, so the extracted value is exact).
    Rows where ``emit`` is False write nothing: a freed page can never
    be corrupted by a retired slot's stale block-table row."""
    B = btab.shape[0]
    rows = jnp.arange(B)
    p = jnp.asarray(pos, jnp.int32)
    out = {}
    for j, spec in enumerate(cfg.pattern):
        name = f"layer{j}"
        layer = blocks[name]
        new = new_blocks[name]
        if spec.mixer != Mixer.ATTENTION:
            out[name] = _merge_per_slot(layer, new, emit)
            continue
        L = kv_cache_len(cfg, spec, max_seq, long_mode)
        w = effective_window(cfg, spec, long_mode)
        slot = p % L if w is not None else jnp.minimum(p, L - 1)
        n_pool = layer["k"].shape[1]
        page = btab[rows, slot // page_size]
        page = jnp.where(emit, page, n_pool)   # masked rows -> dropped
        off = slot % page_size

        def u(pool, view):
            val = jnp.take_along_axis(
                view, slot[None, :, None, None, None], axis=2)[:, :, 0]
            return pool.at[:, page, off].set(val.astype(pool.dtype),
                                             mode="drop")

        upd = {kk: leaf for kk, leaf in layer.items()}
        upd["k"] = u(layer["k"], new["k"])
        upd["v"] = u(layer["v"], new["v"])
        for kk in layer:
            if kk not in ("k", "v"):
                upd[kk] = jnp.where(_slot_axes_mask(emit, layer[kk]),
                                    new[kk].astype(layer[kk].dtype),
                                    layer[kk])
        out[name] = upd
    return out


def paged_update_chunk(cfg: ModelConfig, blocks, new_blocks, btab, pcur,
                       n_valid, chunk: int, max_seq: int, *,
                       page_size: int, long_mode: bool = False):
    """Scatter a prefill chunk's token deltas back into the pools.

    Chunk token c of row b sits at absolute position ``pcur[b] + c`` and
    is live when ``c < n_valid[b]``.  Values are read from the
    post-``prefill_chunk_step`` contiguous view at the slot each
    position maps to, so ring-wrap duplicates and full-cache clamps all
    read the *final* value ``write_kv`` left there — duplicate scatter
    targets carry identical payloads and ordering cannot matter."""
    B = btab.shape[0]
    rows = jnp.arange(B)[:, None]
    positions = jnp.asarray(pcur, jnp.int32)[:, None] + jnp.arange(chunk)
    valid = jnp.arange(chunk)[None, :] < jnp.asarray(n_valid)[:, None]
    out = {}
    for j, spec in enumerate(cfg.pattern):
        name = f"layer{j}"
        layer = blocks[name]
        new = new_blocks[name]
        if spec.mixer != Mixer.ATTENTION:
            out[name] = _merge_per_slot(layer, new, jnp.asarray(n_valid) > 0)
            continue
        L = kv_cache_len(cfg, spec, max_seq, long_mode)
        w = effective_window(cfg, spec, long_mode)
        slot = positions % L if w is not None else jnp.minimum(positions,
                                                               L - 1)
        n_pool = layer["k"].shape[1]
        page = btab[jnp.broadcast_to(rows, slot.shape), slot // page_size]
        page = jnp.where(valid, page, n_pool)
        off = slot % page_size

        def u(pool, view):
            val = jnp.take_along_axis(
                view, slot[None, :, :, None, None], axis=2)
            return pool.at[:, page, off].set(val.astype(pool.dtype),
                                             mode="drop")

        upd = {kk: leaf for kk, leaf in layer.items()}
        upd["k"] = u(layer["k"], new["k"])
        upd["v"] = u(layer["v"], new["v"])
        for kk in layer:
            if kk not in ("k", "v"):
                upd[kk] = jnp.where(
                    _slot_axes_mask(jnp.asarray(n_valid) > 0, layer[kk]),
                    new[kk].astype(layer[kk].dtype), layer[kk])
        out[name] = upd
    return out


def copy_pages(cfg: ModelConfig, blocks, src, dst):
    """Device-side page copies: ``pool[dst[i]] = pool[src[i]]`` in every
    attention layer's k and v pool — the copy-on-write step the host
    allocator schedules when an admission diverges mid-page from a
    cached prefix.  ``src``/``dst`` are equal-length int32 vectors;
    entries padded with the sentinel page id are dropped."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    out = {}
    for j, spec in enumerate(cfg.pattern):
        name = f"layer{j}"
        layer = blocks[name]
        if spec.mixer != Mixer.ATTENTION:
            out[name] = layer
            continue
        upd = dict(layer)
        for kk in ("k", "v"):
            pool = layer[kk]
            vals = jnp.take(pool, src, axis=1, mode="fill", fill_value=0)
            upd[kk] = pool.at[:, dst].set(vals, mode="drop")
        out[name] = upd
    return out


def zero_paged_slots(cfg: ModelConfig, blocks, slot_mask):
    """Paged analogue of ``zero_slots``: zero only the *per-slot* leaves
    (recurrent state, cm_shift) of slots where ``slot_mask`` is True.
    Page-pool k/v is deliberately left untouched — stale page contents
    are masked by position validity exactly as in the contiguous layout,
    and zeroing through a recycled slot's block-table row could clobber
    a page still referenced by a live sharer."""
    out = {}
    for j, spec in enumerate(cfg.pattern):
        name = f"layer{j}"
        layer = blocks[name]
        upd = {}
        for kk, leaf in layer.items():
            if spec.mixer == Mixer.ATTENTION and kk in ("k", "v"):
                upd[kk] = leaf
            else:
                upd[kk] = jnp.where(_slot_axes_mask(slot_mask, leaf),
                                    jnp.zeros((), leaf.dtype), leaf)
        out[name] = upd
    return out
