"""Decode-time state: KV caches (full + ring-buffer windowed), SSM states,
RWKV states.  Cache leaves for the scanned layer stack carry a leading
[R] repeats dim so decode can scan over blocks with per-repeat cache slices.

Every block-cache leaf is laid out ``[R, B, ...]`` with the batch (decode
*slot*) dim at axis 1; ``gather_slots`` / ``scatter_slots`` exploit this
to move whole per-slot cache rows in and out, which is what the
continuous-batching engine (``repro.genserve``) uses to recycle decode
slots: a retired slot's rows are simply overwritten by the freshly
prefilled rows of the next request.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import Mixer, ModelConfig
from repro.models import mamba as mamba_mod
from repro.models import rwkv as rwkv_mod


def effective_window(cfg: ModelConfig, spec, long_mode: bool) -> Optional[int]:
    """Window for an attention layer; long mode caps global layers."""
    if spec.window is not None:
        return spec.window
    if long_mode and cfg.long_mode_window is not None:
        return cfg.long_mode_window
    return None


def kv_cache_len(cfg: ModelConfig, spec, max_seq: int,
                 long_mode: bool) -> int:
    w = effective_window(cfg, spec, long_mode)
    return min(w, max_seq) if w is not None else max_seq


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
               long_mode: bool = False, dtype=jnp.bfloat16):
    """Cache pytree: {"blocks": {"layer{j}": leaves [R, B, ...]}, "pos": i32}."""
    R = cfg.n_pattern_repeats
    hd = cfg.resolved_head_dim
    blocks = {}
    for j, spec in enumerate(cfg.pattern):
        if spec.mixer == Mixer.ATTENTION:
            L = kv_cache_len(cfg, spec, max_seq, long_mode)
            layer = {
                "k": jnp.zeros((R, batch, L, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((R, batch, L, cfg.n_kv_heads, hd), dtype),
            }
        elif spec.mixer == Mixer.MAMBA:
            st = mamba_mod.init_mamba_state(cfg, batch)
            layer = {k: jnp.broadcast_to(v, (R,) + v.shape)
                     for k, v in st.items()}
        elif spec.mixer == Mixer.RWKV6:
            st = rwkv_mod.init_rwkv_state(cfg, batch)
            layer = {k: jnp.broadcast_to(v, (R,) + v.shape)
                     for k, v in st.items()}
        else:
            raise ValueError(spec.mixer)
        if spec.ffn.value == "rwkv_channel":
            layer["cm_shift"] = jnp.zeros((R, batch, cfg.d_model), dtype)
        blocks[f"layer{j}"] = layer
    return {"blocks": blocks, "pos": jnp.zeros((), jnp.int32)}


def _slot_axes_mask(mask, leaf):
    """Broadcast a [B]-shaped slot mask over a [R, B, ...] cache leaf."""
    return mask.reshape((1, mask.shape[0]) + (1,) * (leaf.ndim - 2))


def gather_slots(blocks, idx):
    """Extract per-slot cache rows: leaves [R, B, ...] -> [R, len(idx), ...].

    `idx` is an int array of slot indices; works under jit (idx traced)."""
    return jax.tree_util.tree_map(lambda l: jnp.take(l, idx, axis=1), blocks)


def scatter_slots(dst_blocks, src_blocks, slot_mask):
    """Overwrite slot rows of `dst_blocks` with `src_blocks` where
    `slot_mask` [B] is True.  Both pytrees have leaves [R, B, ...]; this
    is the whole-row replacement the genserve engine performs at prefill
    injection, so no stale KV/SSM state from the previous occupant can
    leak into a recycled slot."""
    return jax.tree_util.tree_map(
        lambda dst, src: jnp.where(_slot_axes_mask(slot_mask, dst),
                                   src.astype(dst.dtype), dst),
        dst_blocks, src_blocks)


def zero_slots(blocks, slot_mask):
    """Zero the cache rows of slots where `slot_mask` [B] is True.

    Chunked admission starts a slot's prefill from zero-initialized
    state (recurrent mixers accumulate chunk by chunk), so a freshly
    installed slot must not inherit its previous occupant's SSM/RWKV
    state or cm_shift — the KV rows are zeroed too for hygiene (their
    stale contents are already masked by position validity)."""
    return jax.tree_util.tree_map(
        lambda l: jnp.where(_slot_axes_mask(slot_mask, l),
                            jnp.zeros((), l.dtype), l),
        blocks)


def ring_slot_positions(cache_len: int, window: Optional[int], pos):
    """Absolute position stored in each cache slot at decode step `pos`.

    Full cache (window None): slot i holds position i (valid if i <= pos).
    Ring cache: slot i holds the largest p' <= pos with p' % W == i.

    `pos` may be a scalar (shared position, returns [L]) or a [B] vector
    of per-slot positions (batched decode, returns [B, L])."""
    idx = jnp.arange(cache_len)
    p = jnp.asarray(pos, jnp.int32)[..., None]      # [1] or [B, 1]
    if window is None:
        valid = idx <= p
        k_pos = jnp.broadcast_to(idx, valid.shape)
    else:
        W = cache_len
        k_pos = p - ((p - idx) % W)
        valid = k_pos >= 0
    return k_pos, valid


def write_kv(cache_k, cache_v, k_new, v_new, pos, window: Optional[int],
             valid=None):
    """Write a chunk of Sq consecutive tokens' k/v starting at `pos`.

    cache_k: [B, L, KV, hd]; k_new: [B, Sq, KV, hd].  `pos` is a scalar
    (all rows write at the same start position) or a [B] vector of
    per-row start positions (batched wave decode / chunked prefill:
    every slot writes at its own cursor — token j of row b lands at
    absolute position pos[b] + j).  `valid` optionally masks individual
    chunk tokens ([B, Sq] bool): invalid tokens leave the cache
    untouched (the chunked-prefill partial-last-chunk case).

    Ring caches (window not None) wrap at slot p % L; when a chunk spans
    more than one lap of the ring, only the latest token per slot
    survives (matching sequential writes).  Full caches clamp
    out-of-range positions to the last slot (the single-token decode
    guard), keeping the first such token — for a whole-sequence write
    this is exactly the keep-first-L truncation of the former
    ``prefill_kv`` special case (Sq == S, pos == 0), which this
    function now subsumes."""
    B, C = k_new.shape[:2]
    L = cache_k.shape[1]
    p = jnp.asarray(pos)
    if p.ndim == 0 and C == 1 and valid is None:
        # scalar single-token decode fast path
        slot = p % L if window is not None else jnp.minimum(p, L - 1)
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype), (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype), (0, slot, 0, 0))
        return cache_k, cache_v
    if p.ndim == 0 and valid is None:
        # statically-known scalar start (the one-shot prefill and any
        # within-capacity chunk): a contiguous slice update beats the
        # general scatter — take it whenever the chunk provably neither
        # overflows the cache nor wraps the ring
        try:
            start = int(p)
        except (TypeError, jax.errors.TracerIntegerConversionError):
            start = None
        if start is not None:
            if window is None and start + C <= L:
                slot0 = start
            elif window is not None and start % L + C <= L:
                slot0 = start % L
            else:
                slot0 = None
            if slot0 is not None:
                cache_k = jax.lax.dynamic_update_slice(
                    cache_k, k_new.astype(cache_k.dtype), (0, slot0, 0, 0))
                cache_v = jax.lax.dynamic_update_slice(
                    cache_v, v_new.astype(cache_v.dtype), (0, slot0, 0, 0))
                return cache_k, cache_v
    positions = jnp.broadcast_to(
        jnp.asarray(p, jnp.int32).reshape((-1, 1)) + jnp.arange(C), (B, C))
    if valid is None:
        keep = jnp.ones((B, C), bool)
    else:
        keep = valid
    if window is not None:
        # within-chunk ring overwrites: a token is superseded when a
        # later valid token maps to the same slot (positions congruent
        # mod L) — drop it so scatter order cannot matter
        last = jnp.max(jnp.where(keep, positions, -1), axis=1, keepdims=True)
        keep = keep & (positions + L > last)
        slot = positions % L
    else:
        # full cache: clamp past-the-end positions to the last slot and
        # keep only the first such token per row (sequentially, later
        # clamped writes would land on top — but the one-shot prefill
        # semantics this subsumes keep the first L tokens)
        over = keep & (positions >= L - 1)
        first_over = jnp.min(jnp.where(over, positions, 2 ** 30), axis=1,
                             keepdims=True)
        keep = keep & ((positions < L - 1) | (positions == first_over))
        slot = jnp.minimum(positions, L - 1)
    slot = jnp.where(keep, slot, L)       # out of bounds -> update dropped
    rows = jnp.broadcast_to(jnp.arange(B)[:, None], (B, C))
    cache_k = cache_k.at[rows, slot].set(k_new.astype(cache_k.dtype),
                                         mode="drop")
    cache_v = cache_v.at[rows, slot].set(v_new.astype(cache_v.dtype),
                                         mode="drop")
    return cache_k, cache_v


def prefill_kv(cache_k, cache_v, k, v, window: Optional[int]):
    """Fill cache from a one-shot prefill pass. k: [B, S, KV, hd].

    Thin alias over the generalized ``write_kv`` (chunk write starting
    at position 0): full caches keep the first L tokens, ring caches the
    last L — identical layout to writing the sequence token by token."""
    return write_kv(cache_k, cache_v, k, v, 0, window)
