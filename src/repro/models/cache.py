"""Decode-time state: KV caches (full + ring-buffer windowed), SSM states,
RWKV states.  Cache leaves for the scanned layer stack carry a leading
[R] repeats dim so decode can scan over blocks with per-repeat cache slices.

Every block-cache leaf is laid out ``[R, B, ...]`` with the batch (decode
*slot*) dim at axis 1; ``gather_slots`` / ``scatter_slots`` exploit this
to move whole per-slot cache rows in and out, which is what the
continuous-batching engine (``repro.genserve``) uses to recycle decode
slots: a retired slot's rows are simply overwritten by the freshly
prefilled rows of the next request.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import Mixer, ModelConfig
from repro.models import mamba as mamba_mod
from repro.models import rwkv as rwkv_mod


def effective_window(cfg: ModelConfig, spec, long_mode: bool) -> Optional[int]:
    """Window for an attention layer; long mode caps global layers."""
    if spec.window is not None:
        return spec.window
    if long_mode and cfg.long_mode_window is not None:
        return cfg.long_mode_window
    return None


def kv_cache_len(cfg: ModelConfig, spec, max_seq: int,
                 long_mode: bool) -> int:
    w = effective_window(cfg, spec, long_mode)
    return min(w, max_seq) if w is not None else max_seq


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
               long_mode: bool = False, dtype=jnp.bfloat16):
    """Cache pytree: {"blocks": {"layer{j}": leaves [R, B, ...]}, "pos": i32}."""
    R = cfg.n_pattern_repeats
    hd = cfg.resolved_head_dim
    blocks = {}
    for j, spec in enumerate(cfg.pattern):
        if spec.mixer == Mixer.ATTENTION:
            L = kv_cache_len(cfg, spec, max_seq, long_mode)
            layer = {
                "k": jnp.zeros((R, batch, L, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((R, batch, L, cfg.n_kv_heads, hd), dtype),
            }
        elif spec.mixer == Mixer.MAMBA:
            st = mamba_mod.init_mamba_state(cfg, batch)
            layer = {k: jnp.broadcast_to(v, (R,) + v.shape)
                     for k, v in st.items()}
        elif spec.mixer == Mixer.RWKV6:
            st = rwkv_mod.init_rwkv_state(cfg, batch)
            layer = {k: jnp.broadcast_to(v, (R,) + v.shape)
                     for k, v in st.items()}
        else:
            raise ValueError(spec.mixer)
        if spec.ffn.value == "rwkv_channel":
            layer["cm_shift"] = jnp.zeros((R, batch, cfg.d_model), dtype)
        blocks[f"layer{j}"] = layer
    return {"blocks": blocks, "pos": jnp.zeros((), jnp.int32)}


def _slot_axes_mask(mask, leaf):
    """Broadcast a [B]-shaped slot mask over a [R, B, ...] cache leaf."""
    return mask.reshape((1, mask.shape[0]) + (1,) * (leaf.ndim - 2))


def gather_slots(blocks, idx):
    """Extract per-slot cache rows: leaves [R, B, ...] -> [R, len(idx), ...].

    `idx` is an int array of slot indices; works under jit (idx traced)."""
    return jax.tree_util.tree_map(lambda l: jnp.take(l, idx, axis=1), blocks)


def scatter_slots(dst_blocks, src_blocks, slot_mask):
    """Overwrite slot rows of `dst_blocks` with `src_blocks` where
    `slot_mask` [B] is True.  Both pytrees have leaves [R, B, ...]; this
    is the whole-row replacement the genserve engine performs at prefill
    injection, so no stale KV/SSM state from the previous occupant can
    leak into a recycled slot."""
    return jax.tree_util.tree_map(
        lambda dst, src: jnp.where(_slot_axes_mask(slot_mask, dst),
                                   src.astype(dst.dtype), dst),
        dst_blocks, src_blocks)


def ring_slot_positions(cache_len: int, window: Optional[int], pos):
    """Absolute position stored in each cache slot at decode step `pos`.

    Full cache (window None): slot i holds position i (valid if i <= pos).
    Ring cache: slot i holds the largest p' <= pos with p' % W == i.

    `pos` may be a scalar (shared position, returns [L]) or a [B] vector
    of per-slot positions (batched decode, returns [B, L])."""
    idx = jnp.arange(cache_len)
    p = jnp.asarray(pos, jnp.int32)[..., None]      # [1] or [B, 1]
    if window is None:
        valid = idx <= p
        k_pos = jnp.broadcast_to(idx, valid.shape)
    else:
        W = cache_len
        k_pos = p - ((p - idx) % W)
        valid = k_pos >= 0
    return k_pos, valid


def write_kv(cache_k, cache_v, k_new, v_new, pos, window: Optional[int]):
    """Write one token's k/v at decode position `pos`.

    cache_k: [B, L, KV, hd]; k_new: [B, 1, KV, hd].  `pos` is a scalar
    (all rows write the same slot) or a [B] vector of per-row positions
    (batched wave decode: each slot writes at its own ring offset)."""
    L = cache_k.shape[1]
    p = jnp.asarray(pos)
    if p.ndim == 0:
        slot = p % L if window is not None else jnp.minimum(p, L - 1)
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype), (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype), (0, slot, 0, 0))
        return cache_k, cache_v
    slot = p % L if window is not None else jnp.minimum(p, L - 1)
    rows = jnp.arange(cache_k.shape[0])
    cache_k = cache_k.at[rows, slot].set(k_new[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[rows, slot].set(v_new[:, 0].astype(cache_v.dtype))
    return cache_k, cache_v


def prefill_kv(cache_k, cache_v, k, v, window: Optional[int]):
    """Fill cache from a prefill pass. k: [B, S, KV, hd]."""
    S = k.shape[1]
    L = cache_k.shape[1]
    if window is None or S <= L:
        n = min(S, L)
        cache_k = cache_k.at[:, :n].set(k[:, :n].astype(cache_k.dtype))
        cache_v = cache_v.at[:, :n].set(v[:, :n].astype(cache_v.dtype))
        return cache_k, cache_v
    # ring layout: keep last L positions at slot p % L
    keep = jnp.arange(S - L, S)
    slots = keep % L
    cache_k = cache_k.at[:, slots].set(k[:, keep].astype(cache_k.dtype))
    cache_v = cache_v.at[:, slots].set(v[:, keep].astype(cache_v.dtype))
    return cache_k, cache_v
