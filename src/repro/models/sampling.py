"""Shared token-sampling and EOS/aliveness helpers.

Both decode paths — the single-wave reference (`rl.rollout.generate`) and
the continuous-batching engine (`repro.genserve`) — sample tokens and
track sequence aliveness with these helpers so their masking semantics
cannot drift apart.

Aliveness contract (shared by rollout and genserve):
  * a sequence starts alive unless its prompt already ends with EOS
    (the model finished before generating anything);
  * the first emitted EOS token is itself *valid* (mask 1) — the
    sequence dies after emitting it, so every later position is invalid.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample_tokens(rng, logits, *, temperature: float = 1.0,
                  greedy: bool = False):
    """logits [..., V] -> int32 token ids (argmax or categorical)."""
    if greedy or temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        rng, logits.astype(jnp.float32) / temperature, axis=-1) \
        .astype(jnp.int32)


def token_logprobs(logits, tokens):
    """Log-probabilities of `tokens` [...]. under `logits` [..., V]."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(logp, tokens[..., None], axis=-1)[..., 0]


def sample_with_logprobs(rng, logits, *, temperature: float = 1.0,
                         greedy: bool = False):
    """Sample tokens and score them in one call: (tokens, logprobs).

    The single sampling point shared by the rollout decode loop, the
    genserve wave decode / mixed-landing programs and the speculative
    verify step — temperature/greedy semantics cannot drift between
    them.  Logprobs are always the *untempered* model logprobs of the
    chosen token (the RL objective scores under the model, not the
    sampler)."""
    tokens = sample_tokens(rng, logits, temperature=temperature,
                           greedy=greedy)
    return tokens, token_logprobs(logits, tokens)


def speculative_accept(rng, target_logits, drafts, draft_logits, *,
                       temperature: float = 1.0, greedy: bool = False):
    """Batched draft-token acceptance for speculative decoding.

    target_logits: [B, k+1, V] — one batched target step over the
      candidate chunk [t0, d_1..d_k]; position j scores the token that
      follows the prefix [t0, d_1..d_j], so row j is the target
      distribution at the j-th speculated position.
    drafts: [B, k] int32 — the draft proposals d_1..d_k.
    draft_logits: [B, k, V] — the draft distributions the proposals were
      sampled from (ignored on the greedy path; may be anything there).

    Returns (accept_len [B] int32 in [0, k], cand [B, k+1] int32):
      cand[:, j] == d_{j+1} for j < accept_len, and cand[:, accept_len]
      is the bonus/corrected token t* — greedy: the target argmax at the
      first mismatch (or after all k accepts); sampled: standard
      rejection-resampling (accept d_j iff u_j < p_j(d_j)/q_j(d_j),
      resample t* from the residual ``max(p - q, 0)`` on rejection, from
      p itself when all k accepted) so the emitted-token distribution is
      exactly the target's.  Positions past accept_len hold t*
      (don't-care: the caller's validity mask cuts there)."""
    B, k1, V = target_logits.shape
    k = k1 - 1
    tl = target_logits.astype(jnp.float32)
    if greedy or temperature <= 0:
        tgt = jnp.argmax(tl, axis=-1).astype(jnp.int32)        # [B, k+1]
        match = drafts == tgt[:, :k]                           # [B, k]
        accept = jnp.cumprod(match.astype(jnp.int32), axis=1)
        a = jnp.sum(accept, axis=1).astype(jnp.int32)          # [B]
        tstar = jnp.take_along_axis(tgt, a[:, None], axis=1)[:, 0]
    else:
        u_key, r_key = jax.random.split(rng)
        p = jax.nn.softmax(tl / temperature, axis=-1)          # [B,k+1,V]
        q = jax.nn.softmax(draft_logits.astype(jnp.float32) / temperature,
                           axis=-1)                            # [B, k, V]
        p_d = jnp.take_along_axis(p[:, :k], drafts[..., None],
                                  axis=-1)[..., 0]             # [B, k]
        q_d = jnp.take_along_axis(q, drafts[..., None], axis=-1)[..., 0]
        u = jax.random.uniform(u_key, (B, k))
        ok = u * jnp.maximum(q_d, 1e-30) < p_d
        accept = jnp.cumprod(ok.astype(jnp.int32), axis=1)
        a = jnp.sum(accept, axis=1).astype(jnp.int32)
        # residual at the rejection point: max(p_a - q_a, 0); q padded
        # with zeros at position k so the all-accepted bonus draw is
        # from p_k itself
        q_ext = jnp.concatenate([q, jnp.zeros((B, 1, V), q.dtype)], axis=1)
        p_a = jnp.take_along_axis(p, a[:, None, None], axis=1)[:, 0]
        q_a = jnp.take_along_axis(q_ext, a[:, None, None], axis=1)[:, 0]
        resid = jnp.maximum(p_a - q_a, 0.0)
        # degenerate residual (p <= q everywhere yet a rejection fired —
        # numerically possible): fall back to the target distribution
        resid = jnp.where(jnp.sum(resid, axis=-1, keepdims=True) > 0.0,
                          resid, p_a)
        tstar = jax.random.categorical(
            r_key, jnp.log(jnp.maximum(resid, 1e-30)), axis=-1) \
            .astype(jnp.int32)
    drafts_ext = jnp.concatenate(
        [drafts, jnp.zeros((B, 1), jnp.int32)], axis=1)        # [B, k+1]
    cand = jnp.where(jnp.arange(k1)[None, :] < a[:, None],
                     drafts_ext, tstar[:, None])
    return a, cand


def initial_alive(prompts, eos_token: Optional[int]):
    """[B] bool: alive at generation start.

    A prompt whose last token is already EOS produced a finished
    sequence — its first sampled token (and everything after) is
    invalid."""
    if eos_token is None:
        return jnp.ones((prompts.shape[0],), bool)
    return prompts[:, -1] != eos_token


def next_alive(alive, emitted, eos_token: Optional[int]):
    """Aliveness after emitting `emitted` [B]: dies on (its own) EOS.

    The emitted EOS is still valid under `alive`; only subsequent
    positions are masked."""
    if eos_token is None:
        return alive
    return alive & (emitted != eos_token)
