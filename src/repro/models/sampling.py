"""Shared token-sampling and EOS/aliveness helpers.

Both decode paths — the single-wave reference (`rl.rollout.generate`) and
the continuous-batching engine (`repro.genserve`) — sample tokens and
track sequence aliveness with these helpers so their masking semantics
cannot drift apart.

Aliveness contract (shared by rollout and genserve):
  * a sequence starts alive unless its prompt already ends with EOS
    (the model finished before generating anything);
  * the first emitted EOS token is itself *valid* (mask 1) — the
    sequence dies after emitting it, so every later position is invalid.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample_tokens(rng, logits, *, temperature: float = 1.0,
                  greedy: bool = False):
    """logits [..., V] -> int32 token ids (argmax or categorical)."""
    if greedy or temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        rng, logits.astype(jnp.float32) / temperature, axis=-1) \
        .astype(jnp.int32)


def token_logprobs(logits, tokens):
    """Log-probabilities of `tokens` [...]. under `logits` [..., V]."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(logp, tokens[..., None], axis=-1)[..., 0]


def initial_alive(prompts, eos_token: Optional[int]):
    """[B] bool: alive at generation start.

    A prompt whose last token is already EOS produced a finished
    sequence — its first sampled token (and everything after) is
    invalid."""
    if eos_token is None:
        return jnp.ones((prompts.shape[0],), bool)
    return prompts[:, -1] != eos_token


def next_alive(alive, emitted, eos_token: Optional[int]):
    """Aliveness after emitting `emitted` [B]: dies on (its own) EOS.

    The emitted EOS is still valid under `alive`; only subsequent
    positions are masked."""
    if eos_token is None:
        return alive
    return alive & (emitted != eos_token)
