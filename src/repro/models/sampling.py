"""Standalone decoding helpers (serving path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig


def greedy_decode(params, cfg: ModelConfig, prompts, n_new: int,
                  long_mode: bool = False):
    """prompts: [B, P] -> generated tokens [B, n_new] (greedy)."""
    B, P = prompts.shape
    out = T.forward(params, cfg, {"tokens": prompts}, return_cache=True,
                    max_cache_len=P + n_new, remat=False,
                    long_mode=long_mode)
    cache = out["cache"]
    tok = jnp.argmax(out["logits"][:, -1], axis=-1).astype(jnp.int32)

    def step(carry, _):
        cache, tok = carry
        logits, cache = T.decode_step(params, cfg, tok[:, None], cache,
                                      long_mode=long_mode)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (cache, nxt), tok

    (_, last), toks = jax.lax.scan(step, (cache, tok), None,
                                   length=n_new - 1)
    return jnp.concatenate([toks.T, last[:, None]], axis=1) \
        if n_new > 1 else tok[:, None]
