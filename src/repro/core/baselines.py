"""Baseline schedulers the paper compares against (§5.1, §5.4, §6).

* verl-like   — SoTA homogeneous RL scheduler: colocates all tasks on all
  GPUs, enumerates uniform parallelizations with a homogeneity-assuming
  cost model (device/network heterogeneity invisible: it sees mean TFLOPS
  and mean bandwidth), contiguous device order.
* StreamRL-like — two groups: actor generation | everything else, each
  group required to be "homogeneous and in one data center": devices are
  grouped by (region, spec) and the split picks whole homogeneous islands.
* DEAP-like pure EA — standard EA over the full space: random init, generic
  mutation, no SHA statistics, no custom upgrade mutation, no Baldwinian
  local search.
* Pure SHA — SHA over Levels 1-2 with random (not EA) low-level sampling.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import enumerate as enum_mod
from repro.core.costmodel import CostModel
from repro.core.ea import EvolutionarySearch, Individual
from repro.core.plan import Plan, check_constraints, \
    feasible_parallelizations
from repro.core.sha import SearchResult
from repro.core.topology import Device, GPUSpec, Topology
from repro.core.workflow import RLWorkflow, TaskKind


# ---------------------------------------------------------------------------
# verl-like
# ---------------------------------------------------------------------------

def _homogenized(topo: Topology) -> Topology:
    """What a homogeneity-assuming scheduler believes the cluster is."""
    mean_tflops = float(np.mean([d.spec.fp16_tflops for d in topo.devices]))
    mean_mem = float(np.mean([d.spec.mem_gb for d in topo.devices]))
    mean_hbm = float(np.mean([d.spec.hbm_gbps for d in topo.devices]))
    spec = GPUSpec("uniform", mean_tflops, mean_mem, mean_hbm, 64.0 / 8)
    devices = [Device(d.id, spec, d.machine, 0, "uniform")
               for d in topo.devices]
    off = topo.latency_s[~np.eye(topo.n, dtype=bool)]
    bwo = topo.bandwidth_gbps[~np.eye(topo.n, dtype=bool)]
    lat = np.full_like(topo.latency_s, float(np.mean(off)))
    np.fill_diagonal(lat, 0.0)
    bw = np.full_like(topo.bandwidth_gbps, float(np.mean(bwo)))
    np.fill_diagonal(bw, 1e9)
    return Topology(devices, lat, bw)


def _full_cluster_factorizations(n: int, n_layers: int):
    out = []
    for tp in (1, 2, 4, 8):
        if n % tp:
            continue
        rest = n // tp
        for pp in range(1, min(n_layers, rest) + 1):
            if rest % pp:
                continue
            out.append((rest // pp, pp, tp))
    return out


def verl_scheduler(topo: Topology, wf: RLWorkflow, budget: int = 256,
                   eta: Optional[float] = None) -> SearchResult:
    """verl-like: colocate every task on ALL GPUs (time-shared) and pick
    each task's (dp, pp, tp) independently with a homogenized view of the
    cluster; evaluated on the real topology."""
    fake = _homogenized(topo)
    cm_fake = CostModel(fake, wf, eta=eta)
    cm_real = CostModel(topo, wf, eta=eta)
    grouping = (tuple(range(wf.n_tasks)),)
    sizes = [topo.n]
    order = list(range(topo.n))
    evals = 0

    # rank each task's full-cluster factorizations by homogenized cost
    ranked: Dict[int, List[Tuple[int, int, int]]] = {}
    for t in range(wf.n_tasks):
        cands = _full_cluster_factorizations(
            topo.n, wf.task(t).model.n_layers)
        scored = []
        for par_t in cands:
            par = {u: par_t if u == t else (topo.n, 1, 1)
                   for u in range(wf.n_tasks)}
            plan = enum_mod.build_plan(fake, wf, grouping, sizes, order,
                                       parallel=par)
            evals += 1
            scored.append((cm_fake.task_cost(plan, t).total, par_t))
        scored.sort(key=lambda x: x[0])
        ranked[t] = [p for _, p in scored]

    # combine per-task bests; walk down ranks until memory-feasible
    best_plan, best_cost = None, math.inf
    depth = [0] * wf.n_tasks
    for trial in range(budget):
        par = {t: ranked[t][min(depth[t], len(ranked[t]) - 1)]
               for t in range(wf.n_tasks)}
        plan = enum_mod.build_plan(topo, wf, grouping, sizes, order,
                                   parallel=par)
        evals += 1
        ok, msg = check_constraints(topo, wf, plan)
        if ok:
            c = cm_real.cost(plan)
            if c < best_cost:
                best_cost, best_plan = c, plan
            break
        # bump the rank of the task contributing most memory (more pp/tp)
        t_heavy = max(
            range(wf.n_tasks),
            key=lambda t: wf.task(t).model.total_weight_count
            * (16 if wf.task(t).kind == TaskKind.TRAIN else 2)
            / max(par[t][1] * par[t][2], 1))
        if depth[t_heavy] + 1 >= len(ranked[t_heavy]):
            break
        depth[t_heavy] += 1
    if best_plan is None:
        # fall back to the most memory-sharded factorization per task
        par = {}
        for t in range(wf.n_tasks):
            cands = _full_cluster_factorizations(
                topo.n, wf.task(t).model.n_layers)
            par[t] = max(cands, key=lambda p: (p[1] * p[2], p[2]))
        plan = enum_mod.build_plan(topo, wf, grouping, sizes, order,
                                   parallel=par)
        evals += 1
        if check_constraints(topo, wf, plan)[0]:
            best_plan, best_cost = plan, cm_real.cost(plan)
    return SearchResult(best_plan, best_cost, evals, grouping, tuple(sizes))


# ---------------------------------------------------------------------------
# StreamRL-like
# ---------------------------------------------------------------------------

def streamrl_scheduler(topo: Topology, wf: RLWorkflow, budget: int = 256,
                       eta: Optional[float] = None) -> SearchResult:
    """Two groups (gen | rest), each a homogeneous same-region island."""
    cm = CostModel(topo, wf, eta=eta)
    gen_tasks = tuple(t for t in range(wf.n_tasks)
                      if wf.task(t).kind == TaskKind.GEN)
    rest = tuple(t for t in range(wf.n_tasks) if t not in gen_tasks)
    grouping = (gen_tasks, rest)
    # homogeneous islands: (region, spec) -> device ids
    islands: Dict[Tuple[str, str], List[int]] = {}
    for d in topo.devices:
        islands.setdefault((d.region, d.spec.name), []).append(d.id)
    island_list = sorted(islands.values(), key=len, reverse=True)
    best = SearchResult(None, math.inf, 0, grouping)
    evals = 0
    # pick one subset of islands for gen, the rest for training
    for r in range(1, len(island_list)):
        for combo in itertools.combinations(range(len(island_list)), r):
            if evals >= budget:
                break
            gen_devs = [d for i in combo for d in island_list[i]]
            rest_devs = [d for i in range(len(island_list))
                         if i not in combo for d in island_list[i]]
            if not gen_devs or not rest_devs:
                continue
            order = gen_devs + rest_devs
            sizes = [len(gen_devs), len(rest_devs)]
            # rank each task's parallelizations within its group, then walk
            # down ranks until the combined plan is memory-feasible
            ranked: Dict[int, List[Tuple[int, int, int]]] = {}
            for gi, g in enumerate(grouping):
                n_g = sizes[gi]
                for t in g:
                    cands = _full_cluster_factorizations(
                        n_g, wf.task(t).model.n_layers) or \
                        [enum_mod.default_parallelization(
                            topo, wf, t, order[:n_g] if gi == 0
                            else order[n_g:])]
                    scored = []
                    for p_t in cands:
                        plan = enum_mod.build_plan(
                            topo, wf, grouping, sizes, order,
                            parallel={t: p_t})
                        evals += 1
                        scored.append((cm.task_cost(plan, t).total, p_t))
                    scored.sort(key=lambda x: x[0])
                    ranked[t] = [p for _, p in scored]
            depth = [0] * wf.n_tasks
            for _ in range(96):
                par = {t: ranked[t][min(depth[t], len(ranked[t]) - 1)]
                       for t in range(wf.n_tasks)}
                plan = enum_mod.build_plan(topo, wf, grouping, sizes, order,
                                           parallel=par)
                evals += 1
                ok, _ = check_constraints(topo, wf, plan)
                if ok:
                    c = cm.cost(plan)
                    if c < best.cost:
                        best = SearchResult(plan, c, evals, grouping,
                                            tuple(sizes))
                    break
                t_heavy = max(
                    range(wf.n_tasks),
                    key=lambda t: wf.task(t).model.total_weight_count
                    * (16 if wf.task(t).kind == TaskKind.TRAIN else 2)
                    / max(par[t][1] * par[t][2], 1))
                if depth[t_heavy] + 1 >= len(ranked[t_heavy]):
                    break
                depth[t_heavy] += 1
    best.evals = evals
    return best


# ---------------------------------------------------------------------------
# DEAP-like pure EA
# ---------------------------------------------------------------------------

class PureEA(EvolutionarySearch):
    """Standard EA: no upgrade mutation, no Baldwinian local search."""

    def __init__(self, topo, wf, grouping, sizes, **kw):
        kw.setdefault("mutate_upgrade_p", 0.0)
        kw.setdefault("use_load_balance", False)
        super().__init__(topo, wf, grouping, sizes, **kw)

    def local_search(self, ind: Individual, max_steps: int = 0) -> Individual:
        return ind


def deap_scheduler(topo: Topology, wf: RLWorkflow, budget: int,
                   seed: int = 0, eta: Optional[float] = None) -> SearchResult:
    """Pure EA over a random-but-fixed task grouping and proportional
    sizes (it has no SHA statistics to pick high-level decisions)."""
    rng = np.random.default_rng(seed)
    groupings = enum_mod.task_groupings(wf)
    grouping = groupings[int(rng.integers(len(groupings)))]
    sizes = enum_mod.proportional_sizes(wf, grouping, topo.n)
    ea = PureEA(topo, wf, grouping, sizes, seed=seed, eta=eta)
    plan, cost = ea.run(budget)
    return SearchResult(plan, cost, ea.evals, grouping, tuple(sizes))


# ---------------------------------------------------------------------------
# Pure SHA (random low-level sampling instead of EA)
# ---------------------------------------------------------------------------

def pure_sha_scheduler(topo: Topology, wf: RLWorkflow, budget: int,
                       seed: int = 0,
                       eta: Optional[float] = None) -> SearchResult:
    from repro.core.sha import HybridScheduler

    class RandomSampler(EvolutionarySearch):
        def run(self, b):
            while b > 0:
                ind = self._random_individual()
                cost = EvolutionarySearch.evaluate(self, ind)
                b -= 1
            return self.best_plan, self.best_cost

        def local_search(self, ind, max_steps: int = 0):
            return ind

    sched = HybridScheduler(topo, wf, seed=seed, eta=eta)
    sched._searchers = {}
    orig = sched._searcher

    def patched(tg, gg):
        key = (tg, gg)
        if key not in sched._searchers:
            sched._searchers[key] = RandomSampler(
                topo, wf, tg, list(gg), seed=seed + hash(key) % 65536,
                mutate_upgrade_p=0.0, use_load_balance=False, eta=eta)
        return sched._searchers[key]

    sched._searcher = patched
    return sched.search(budget)
