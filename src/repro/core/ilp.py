"""ILP-based scheduling (§3.5): exact optimization over the same search
space as the hybrid algorithm.

The paper converts the discrete choices into binary decision variables and
hands them to a MILP solver.  No solver ships in this environment, so we
implement the equivalent exact optimizer directly: exhaustive enumeration
with branch-and-bound structure over

    task grouping -> group sizes -> device-class multisets per group ->
    per-group parallelization combos -> tasklet ordering,

with three exactness-preserving reductions (all documented):
  * device-equivalence-class symmetry breaking (devices with identical spec
    + machine are interchangeable under the cost model);
  * per-group decomposition: groups own disjoint devices and the end-to-end
    cost is monotone in per-task costs, so within-group combos can be
    Pareto-pruned on the (memory-feasible) task-cost vector without losing
    the optimum;
  * parallelizations restricted to dp*pp*tp == group size (using fewer
    devices is dominated by the smaller group size, which is enumerated).

Tasklet orderings are exhaustively permuted for groups of <= max_perm
devices; beyond that the contiguous order is used (noted: exactness then
holds w.r.t. the contiguous-order subspace).  On the paper's Figure-6
regime (<= 24 GPUs, few machines) the class reduction keeps this exact and
fast; `max_nodes`/`max_seconds` bound worst cases gracefully.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import enumerate as enum_mod
from repro.core import loadbalance
from repro.core.costmodel import (CostModel, apply_speculative_best_response,
                                  flops_per_layer)
from repro.core.plan import (Plan, check_constraints, model_memory,
                             working_memory)
from repro.core.sha import SearchResult
from repro.core.topology import Topology
from repro.core.workflow import RLWorkflow, TaskKind


def _device_classes(topo: Topology) -> List[List[int]]:
    groups: Dict[Tuple[str, int], List[int]] = {}
    for d in topo.devices:
        groups.setdefault((d.spec.name, d.machine), []).append(d.id)
    return sorted(groups.values())


def _subset_choices(cls_sizes: List[int], want: int):
    def rec(i, remaining):
        if i == len(cls_sizes):
            if remaining == 0:
                yield ()
            return
        lo = max(0, remaining - sum(cls_sizes[i + 1:]))
        hi = min(cls_sizes[i], remaining)
        for k in range(lo, hi + 1):
            for rest in rec(i + 1, remaining - k):
                yield (k,) + rest
    return rec(0, want)


def _group_memory_ok(topo, wf, plan, tasks) -> bool:
    """C3 restricted to one group's tasks (their devices are disjoint
    from other groups')."""
    use: Dict[int, float] = {}
    peak: Dict[int, float] = {}
    for t in tasks:
        dp, pp, tp = plan.parallel[t]
        for i in range(dp):
            for j in range(pp):
                mm = model_memory(wf, plan, t, j)
                wm = working_memory(wf, plan, t, i, j)
                for d in plan.assignment[t][i, j]:
                    d = int(d)
                    use[d] = use.get(d, 0.0) + mm
                    peak[d] = max(peak.get(d, 0.0), wm)
    return all(use[d] + peak[d] <= topo.mem(d) for d in use)


def _pareto(combos: List[Tuple[Tuple, Tuple[float, ...]]],
            cap: int = 12) -> List[Tuple]:
    """Keep Pareto-optimal cost vectors (then trim to `cap` by sum)."""
    combos = sorted(combos, key=lambda x: sum(x[1]))
    kept: List[Tuple[Tuple, Tuple[float, ...]]] = []
    for par, vec in combos:
        if any(all(kv <= v for kv, v in zip(kvec, vec)) for _, kvec in kept):
            continue
        kept.append((par, vec))
        if len(kept) >= cap:
            break
    return [p for p, _ in kept]


def ilp_scheduler(topo: Topology, wf: RLWorkflow, *,
                  max_seconds: float = 180.0,
                  max_nodes: int = 500_000,
                  max_perm_devices: int = 4,
                  eta: Optional[float] = None) -> SearchResult:
    t0 = time.monotonic()
    cm = CostModel(topo, wf, eta=eta)
    classes = _device_classes(topo)
    cls_sizes = [len(c) for c in classes]
    best = SearchResult(None, math.inf, 0)
    nodes = 0

    def out_of_budget():
        return time.monotonic() - t0 > max_seconds or nodes > max_nodes

    for tg in enum_mod.task_groupings(wf):
        if out_of_budget():
            break
        G = len(tg)

        def size_combos():
            def rec(i, remaining):
                if i == G - 1:
                    if remaining >= 1:
                        yield (remaining,)
                    return
                for s in range(1, remaining - (G - 1 - i) + 1):
                    for rest in rec(i + 1, remaining - s):
                        yield (s,) + rest
            return rec(0, topo.n)

        for sizes in size_combos():
            if out_of_budget():
                break

            def assign_rec(gi, avail, chosen):
                nonlocal nodes, best
                if out_of_budget():
                    return
                if gi == G:
                    nodes += 1
                    _solve_leaf(topo, wf, cm, tg, sizes, chosen, classes)
                    return
                for combo in _subset_choices(avail, sizes[gi]):
                    nodes += 1
                    if out_of_budget():
                        return
                    assign_rec(gi + 1,
                               [a - k for a, k in zip(avail, combo)],
                               chosen + [combo])

            def _solve_leaf(topo, wf, cm, tg, sizes, chosen, classes):
                nonlocal best, nodes
                taken = [0] * len(classes)
                order: List[int] = []
                group_devs: List[List[int]] = []
                for combo in chosen:
                    devs = []
                    for ci, k in enumerate(combo):
                        devs.extend(classes[ci][taken[ci]:taken[ci] + k])
                        taken[ci] += k
                    group_devs.append(devs)
                    order.extend(devs)

                # per-group Pareto sets of parallelization combos
                pareto_sets: List[List[Dict]] = []
                for gi, g in enumerate(tg):
                    n_g = sizes[gi]
                    per_task = []
                    for t in g:
                        # factorizations of every m <= n_g (idle devices in
                        # a group are a legitimate Level-4 outcome: e.g.
                        # generation on the fast subset only)
                        opts = []
                        for m in range(1, n_g + 1):
                            opts.extend(enum_mod.full_group_factorizations(
                                m, wf.task(t).model.n_layers))
                        if not opts:
                            return
                        # rank by this task's own cost, truncate (doc'd cap)
                        scored = []
                        for o in opts:
                            plan = enum_mod.build_plan(
                                topo, wf, tg, sizes, order, parallel={t: o})
                            plan = loadbalance.balance(topo, wf, plan)
                            scored.append(
                                (cm.task_cost(plan, t).total, o))
                        scored.sort(key=lambda x: x[0])
                        cap = 12 if len(g) <= 2 else 7
                        per_task.append([(t, o) for _, o in scored[:cap]])
                    combos_scored = []
                    for combo in itertools.product(*per_task):
                        nodes += 1
                        if nodes > max_nodes:
                            return
                        par = dict(combo)
                        plan = enum_mod.build_plan(
                            topo, wf, tg, sizes, order, parallel=par)
                        plan = loadbalance.balance(topo, wf, plan)
                        if not _group_memory_ok(topo, wf, plan, g):
                            continue
                        vec = tuple(cm.task_cost(plan, t).total for t in g)
                        combos_scored.append((tuple(sorted(par.items())),
                                              vec))
                    if not combos_scored:
                        return
                    pareto_sets.append(_pareto(combos_scored))

                # combine Pareto sets across groups; evaluate full plans
                for sel in itertools.product(*pareto_sets):
                    nodes += 1
                    if nodes > max_nodes:
                        return
                    par = {t: p for items in sel for t, p in items}
                    plan = enum_mod.build_plan(topo, wf, tg, sizes, order,
                                               parallel=par)
                    plan = loadbalance.balance(topo, wf, plan)
                    ok, _ = check_constraints(topo, wf, plan)
                    if not ok:
                        continue
                    # same deterministic spec refinement the EA's
                    # decode applies — the two searches price the same
                    # plan space
                    plan = apply_speculative_best_response(cm, plan)
                    c = cm.cost(plan)
                    if c < best.cost:
                        best = SearchResult(plan, c, nodes, tg, tuple(sizes))
                        # exact tasklet permutation for tiny groups
                        for gi, g in enumerate(tg):
                            devs = group_devs[gi]
                            if 1 < len(devs) <= max_perm_devices:
                                for perm in itertools.permutations(devs):
                                    p2 = enum_mod.build_plan(
                                        topo, wf, tg, sizes, order,
                                        parallel=dict(par),
                                        tasklet_order={t: list(perm)
                                                       for t in g})
                                    p2 = loadbalance.balance(topo, wf, p2)
                                    if check_constraints(topo, wf, p2)[0]:
                                        p2 = apply_speculative_best_response(
                                            cm, p2)
                                        c2 = cm.cost(p2)
                                        if c2 < best.cost:
                                            best = SearchResult(
                                                p2, c2, nodes, tg,
                                                tuple(sizes))

            assign_rec(0, list(cls_sizes), [])
    best.evals = nodes
    return best
