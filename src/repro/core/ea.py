"""Evolutionary algorithm for low-level plan generation (§3.4, Levels 3–5).

Genome (under a fixed Level-1 task grouping and Level-2 group sizes):
  * ``device_perm`` — permutation of all device ids; split by sizes gives
    the medium-grained group assignment (Level 3).
  * ``par`` — per-task (dp, pp, tp) from the feasible set (Level 4).
  * ``order`` — per-task device ordering within its group (Level 5 tasklet
    mapping; the first dp*pp*tp devices are used, dp-major).

Custom mutation (paper): with some probability, replace a GPU in a
*training* task group with a higher-TFLOPS GPU from a non-training group.

Baldwinian local search (paper): greedy cross-group swaps maximizing a
machine/zone/region locality score; the improved *phenotype* is evaluated,
but improvements are not written back to the genotype.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import enumerate as enum_mod
from repro.core import loadbalance
from repro.core.costmodel import CostModel, apply_speculative_best_response
from repro.core.plan import Plan, check_constraints, \
    feasible_parallelizations
from repro.core.topology import Topology
from repro.core.workflow import RLWorkflow, TaskKind


@dataclasses.dataclass
class Individual:
    device_perm: np.ndarray
    par: Dict[int, Tuple[int, int, int]]
    order: Dict[int, np.ndarray]     # task -> permutation of its group devs
    fitness: float = math.inf        # cost of the locally-improved phenotype


class EvolutionarySearch:
    def __init__(self, topo: Topology, wf: RLWorkflow, grouping,
                 sizes: Sequence[int], *, seed: int = 0,
                 pop_size: int = 12, mutate_upgrade_p: float = 0.3,
                 use_load_balance: bool = True,
                 eta: Optional[float] = None):
        self.topo, self.wf = topo, wf
        self.grouping, self.sizes = grouping, list(sizes)
        self.rng = np.random.default_rng(seed)
        self.pop_size = pop_size
        self.mutate_upgrade_p = mutate_upgrade_p
        self.use_load_balance = use_load_balance
        self.cm = CostModel(topo, wf, eta=eta)
        self.population: List[Individual] = []
        self.best_plan: Optional[Plan] = None
        self.best_cost = math.inf
        self.evals = 0
        self._train_groups = {gi for gi, g in enumerate(grouping)
                              if any(wf.task(t).kind == TaskKind.TRAIN
                                     for t in g)}
        # GEN tasks eligible for the speculative-decode gene (the verify
        # step needs attention; cache.supports_speculative_target)
        self._spec_tasks = [t for t in range(wf.n_tasks)
                            if wf.task(t).kind == TaskKind.GEN
                            and not wf.task(t).model.attention_free]
        self._ranked_cache: Dict[Tuple[int, int], List[Tuple[int, int, int]]] = {}

    # -- genome <-> plan -------------------------------------------------
    def _group_slices(self) -> List[slice]:
        out, off = [], 0
        for s in self.sizes:
            out.append(slice(off, off + s))
            off += s
        return out

    def decode(self, ind: Individual) -> Plan:
        order = {t: ind.order[t] for t in ind.order}
        plan = enum_mod.build_plan(
            self.topo, self.wf, self.grouping, self.sizes,
            ind.device_perm.tolist(), parallel=dict(ind.par),
            tasklet_order={t: o.tolist() for t, o in order.items()})
        # speculative decoding is a best response, not a gene: given the
        # assignment the genome proposes, pick the draft-k the cost
        # model prices cheapest per GEN task (0 = off) — deterministic,
        # so re-searching an unchanged topology cannot "discover" spec
        # late and flip the incumbent (the ILP refines identically)
        return apply_speculative_best_response(self.cm, plan)

    # -- init --------------------------------------------------------------
    def _random_individual(self) -> Individual:
        perm = self.rng.permutation(self.topo.n)
        par: Dict[int, Tuple[int, int, int]] = {}
        order: Dict[int, np.ndarray] = {}
        sl = self._group_slices()
        for gi, g in enumerate(self.grouping):
            devs = perm[sl[gi]]
            for t in g:
                par[t] = enum_mod.default_parallelization(
                    self.topo, self.wf, t, devs.tolist())
                order[t] = devs.copy()
        return Individual(perm, par, order)

    def _seeded_individual(self) -> Individual:
        """Locality-contiguous device order + feasibility-aware rank-walk
        parallelization (memory-heavy tasks pushed to deeper pp*tp)."""
        devs_sorted = sorted(
            range(self.topo.n),
            key=lambda d: (self.topo.devices[d].region,
                           -self.topo.devices[d].spec.fp16_tflops,
                           self.topo.devices[d].machine, d))
        # training groups get the fastest devices first
        order_groups = sorted(
            range(len(self.grouping)),
            key=lambda gi: 0 if gi in self._train_groups else 1)
        perm = np.empty(self.topo.n, dtype=int)
        sl = self._group_slices()
        taken = 0
        chosen: Dict[int, List[int]] = {}
        for gi in order_groups:
            chosen[gi] = devs_sorted[taken:taken + self.sizes[gi]]
            taken += self.sizes[gi]
        for gi in range(len(self.grouping)):
            perm[sl[gi]] = chosen[gi]
        par: Dict[int, Tuple[int, int, int]] = {}
        order: Dict[int, np.ndarray] = {}
        for gi, g in enumerate(self.grouping):
            devs = perm[sl[gi]]
            n_g = len(devs)
            for t in g:
                ranked = self._ranked_pars(t, n_g, devs.tolist())
                par[t] = ranked[0]
                order[t] = devs.copy()
        ind = Individual(perm, par, order)
        # rank-walk toward feasibility
        from repro.core.plan import check_constraints as chk
        for _ in range(24):
            plan = self.decode(ind)
            ok, msg = chk(self.topo, self.wf, plan)
            if ok or not msg.startswith("OOM"):
                break
            t_heavy = max(
                range(self.wf.n_tasks),
                key=lambda t: self.wf.task(t).model.total_weight_count
                * (16 if self.wf.task(t).kind == TaskKind.TRAIN else 2)
                / max(ind.par[t][1] * ind.par[t][2], 1))
            gi = next(i for i, g in enumerate(self.grouping)
                      if t_heavy in g)
            ranked = self._ranked_pars(
                t_heavy, self.sizes[gi],
                ind.device_perm[self._group_slices()[gi]].tolist())
            cur = ranked.index(ind.par[t_heavy]) \
                if ind.par[t_heavy] in ranked else -1
            if cur + 1 >= len(ranked):
                break
            ind.par[t_heavy] = ranked[cur + 1]
        return ind

    def _ranked_pars(self, t: int, n_g: int,
                     devs: List[int]) -> List[Tuple[int, int, int]]:
        cands = enum_mod.full_group_factorizations(
            n_g, self.wf.task(t).model.n_layers)
        if not cands:
            cands = [enum_mod.default_parallelization(
                self.topo, self.wf, t, devs)]
        # rank by the task's actual cost-model estimate on a trial plan
        # (one-time per searcher; far better seeds than the dp-max proxy)
        scored = []
        for p in cands:
            try:
                trial = enum_mod.build_plan(
                    self.topo, self.wf, self.grouping, self.sizes,
                    self._seed_order(), parallel={t: p})
                scored.append((self.cm.task_cost(trial, t).total, p))
            except Exception:
                scored.append((float("inf"), p))
        scored.sort(key=lambda x: x[0])
        return [p for _, p in scored]

    def _seed_order(self) -> List[int]:
        if not hasattr(self, "_seed_order_cache"):
            self._seed_order_cache = sorted(
                range(self.topo.n),
                key=lambda d: (self.topo.devices[d].region,
                               -self.topo.devices[d].spec.fp16_tflops,
                               self.topo.devices[d].machine, d))
        return self._seed_order_cache

    # -- mutation ----------------------------------------------------------
    def mutate(self, ind: Individual) -> Individual:
        perm = ind.device_perm.copy()
        par = dict(ind.par)
        order = {t: o.copy() for t, o in ind.order.items()}
        sl = self._group_slices()
        op = self.rng.random()

        if op < self.mutate_upgrade_p and self._train_groups:
            # paper's custom mutation: pull a higher-TFLOPS GPU into a
            # training group from a non-training group
            gi = int(self.rng.choice(sorted(self._train_groups)))
            others = [i for i in range(len(self.sizes))
                      if i not in self._train_groups]
            if others:
                gj = int(self.rng.choice(others))
                di = sl[gi].start + int(self.rng.integers(self.sizes[gi]))
                tf = lambda d: self.topo.devices[int(perm[d])].spec.fp16_tflops
                cand = [sl[gj].start + k for k in range(self.sizes[gj])
                        if tf(sl[gj].start + k) > tf(di)]
                if cand:
                    dj = int(self.rng.choice(cand))
                    perm[di], perm[dj] = perm[dj], perm[di]
        elif op < 0.55:
            # random cross-group swap
            a, b = self.rng.integers(self.topo.n, size=2)
            perm[a], perm[b] = perm[b], perm[a]
        elif op < 0.8:
            # change a task's parallelization: half the moves walk the
            # cost-model ranking of that task's candidates (geometric
            # bias toward the top — directed search, cached per
            # (task, group size)), half stay uniform for exploration
            t = int(self.rng.integers(self.wf.n_tasks))
            gi = next(i for i, g in enumerate(self.grouping) if t in g)
            n = self.sizes[gi]
            key = (t, n)
            if key not in self._ranked_cache:
                self._ranked_cache[key] = self._ranked_pars(
                    t, n, self._seed_order()[:n])
            ranked = self._ranked_cache[key]
            if ranked and self.rng.random() < 0.5:
                idx = min(int(self.rng.geometric(0.45)) - 1,
                          len(ranked) - 1)
                par[t] = ranked[idx]
            else:
                feas = feasible_parallelizations(
                    n, self.wf.task(t).model.n_layers)
                par[t] = feas[int(self.rng.integers(len(feas)))]
        else:
            # shuffle a task's tasklet order
            t = int(self.rng.integers(self.wf.n_tasks))
            if t in order and len(order[t]) > 1:
                i, j = self.rng.integers(len(order[t]), size=2)
                order[t][i], order[t][j] = order[t][j], order[t][i]

        # re-sync orders with (possibly changed) group membership
        for gi, g in enumerate(self.grouping):
            devs = perm[sl[gi]]
            dev_set = set(devs.tolist())
            for t in g:
                if t not in order or set(order[t].tolist()) != dev_set:
                    order[t] = devs.copy()
        return Individual(perm, par, order)

    # -- incumbent injection (§6 reschedule warm start) ---------------------
    def inject_plan(self, plan: Plan) -> bool:
        """Seed the search with a known-good plan: score the plan itself
        (one evaluation, straight into best) and add its genome — device
        order, parallelizations, tasklet mapping — to the population so
        mutation explores around it.  Returns False when the plan's
        groups don't match this searcher's (grouping, sizes) arm."""
        devs_of = {tuple(sorted(g.tasks)): [int(d) for d in g.devices]
                   for g in plan.groups}
        perm: List[int] = []
        par: Dict[int, Tuple[int, int, int]] = {}
        order: Dict[int, np.ndarray] = {}
        for gi, g in enumerate(self.grouping):
            devs = devs_of.get(tuple(sorted(g)))
            if devs is None or len(devs) != self.sizes[gi]:
                return False
            perm.extend(devs)
            for t in g:
                par[t] = tuple(plan.parallel[t])
                flat = [int(d) for d in plan.assignment[t].reshape(-1)]
                rest = [d for d in devs if d not in set(flat)]
                order[t] = np.array(flat + rest, dtype=int)
        if sorted(perm) != list(range(self.topo.n)):
            return False
        ok, _ = check_constraints(self.topo, self.wf, plan)
        self.evals += 1
        cost = self.cm.cost(plan) if ok else math.inf
        if ok and cost < self.best_cost:
            self.best_cost, self.best_plan = cost, plan
        self.population.append(
            Individual(np.array(perm, dtype=int), par, order, cost))
        return True

    # -- Baldwinian local search (locality) ---------------------------------
    def local_search(self, ind: Individual, max_steps: int = 20) -> Individual:
        """Greedy cross-group swaps maximizing locality gain, vectorized:
        gain(a in ga, b in gb) = S[a,gb] + S[b,ga] - S[a,ga] - S[b,gb]
                                 - 2*loc(a,b),
        where S[x, g] = sum_{d in g} loc(x, d)."""
        perm = ind.device_perm.copy()
        sl = self._group_slices()
        G = len(self.sizes)
        loc = self.topo.locality_matrix()
        if G > 1:
            npos = len(perm)
            group_of_pos = np.concatenate(
                [np.full(self.sizes[g], g) for g in range(G)])
            pos_idx = np.arange(npos)
            for _ in range(max_steps):
                member = np.zeros((self.topo.n, G))
                member[perm, group_of_pos] = 1.0
                S = loc @ member                       # [N_dev, G]
                Sa = S[perm]                           # [N_pos, G]
                ga = group_of_pos                      # [N_pos]
                cross = Sa[:, ga]                      # [pa,pb]=S[a, g(b)]
                self_aff = Sa[pos_idx, ga]             # S[a, g(a)]
                # gain[pa, pb] of swapping devices at positions pa, pb
                gain = (cross + cross.T
                        - self_aff[:, None] - self_aff[None, :]
                        - 2.0 * loc[perm[:, None], perm[None, :]])
                gain[ga[:, None] == ga[None, :]] = -np.inf
                idx = int(np.argmax(gain))
                pa, pb = divmod(idx, npos)
                if gain[pa, pb] <= 1e-12:
                    break
                perm[pa], perm[pb] = perm[pb], perm[pa]
        out = Individual(perm, dict(ind.par),
                         {t: o.copy() for t, o in ind.order.items()},
                         ind.fitness)
        # re-sync tasklet orders to new group membership
        for gi, g in enumerate(self.grouping):
            devs = perm[sl[gi]]
            for t in g:
                out.order[t] = devs.copy()
        return out

    # -- evaluation -------------------------------------------------------
    def evaluate(self, ind: Individual) -> float:
        from repro.core.plan import memory_overflow
        phenotype = self.local_search(ind)
        plan = self.decode(phenotype)
        if self.use_load_balance:
            plan = loadbalance.balance(self.topo, self.wf, plan)
        ok, msg = check_constraints(self.topo, self.wf, plan)
        self.evals += 1
        if not ok and not msg.startswith("OOM"):
            return math.inf
        cost = self.cm.cost(plan)
        if not ok:
            # graded penalty keeps the EA's gradient toward feasibility
            over = memory_overflow(self.topo, self.wf, plan)
            return cost * (1.0 + 10.0 * over) + 1e6 * over
        if cost < self.best_cost:
            self.best_cost = cost
            self.best_plan = plan
        return cost

    # -- main loop ----------------------------------------------------------
    def run(self, budget: int) -> Tuple[Optional[Plan], float]:
        """Spend `budget` cost-model evaluations; resumable."""
        if not self.population and budget > 0:
            seed = self._seeded_individual()
            seed.fitness = self.evaluate(seed)
            self.population.append(seed)
            budget -= 1
        while len(self.population) < self.pop_size and budget > 0:
            ind = self._random_individual()
            ind.fitness = self.evaluate(ind)
            self.population.append(ind)
            budget -= 1
        while budget > 0:
            k = min(3, len(self.population))
            idxs = self.rng.choice(len(self.population), k, replace=False)
            parent = min((self.population[i] for i in idxs),
                         key=lambda x: x.fitness)
            child = self.mutate(parent)
            child.fitness = self.evaluate(child)
            budget -= 1
            worst = max(range(len(self.population)),
                        key=lambda i: self.population[i].fitness)
            if child.fitness < self.population[worst].fitness:
                self.population[worst] = child
        return self.best_plan, self.best_cost
