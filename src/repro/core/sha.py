"""Hybrid scheduling algorithm: nested Successive Halving + EA (Algorithm 1).

Level-1 arms: task groupings; Level-2 arms: GPU group-size vectors.  Each
(tg, gg) pair owns a persistent EvolutionarySearch whose best-found cost is
the arm's loss; halving discards the worse half at each level and doubles
the per-arm budget, exactly as in Algorithm 1.  The budget is counted in
cost-model evaluations (deterministic; a wall-clock budget wrapper is
provided for the paper's Figure-5 style experiments).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

from repro.core import enumerate as enum_mod
from repro.core.ea import EvolutionarySearch
from repro.core.plan import Plan
from repro.core.topology import Topology
from repro.core.workflow import RLWorkflow


@dataclasses.dataclass
class SearchResult:
    plan: Optional[Plan]
    cost: float
    evals: int
    grouping: Optional[tuple] = None
    sizes: Optional[tuple] = None
    trace: List[Tuple[int, float]] = dataclasses.field(default_factory=list)


class HybridScheduler:
    """HetRL (SHA-EA)."""

    def __init__(self, topo: Topology, wf: RLWorkflow, *, seed: int = 0,
                 max_groupings: Optional[int] = None,
                 max_sizes_per_grouping: int = 8,
                 use_load_balance: bool = True,
                 eta: Optional[float] = None):
        self.topo, self.wf = topo, wf
        self.seed = seed
        self.use_load_balance = use_load_balance
        self.eta = eta
        groupings = enum_mod.task_groupings(wf)
        priority = enum_mod.priority_groupings(wf)
        if max_groupings is not None and len(groupings) > max_groupings:
            rest = [g for g in sorted(groupings, key=lambda g: (len(g), g))
                    if g not in priority]
            n_rest = max(max_groupings - len(priority), 0)
            step = len(rest) / max(n_rest, 1)
            sampled = [rest[int(i * step)] for i in range(n_rest)]
            groupings = priority + sampled
        else:
            groupings = priority + [g for g in groupings
                                    if g not in priority]
        self.groupings = groupings
        self.max_sizes = max_sizes_per_grouping
        self._searchers: Dict[tuple, EvolutionarySearch] = {}
        self._seeded_sizes: Dict[tuple, tuple] = {}

    def _sizes_for(self, tg) -> List[tuple]:
        sizes = [tuple(s) for s in enum_mod.candidate_group_sizes(
            self.wf, tg, self.topo.n, self.max_sizes, seed=self.seed)]
        seeded = self._seeded_sizes.get(tg)
        if seeded is not None:
            sizes = [seeded] + [s for s in sizes if s != seeded]
        return sizes

    def seed_incumbent(self, plan: Plan) -> None:
        """Warm-start the search from an incumbent plan (§6 reschedule):
        its task grouping becomes the first Level-1 arm, its exact group
        sizes the first Level-2 arm of that grouping, and its device
        order / parallelizations / tasklet mapping are injected into the
        corresponding EA population — so a short budget re-evaluates the
        incumbent itself before exploring, and an unchanged topology
        reliably rediscovers it.  Incumbents that no longer fit the
        topology (dropped devices, size mismatch) seed only what still
        applies."""
        grouping = tuple(sorted(tuple(sorted(g.tasks)) for g in plan.groups))
        self.groupings = [grouping] + [g for g in self.groupings
                                       if g != grouping]
        size_of = {tuple(sorted(g.tasks)): len(g.devices)
                   for g in plan.groups}
        sizes = tuple(size_of[b] for b in grouping)
        if sum(sizes) != self.topo.n or \
                any(int(d) >= self.topo.n for g in plan.groups
                    for d in g.devices):
            return
        self._seeded_sizes[grouping] = sizes
        self._searcher(grouping, sizes).inject_plan(plan)

    def _searcher(self, tg, gg) -> EvolutionarySearch:
        key = (tg, gg)
        if key not in self._searchers:
            self._searchers[key] = EvolutionarySearch(
                self.topo, self.wf, tg, list(gg),
                seed=self.seed + hash(key) % 65536,
                use_load_balance=self.use_load_balance, eta=self.eta)
        return self._searchers[key]

    def search(self, budget: int) -> SearchResult:
        """Nested SHA per Algorithm 1. `budget` = cost-model evaluations."""
        TG = list(self.groupings)
        GG: Dict[tuple, List[tuple]] = {tg: self._sizes_for(tg) for tg in TG}
        best = SearchResult(None, math.inf, 0)
        rounds_l1 = max(math.ceil(math.log2(max(len(TG), 2))), 1)
        TG_m = TG
        spent = 0
        for m in range(rounds_l1):
            if not TG_m or spent >= budget:
                break
            b_m = max(budget // (len(TG_m) * rounds_l1), 1)
            tg_costs: Dict[tuple, float] = {}
            for tg in TG_m:
                gg_list = GG[tg]
                rounds_l2 = max(math.ceil(math.log2(max(len(gg_list), 2))), 1)
                gg_n = list(gg_list)
                for n in range(rounds_l2):
                    if not gg_n:
                        break
                    b_mn = max(b_m // (len(gg_n) * rounds_l2), 1)
                    for gg in gg_n:
                        se = self._searcher(tg, gg)
                        plan, cost = se.run(b_mn)
                        spent += b_mn
                        if cost < best.cost:
                            best = SearchResult(plan, cost, spent, tg, gg,
                                                list(best.trace))
                        best.trace.append((spent, best.cost))
                    gg_n = sorted(
                        gg_n, key=lambda g: self._searcher(tg, g).best_cost
                    )[:max(len(gg_n) // 2, 1)]
                GG[tg] = gg_n
                tg_costs[tg] = min(
                    (self._searcher(tg, g).best_cost for g in gg_list),
                    default=math.inf)
            TG_m = sorted(TG_m, key=lambda tg: tg_costs.get(tg, math.inf)) \
                [:max(len(TG_m) // 2, 1)]
        best.evals = spent
        return best

    def search_timed(self, seconds: float,
                     chunk: int = 64) -> SearchResult:
        """Wall-clock budgeted variant (Figure 5).

        Resumes with *incremental* budget: each round grants a doubled
        chunk of fresh evaluations to the persistent per-arm searchers
        (EvolutionarySearch state carries over), so evaluations are
        counted once instead of re-running the whole search from
        scratch at every doubling."""
        t0 = time.monotonic()
        best = SearchResult(None, math.inf, 0)
        trace: List[Tuple[int, float]] = []
        spent = 0
        increment = chunk
        while time.monotonic() - t0 < seconds:
            r = self.search(increment)
            trace.extend((spent + e, min(c, best.cost)) for e, c in r.trace)
            spent += r.evals
            if r.cost < best.cost:
                best = SearchResult(r.plan, r.cost, spent,
                                    r.grouping, r.sizes)
            best.evals = spent
            increment *= 2
        best.trace = trace
        return best
