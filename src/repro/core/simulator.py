"""Discrete-event execution simulator.

Executes an RL workflow plan on the device-topology graph: tasks become
ready when their dependencies complete; a task occupies its GPU group's
devices for its cost-model duration; colocated tasks serialize on their
shared devices; asynchronous workflows overlap next-iteration generation
with current-iteration training (one-step off-policy, §2.1) after weight
sync.

Used (a) to cross-check the closed-form Appendix-B composition against an
event-driven timeline (Fig 7 analogue) and (b) by benchmarks to report
steady-state throughput of multi-iteration schedules.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro.core.costmodel import CostModel
from repro.core.plan import Plan
from repro.core.topology import Topology
from repro.core.workflow import RLWorkflow, TaskKind


@dataclasses.dataclass
class Event:
    time: float
    kind: str           # "start" | "end"
    iteration: int
    task: int
    # decode-wave annotations (engine-measured GEN sub-events only): the
    # wave round index and its mean active-slot occupancy.  None for
    # task-level events — the simulator never fills these.
    wave: Optional[int] = None
    occupancy: Optional[float] = None
    # plan epoch (§6 online redeployment): which execution plan was live
    # when the event happened.  The simulator always predicts a single
    # plan, so simulated events stay at epoch 0; the engine bumps the
    # epoch at every ``apply_plan`` swap so steady-state estimates never
    # straddle a plan transition.
    epoch: int = 0
    # host wall-clock (seconds since engine construction) at which the
    # measured execution behind this event really started/ended, and the
    # id of the obs.trace span that recorded it.  Replay times (`time`)
    # follow the simulator's scheduling rules on the plan's devices;
    # `t_wall` is what the host actually observed — the pair is what
    # obs.calibrate fits scale factors from.  None on simulated events.
    t_wall: Optional[float] = None
    span: Optional[int] = None
    # device-folding collision flag (engine-measured events only): True
    # when the task's plan group shared a real device with another group
    # after folding — its replayed concurrency with other lanes is
    # nominal, the host actually serialized them.  None on simulated
    # events and on collision-free engine runs.
    collision: Optional[bool] = None


@dataclasses.dataclass
class SimResult:
    iteration_time: float     # steady-state seconds per iteration
    makespan: float
    throughput: float         # samples / s
    timeline: List[Event]


def simulate(topo: Topology, wf: RLWorkflow, plan: Plan,
             n_iterations: int = 4,
             cost_model: Optional[CostModel] = None) -> SimResult:
    cm = cost_model or CostModel(topo, wf)
    durations = {t: cm.task_cost(plan, t).total for t in range(wf.n_tasks)}
    actor_train = 4 if wf.algorithm == "ppo" else 3
    reshard = cm.c_reshard(plan, actor_train) if wf.synchronous \
        else cm.c_sync(plan, actor_train, 0)

    # device availability: devices of each group free at time x
    dev_free: Dict[int, float] = {d: 0.0 for d in range(topo.n)}
    done_at: Dict[Tuple[int, int], float] = {}   # (iter, task) -> end time
    timeline: List[Event] = []

    def devices_of(t: int) -> Tuple[int, ...]:
        return tuple(int(d) for d in plan.assignment[t].reshape(-1))

    def run_task(it: int, t: int, ready: float) -> float:
        devs = devices_of(t)
        start = max([ready] + [dev_free[d] for d in devs])
        end = start + durations[t]
        for d in devs:
            dev_free[d] = end
        timeline.append(Event(start, "start", it, t))
        timeline.append(Event(end, "end", it, t))
        done_at[(it, t)] = end
        return end

    sync_done = 0.0  # when generation weights for next iter are available
    for it in range(n_iterations):
        for t in range(wf.n_tasks):
            task = wf.task(t)
            dep_ready = max(
                [done_at.get((it, d), 0.0) for d in task.depends_on],
                default=0.0)
            if task.kind == TaskKind.GEN:
                # generation needs the *previous* iteration's synced weights
                dep_ready = max(dep_ready, sync_done)
            run_task(it, t, dep_ready)
        train_end = done_at[(it, actor_train)]
        if wf.synchronous:
            # reshard blocks everything (iteration barrier)
            sync_done = train_end + reshard
            for d in dev_free:
                dev_free[d] = max(dev_free[d], sync_done)
        else:
            # async: only the generation group waits for the weight sync
            sync_done = train_end + reshard
            for d in devices_of(0):
                dev_free[d] = max(dev_free[d], sync_done)

    makespan = max(e.time for e in timeline)
    if n_iterations >= 3:
        # steady state: time between the last two generation starts
        gen_starts = sorted(e.time for e in timeline
                            if e.task == 0 and e.kind == "start")
        iter_time = gen_starts[-1] - gen_starts[-2]
    else:
        iter_time = makespan / n_iterations
    iter_time = max(iter_time, 1e-9)
    return SimResult(iter_time, makespan,
                     wf.samples_per_iter / iter_time,
                     sorted(timeline, key=lambda e: e.time))
