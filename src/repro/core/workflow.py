"""RL workflow computational graphs G (§2.1, §3.1).

PPO: 6 tasks over 4 models — actor generation; reward / reference / critic
inference (parallel); actor / critic training (parallel).
GRPO: 4 tasks over 3 models — no critic.

Each task carries the LLM spec it runs, its kind (GEN/INF/TRAIN) and its
dependencies; the cost model consumes these to produce per-task costs, and
the end-to-end composition (Appendix B.4) aggregates them.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence, Tuple

from repro.models.config import ModelConfig


class TaskKind(str, enum.Enum):
    GEN = "gen"
    INF = "inf"
    TRAIN = "train"


@dataclasses.dataclass(frozen=True)
class LLMSpec:
    """Coarse model description used by the scheduler's cost model."""

    name: str
    n_layers: int
    h1: int                 # hidden size
    h2: int                 # intermediate (ffn) size
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    n_experts: int = 0      # 0 = dense
    top_k: int = 0
    attention_free: bool = False

    @property
    def layer_weight_count(self) -> float:
        """Weights per layer. Dense: the paper's 4*h1^2 + 3*h1*h2."""
        attn = 0.0 if self.attention_free else 4.0 * self.h1 * self.h1
        ffn = 3.0 * self.h1 * self.h2
        if self.attention_free:
            # rwkv-style: 5 square projections + channel mix
            attn = 5.0 * self.h1 * self.h1
            ffn = 2.0 * self.h1 * self.h2 + self.h1 * self.h1
        if self.n_experts:
            ffn *= self.n_experts
        return attn + ffn

    @property
    def layer_active_count(self) -> float:
        """Weights touched per token (MoE: top_k experts only)."""
        if not self.n_experts:
            return self.layer_weight_count
        dense = self.layer_weight_count
        ffn_all = 3.0 * self.h1 * self.h2 * self.n_experts
        ffn_act = 3.0 * self.h1 * self.h2 * self.top_k
        return dense - ffn_all + ffn_act

    @property
    def total_weight_count(self) -> float:
        return self.layer_weight_count * self.n_layers + \
            2.0 * self.vocab * self.h1

    @classmethod
    def from_model_config(cls, cfg: ModelConfig) -> "LLMSpec":
        return cls(
            name=cfg.name, n_layers=cfg.n_layers, h1=cfg.d_model,
            h2=cfg.d_ff, vocab=cfg.vocab_size, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
            n_experts=cfg.n_experts, top_k=cfg.top_k,
            attention_free=cfg.attention_free)


# The paper's evaluated models (Qwen series, §5.1)
QWEN_4B = LLMSpec("qwen-4b", 36, 2560, 9728, 151936, 32, 8, 128)
QWEN_8B = LLMSpec("qwen-8b", 36, 4096, 12288, 151936, 32, 8, 128)
QWEN_14B = LLMSpec("qwen-14b", 40, 5120, 17408, 151936, 40, 8, 128)
QWEN_1_7B = LLMSpec("qwen3-1.7b", 28, 2048, 6144, 151936, 16, 8, 128)

QWEN = {"4b": QWEN_4B, "8b": QWEN_8B, "14b": QWEN_14B, "1.7b": QWEN_1_7B}


@dataclasses.dataclass(frozen=True)
class Task:
    id: int
    name: str
    kind: TaskKind
    model: LLMSpec
    depends_on: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class RLWorkflow:
    algorithm: str              # "ppo" | "grpo"
    synchronous: bool
    tasks: Tuple[Task, ...]
    seq_in: int = 1024
    seq_out: int = 1024
    global_batch: int = 384
    n_rollouts: int = 8         # responses per prompt
    micro_batch: int = 4
    eta: float = 1.0            # task-parallelism coefficient (Φ)

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    @property
    def samples_per_iter(self) -> int:
        return self.global_batch * self.n_rollouts

    def task(self, tid: int) -> Task:
        return self.tasks[tid]

    def stages(self) -> List[List[int]]:
        """Topological stages: groups of tasks with no mutual deps."""
        done: set = set()
        out = []
        remaining = list(range(self.n_tasks))
        while remaining:
            stage = [t for t in remaining
                     if all(d in done for d in self.tasks[t].depends_on)]
            assert stage, "dependency cycle"
            out.append(stage)
            done.update(stage)
            remaining = [t for t in remaining if t not in stage]
        return out


def make_ppo(model: LLMSpec, *, synchronous=True, seq_in=1024, seq_out=1024,
             global_batch=384, n_rollouts=8, micro_batch=4,
             critic: Optional[LLMSpec] = None,
             reward: Optional[LLMSpec] = None) -> RLWorkflow:
    critic = critic or model
    reward = reward or model
    tasks = (
        Task(0, "actor_generation", TaskKind.GEN, model),
        Task(1, "reward_inference", TaskKind.INF, reward, (0,)),
        Task(2, "reference_inference", TaskKind.INF, model, (0,)),
        Task(3, "critic_inference", TaskKind.INF, critic, (0,)),
        Task(4, "actor_training", TaskKind.TRAIN, model, (1, 2, 3)),
        Task(5, "critic_training", TaskKind.TRAIN, critic, (1, 2, 3)),
    )
    return RLWorkflow("ppo", synchronous, tasks, seq_in, seq_out,
                      global_batch, n_rollouts, micro_batch)


def make_grpo(model: LLMSpec, *, synchronous=True, seq_in=1024, seq_out=1024,
              global_batch=384, n_rollouts=8, micro_batch=4,
              reward: Optional[LLMSpec] = None) -> RLWorkflow:
    reward = reward or model
    tasks = (
        Task(0, "actor_generation", TaskKind.GEN, model),
        Task(1, "reward_inference", TaskKind.INF, reward, (0,)),
        Task(2, "reference_inference", TaskKind.INF, model, (0,)),
        Task(3, "actor_training", TaskKind.TRAIN, model, (1, 2)),
    )
    return RLWorkflow("grpo", synchronous, tasks, seq_in, seq_out,
                      global_batch, n_rollouts, micro_batch)


def make_workflow(algorithm: str, model: LLMSpec, **kw) -> RLWorkflow:
    if algorithm == "ppo":
        return make_ppo(model, **kw)
    if algorithm == "grpo":
        return make_grpo(model, **kw)
    raise ValueError(algorithm)
