"""Bounded retry with exponential backoff.

Shared by the engine's per-task execution path (transient task crashes
injected by ``repro.faults`` or raised by real runtime failures) and by
elastic checkpointing (flaky filesystem writes).  Policy semantics:

- ``TransientError`` (or any type listed in ``retry_on``) is retried up
  to ``max_attempts`` total attempts with exponential backoff;
- ``PermanentError`` is never retried — it propagates immediately so the
  caller can escalate (drop the device, force a replan);
- exhausting the budget raises ``RetryExhausted`` chaining the last
  transient cause.

Backoff sleeps are injectable (``sleep=``) so tests and the simulator-
clocked engine never block wall time.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional, Tuple, Type


class TransientError(Exception):
    """A failure that is expected to succeed on retry."""


class PermanentError(Exception):
    """A failure retrying cannot fix; escalate instead."""


class RetryExhausted(Exception):
    """All ``max_attempts`` attempts failed with transient errors."""

    def __init__(self, attempts: int, last: BaseException):
        super().__init__(
            f"retry exhausted after {attempts} attempts: {last!r}")
        self.attempts = attempts
        self.last = last


@dataclass(frozen=True)
class RetryPolicy:
    max_attempts: int = 3
    base_delay_s: float = 0.05
    factor: float = 2.0
    max_delay_s: float = 2.0

    def delay(self, attempt: int) -> float:
        """Backoff before attempt ``attempt+1`` (attempts are 0-based)."""
        return min(self.base_delay_s * (self.factor ** attempt),
                   self.max_delay_s)


def retry_call(fn: Callable[[int], object], *,
               policy: RetryPolicy = RetryPolicy(),
               retry_on: Tuple[Type[BaseException], ...] = (TransientError,),
               on_retry: Optional[Callable[[int, BaseException], None]] = None,
               sleep: Callable[[float], None] = time.sleep):
    """Call ``fn(attempt)`` until it succeeds or the policy is exhausted.

    ``fn`` receives the 0-based attempt index (so injectors and loggers
    can key behaviour on it).  ``on_retry(attempt, exc)`` fires after
    each transient failure, before the backoff sleep.
    """
    if policy.max_attempts < 1:
        raise ValueError("max_attempts must be >= 1")
    last: Optional[BaseException] = None
    for attempt in range(policy.max_attempts):
        try:
            return fn(attempt)
        except PermanentError:
            raise
        except retry_on as e:  # noqa: B030 - tuple of types
            last = e
            if on_retry is not None:
                on_retry(attempt, e)
            if attempt + 1 < policy.max_attempts:
                sleep(policy.delay(attempt))
    assert last is not None
    raise RetryExhausted(policy.max_attempts, last) from last
