"""Online redeployment (§6): medium-granularity adaptation to network /
fleet changes by re-scheduling at checkpoint boundaries.

The paper: "HetRL can accommodate medium-granularity network variability
by performing scheduling before the end of the current iteration and
during model checkpointing ... the updated plan is applied immediately
after checkpointing."  `reschedule` warm-starts the hybrid scheduler from
the incumbent plan's Level-1/2 decisions so a short budget suffices, and
reports whether switching is worthwhile (new cost + amortized transition
cost vs staying).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.core.costmodel import CostModel
from repro.core.plan import BYTES_BF16, Plan, check_constraints
from repro.core.sha import HybridScheduler, SearchResult
from repro.core.topology import Topology
from repro.core.workflow import RLWorkflow, TaskKind


@dataclasses.dataclass
class RedeployDecision:
    switch: bool
    plan: Plan
    old_cost: float               # incumbent plan on the NEW topology
    new_cost: float
    transition_cost_s: float      # weight movement at the checkpoint
    amortization_iters: int


def _transition_cost(topo: Topology, wf: RLWorkflow, old: Plan,
                     new: Plan) -> float:
    """Weights that must move to devices not previously holding them:
    approximated as full bf16 weights of every task whose device set
    changed, over the bottleneck link between old and new sets."""
    total = 0.0
    for t in range(wf.n_tasks):
        devs_old = {int(d) for d in old.assignment[t].reshape(-1)} \
            if t in old.assignment else set()
        devs_new = {int(d) for d in new.assignment[t].reshape(-1)}
        moved = devs_new - devs_old
        if not moved or not devs_old:
            continue
        nbytes = BYTES_BF16 * wf.task(t).model.total_weight_count \
            * len(moved) / max(len(devs_new), 1)
        best_bw = max(topo.beta(a, b)
                      for a in devs_old for b in moved)
        total += nbytes / (best_bw * 1e9)
    return total


def reschedule(topo_new: Topology, wf: RLWorkflow, incumbent: Plan, *,
               budget: int = 150, amortization_iters: int = 20,
               seed: int = 0) -> RedeployDecision:
    cm = CostModel(topo_new, wf)
    ok, _ = check_constraints(topo_new, wf, incumbent)
    old_cost = cm.cost(incumbent) if ok else math.inf

    sched = HybridScheduler(topo_new, wf, seed=seed, max_groupings=8,
                            max_sizes_per_grouping=4)
    # warm start: put the incumbent's grouping first among the arms
    inc_grouping = tuple(sorted(tuple(sorted(g.tasks))
                                for g in incumbent.groups))
    if inc_grouping in sched.groupings:
        sched.groupings = [inc_grouping] + \
            [g for g in sched.groupings if g != inc_grouping]
    result = sched.search(budget=budget)
    if result.plan is None:
        return RedeployDecision(False, incumbent, old_cost, math.inf, 0.0,
                                amortization_iters)

    trans = _transition_cost(topo_new, wf, incumbent, result.plan)
    gain_per_iter = old_cost - result.cost
    switch = gain_per_iter * amortization_iters > trans and \
        result.cost < old_cost
    return RedeployDecision(switch, result.plan if switch else incumbent,
                            old_cost, result.cost, trans,
                            amortization_iters)
