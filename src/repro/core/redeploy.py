"""Online redeployment (§6): medium-granularity adaptation to network /
fleet changes by re-scheduling at checkpoint boundaries.

The paper: "HetRL can accommodate medium-granularity network variability
by performing scheduling before the end of the current iteration and
during model checkpointing ... the updated plan is applied immediately
after checkpointing."  `reschedule` warm-starts the hybrid scheduler from
the incumbent plan's Level-1/2 decisions so a short budget suffices, and
reports whether switching is worthwhile (new cost + amortized transition
cost vs staying).

When does ``switch`` fire?
--------------------------
``reschedule`` prices the incumbent on the *new* topology (infinite when
the incumbent no longer fits — devices gone, memory constraints broken)
and searches for a challenger under a short warm-started budget.  The
decision is an amortization inequality::

    switch  <=>  (old_cost - new_cost) * amortization_iters > transition
                 and new_cost < old_cost

i.e. the per-iteration gain, accumulated over the horizon the new plan is
expected to live (`amortization_iters`, the paper's checkpoint interval),
must pay for the one-off weight migration.  Because the warm start seeds
the incumbent's grouping, group sizes, parallelizations and device order
into the scheduler's arms, the challenger is never worse than the
incumbent on the new topology — so an undisturbed topology re-evaluates
the incumbent at equal cost and keeps it (``switch=False`` with zero
transition).  The engine applies a ``switch=True`` decision at the next
iteration boundary through ``engine.Engine.apply_plan``; the elasticity
loop around both lives in ``engine.elastic``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.core.costmodel import CostModel
from repro.core.plan import BYTES_BF16, Plan, check_constraints
from repro.core.sha import HybridScheduler, SearchResult
from repro.core.topology import Topology
from repro.core.workflow import RLWorkflow, TaskKind


@dataclasses.dataclass
class RedeployDecision:
    switch: bool
    plan: Plan
    old_cost: float               # incumbent plan on the NEW topology
    new_cost: float
    transition_cost_s: float      # weight movement at the checkpoint
    amortization_iters: int


def _surviving_ids(topo_new: Topology,
                   topo_old: Optional[Topology]) -> Optional[set]:
    """Device ids whose identity is unchanged between the two topologies.

    ``drop_devices`` densely re-indexes survivors, so an id below the new
    ``n`` may alias a *different* physical device after a non-suffix
    drop.  With the old topology in hand we can tell: an id survives only
    when both topologies agree on its (spec, machine, zone, region).
    Returns None when no old topology is available (ids taken at face
    value, valid for pure link drift)."""
    if topo_old is None:
        return None
    keep = set()
    for d in range(min(topo_new.n, topo_old.n)):
        a, b = topo_old.devices[d], topo_new.devices[d]
        if (a.spec, a.machine, a.zone, a.region) == \
                (b.spec, b.machine, b.zone, b.region):
            keep.add(d)
    return keep


def transition_cost(topo: Topology, wf: RLWorkflow, old: Plan,
                    new: Plan, *,
                    topo_old: Optional[Topology] = None) -> float:
    """Weights that must move to devices not previously holding them:
    approximated as full bf16 weights of every task whose device set
    changed, routed over the bottleneck link of the task's chosen paths.

    Each destination device pulls its shard from the best-connected
    source that still holds the weights (max over sources); the task's
    transfer completes when the slowest such chosen link finishes (min
    over destinations) — the bottleneck between the old and new sets.
    Sources that no longer exist on `topo` (dropped or re-indexed
    devices, detected against `topo_old` when given) cannot serve; a
    task with no surviving source moves for free here, because its
    weights must be restored from the checkpoint that §6 takes at the
    swap boundary anyway."""
    survivors = _surviving_ids(topo, topo_old)
    total = 0.0
    for t in range(wf.n_tasks):
        devs_old = {int(d) for d in old.assignment[t].reshape(-1)} \
            if t in old.assignment else set()
        devs_old = {d for d in devs_old if d < topo.n
                    and (survivors is None or d in survivors)}
        devs_new = {int(d) for d in new.assignment[t].reshape(-1)}
        moved = devs_new - devs_old
        if not moved or not devs_old:
            continue
        nbytes = BYTES_BF16 * wf.task(t).model.total_weight_count \
            * len(moved) / max(len(devs_new), 1)
        bottleneck = min(max(topo.beta(a, b) for a in devs_old)
                         for b in moved)
        total += nbytes / (bottleneck * 1e9)
    return total


# historical name, kept for callers of the private spelling
_transition_cost = transition_cost


def _incumbent_cost(topo_new: Topology, wf: RLWorkflow, cm: CostModel,
                    incumbent: Plan,
                    topo_old: Optional[Topology] = None) -> float:
    """Incumbent plan priced on the new topology; infinite when it no
    longer fits — it references dropped devices, ids whose identity
    changed under re-indexing (detected against `topo_old` when given),
    or violates constraints."""
    used = {int(d) for asg in incumbent.assignment.values()
            for d in asg.reshape(-1)}
    if any(d >= topo_new.n for d in used):
        return math.inf
    survivors = _surviving_ids(topo_new, topo_old)
    if survivors is not None and not used <= survivors:
        return math.inf
    ok, _ = check_constraints(topo_new, wf, incumbent)
    return cm.cost(incumbent) if ok else math.inf


def reschedule(topo_new: Topology, wf: RLWorkflow, incumbent: Plan, *,
               budget: int = 150, amortization_iters: int = 20,
               seed: int = 0,
               topo_old: Optional[Topology] = None) -> RedeployDecision:
    """`topo_old` (the environment the incumbent was planned for, when
    the caller has it — the elastic controller always does) lets the
    decision detect device re-indexing after a fleet shrink rather than
    trusting raw device ids."""
    cm = CostModel(topo_new, wf)
    old_cost = _incumbent_cost(topo_new, wf, cm, incumbent, topo_old)

    sched = HybridScheduler(topo_new, wf, seed=seed, max_groupings=8,
                            max_sizes_per_grouping=4)
    # warm start (Level 1/2/4/5): incumbent grouping first among the
    # arms, its exact group sizes first within that arm, and its device
    # order + parallelizations injected into the matching EA population,
    # so a short budget re-evaluates the incumbent before exploring
    sched.seed_incumbent(incumbent)
    result = sched.search(budget=budget)
    if result.plan is None:
        return RedeployDecision(False, incumbent, old_cost, math.inf, 0.0,
                                amortization_iters)

    trans = transition_cost(topo_new, wf, incumbent, result.plan,
                            topo_old=topo_old)
    gain_per_iter = old_cost - result.cost
    switch = gain_per_iter * amortization_iters > trans and \
        result.cost < old_cost
    return RedeployDecision(switch, result.plan if switch else incumbent,
                            old_cost, result.cost, trans,
                            amortization_iters)
