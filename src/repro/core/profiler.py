"""HetRL profiler (§4.1): collects hardware information.

In a physical deployment this probes GPUs and links; here it (a) reads the
device topology graph (the simulated environment's ground truth) and (b)
calibrates *achievable* compute throughput of the actual local JAX device
by micro-benchmarking matmuls — used by the Figure-7-style cost-model
validation where tiny RL iterations really execute on this host.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.core.topology import Topology


@dataclasses.dataclass
class HardwareInfo:
    tflops: Dict[int, float]
    mem_gb: Dict[int, float]
    hbm_gbps: Dict[int, float]
    latency_s: "np.ndarray"
    bandwidth_gbps: "np.ndarray"


def profile_topology(topo: Topology) -> HardwareInfo:
    return HardwareInfo(
        tflops={d.id: d.spec.fp16_tflops for d in topo.devices},
        mem_gb={d.id: d.spec.mem_gb for d in topo.devices},
        hbm_gbps={d.id: d.spec.hbm_gbps for d in topo.devices},
        latency_s=topo.latency_s.copy(),
        bandwidth_gbps=topo.bandwidth_gbps.copy(),
    )


def calibrate_local_device(size: int = 1024, iters: int = 8,
                           dtype="float32") -> float:
    """Achievable matmul TFLOP/s of the local JAX device."""
    import jax
    import jax.numpy as jnp

    x = jnp.ones((size, size), dtype)
    f = jax.jit(lambda a, b: a @ b)
    f(x, x).block_until_ready()
    t0 = time.perf_counter()
    y = x
    for _ in range(iters):
        y = f(y, x)
    y.block_until_ready()
    dt = time.perf_counter() - t0
    flops = 2 * size ** 3 * iters
    return flops / dt / 1e12


def calibrate_local_hbm(mbytes: int = 64, iters: int = 8,
                        dtype="float32") -> float:
    """Achievable memory bandwidth (GB/s) of the local JAX device.

    Microbenches a jitted elementwise copy-scale over a ``mbytes``-MB
    array: each call streams the buffer in and out once (2x the array
    bytes), which is the traffic pattern the cost model's C_hbm decode
    roofline assumes.  Together with ``calibrate_local_device`` this is
    the measured (TFLOP/s, GB/s) pair ``obs.calibrate`` records per
    profiled host."""
    import jax
    import jax.numpy as jnp

    n = max(mbytes * (1 << 20) // jnp.dtype(dtype).itemsize, 1)
    x = jnp.ones((n,), dtype)
    f = jax.jit(lambda a: a * 1.0000001)
    y = f(x)
    y.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        y = f(y)
    y.block_until_ready()
    dt = max(time.perf_counter() - t0, 1e-9)
    nbytes = 2 * x.nbytes * iters          # one read + one write per call
    return nbytes / dt / 1e9


def profile_local() -> Dict[str, float]:
    """Local-host microbenchmark pair the calibration pass records."""
    return {"tflops": calibrate_local_device(),
            "hbm_gbps": calibrate_local_hbm()}
