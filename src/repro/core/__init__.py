"""HetRL scheduler: the paper's primary contribution in JAX-native form."""
