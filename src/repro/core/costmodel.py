"""Analytical cost model (§3.3 + Appendix B) — faithful implementation.

All costs in seconds, volumes in bytes, bandwidths in GB/s.  The α–β terms
follow Appendix B exactly: TP/DP all-reduce costs minimize over ring graphs
of the participating devices (exact for ≤8 devices, nearest-neighbour +
2-opt beyond); PP costs minimize over inter-stage device pairs.

Deviations (documented in DESIGN.md):
  * ``min over all feasible ring graphs`` is TSP-hard; exact enumeration for
    ≤8 devices, heuristic beyond (the paper is necessarily heuristic too).
  * MoE / attention-free archs (our assigned pool; the paper evaluates dense
    Qwen only) use generalized per-layer weight/FLOP counts from LLMSpec.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.plan import (BYTES_BF16, MAX_DECODE_WAVE, PREFILL_CHUNK,
                             Plan, decode_wave)
from repro.core.topology import Topology
from repro.core.workflow import LLMSpec, RLWorkflow, Task, TaskKind


# ---------------------------------------------------------------------------
# Ring / pair primitives
# ---------------------------------------------------------------------------

def _edge_cost(topo: Topology, a: int, b: int, cv: float) -> float:
    beta = topo.beta(a, b)
    if beta <= 0:
        return 1e9
    return topo.alpha(a, b) + cv / (beta * 1e9)


_EXACT_RING_N = 6


def _ring_order_heuristic(topo: Topology, devices: Sequence[int],
                          cv: float) -> Tuple[int, ...]:
    """Nearest-neighbour construction + bounded 2-opt, cached per set."""
    key = (tuple(sorted(devices)),)
    cache = getattr(topo, "_ring_cache", None)
    if cache is None:
        cache = topo._ring_cache = {}
    if key in cache:
        return cache[key]

    def ring_max(order):
        return max(_edge_cost(topo, order[i], order[(i + 1) % len(order)], cv)
                   for i in range(len(order)))

    remaining = list(devices[1:])
    order = [devices[0]]
    while remaining:
        cur = order[-1]
        nxt = min(remaining, key=lambda d: _edge_cost(topo, cur, d, cv))
        order.append(nxt)
        remaining.remove(nxt)
    best = ring_max(order)
    for _ in range(2):
        improved = False
        for i in range(1, len(order) - 1):
            for j in range(i + 1, len(order)):
                cand = order[:i] + order[i:j + 1][::-1] + order[j + 1:]
                c = ring_max(cand)
                if c < best - 1e-12:
                    best, order = c, cand
                    improved = True
        if not improved:
            break
    cache[key] = tuple(order)
    return cache[key]


def ring_cost(topo: Topology, devices: Sequence[int], cv: float) -> float:
    """min over ring graphs of max over ring edges (alpha + cv/beta).

    Exact for <= _EXACT_RING_N devices; NN + 2-opt heuristic beyond (the
    optimal ring order is cached per device set — it is cv-independent up
    to ties for the bottleneck-ring objective on a fixed edge metric)."""
    n = len(devices)
    if n <= 1:
        return 0.0
    if n == 2:
        return _edge_cost(topo, devices[0], devices[1], cv)

    def ring_max(order):
        return max(_edge_cost(topo, order[i], order[(i + 1) % len(order)], cv)
                   for i in range(len(order)))

    if n <= _EXACT_RING_N:
        first = devices[0]
        best = math.inf
        for perm in itertools.permutations(devices[1:]):
            if perm[0] > perm[-1]:   # skip mirrored rings
                continue
            best = min(best, ring_max((first,) + perm))
        return best

    order = _ring_order_heuristic(topo, devices, cv)
    return ring_max(order)


def pair_min_cost(topo: Topology, devs_a: Sequence[int],
                  devs_b: Sequence[int], cv: float) -> float:
    return min(_edge_cost(topo, a, b, cv)
               for a in devs_a for b in devs_b)


# ---------------------------------------------------------------------------
# Per-layer volumes and FLOPs
# ---------------------------------------------------------------------------

# attention-free recurrent state width per channel (matches the linear-
# time state-update FLOP term below and the decode state read in c_hbm)
_STATE_DIM = 64


def flops_per_layer(task: Task, seq: int) -> float:
    """Per-sample per-layer forward FLOPs (Appendix B 'Computation')."""
    return model_flops_per_layer(task.model, seq)


def model_flops_per_layer(m: LLMSpec, seq: int) -> float:
    if m.attention_free:
        proj = 2 * 5 * seq * m.h1 * m.h1          # r,k,v,g,o projections
        attn = 2 * seq * m.h1 * _STATE_DIM        # linear-time state update
        mlp = 2 * 2 * seq * m.h1 * m.h2 + 2 * seq * m.h1 * m.h1
        return proj + attn + mlp
    qkvo = 2 * 4 * seq * m.h1 * m.h1
    attn = 2 * 2 * seq * seq * m.h1
    mult = m.top_k if m.n_experts else 1
    mlp = 2 * 3 * seq * m.h1 * m.h2 * mult
    return qkvo + attn + mlp


def speculative_expected_tokens(spec_k: int, accept_rate: float) -> float:
    """Expected tokens emitted per draft/verify round: one bonus token
    plus a geometric run of accepted drafts — ``(1 - a^(k+1)) / (1 - a)``
    for per-token accept rate a, saturating at k+1."""
    a = min(max(float(accept_rate), 0.0), 1.0)
    k1 = int(spec_k) + 1
    if a >= 1.0:
        return float(k1)
    return (1.0 - a ** k1) / (1.0 - a)


def default_draft_spec(m: LLMSpec, ratio: int = 4) -> LLMSpec:
    """Conventional draft for target `m`: same family, ``ratio`` x fewer
    layers at half width (~1/16 the weight volume) — the shape of the
    configs/archs tiny<->large pairs the serve path pairs up."""
    return dataclasses.replace(
        m, name=f"{m.name}-draft", n_layers=max(m.n_layers // ratio, 1),
        h1=max(m.h1 // 2, 64), h2=max(m.h2 // 2, 64),
        n_heads=max(m.n_heads // 2, 1) if m.n_heads else 0,
        n_kv_heads=max(m.n_kv_heads // 2, 1) if m.n_kv_heads else 0)


SPEC_K_CHOICES = (0, 2, 4, 8)


def apply_speculative_best_response(cm: "CostModel", plan,
                                    ks: Sequence[int] = SPEC_K_CHOICES):
    """Pick the cheapest draft-k per GEN task and write it into
    ``plan.gen_spec`` (0 = plain wave decode, left absent).

    Speculative decoding is a *best response*, not a search dimension:
    given any assignment, the cost model prices each k deterministically,
    so every scheduler (SHA-EA decode, the ILP's leaf evaluation, a
    hand-built plan) refines plans the same way — searches over the same
    topology cannot disagree about spec and flip an incumbent."""
    for t in range(cm.wf.n_tasks):
        task = cm.wf.task(t)
        if task.kind != TaskKind.GEN or task.model.attention_free:
            continue
        k = min(ks, key=lambda k: cm.gen_speculative_wave(plan, t, 0, 0,
                                                          spec_k=k))
        if k > 0:
            plan.gen_spec[t] = k
    return plan


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TaskCost:
    total: float
    comp: float = 0.0
    tp: float = 0.0
    pp: float = 0.0
    dp: float = 0.0
    hbm: float = 0.0
    bubble: float = 0.0


class CostModel:
    """Estimates per-task and end-to-end iteration time for a plan."""

    def __init__(self, topo: Topology, wf: RLWorkflow,
                 eta: Optional[float] = None,
                 spec_accept_rate: float = 0.7,
                 draft_specs: Optional[Dict[int, LLMSpec]] = None):
        self.topo = topo
        self.wf = wf
        self.eta = eta  # None -> derive task parallelism from the plan
        # speculative-decode operating point: per-token draft accept
        # rate (calibrate from measured gen.spec_accept_rate) and the
        # draft spec per GEN task (default: scaled-down target)
        self.spec_accept_rate = spec_accept_rate
        self.draft_specs = draft_specs or {}

    # -- per-replica micro-batching ------------------------------------
    def _nm_mbs(self, plan: Plan, t: int, i: int) -> Tuple[int, int]:
        local = self.wf.samples_per_iter * plan.replica_fraction(t, i)
        mbs = min(self.wf.micro_batch, max(int(local), 1))
        nm = max(int(math.ceil(local / mbs)), 1)
        return nm, mbs

    def _seq_comm(self) -> int:
        return self.wf.seq_in + self.wf.seq_out

    def _seq_comp(self, task: Task) -> int:
        # actor generation: seq_out := 0 (decode is covered by C_hbm)
        if task.kind == TaskKind.GEN:
            return self.wf.seq_in
        return self.wf.seq_in + self.wf.seq_out

    # -- component costs (Appendix B.2) --------------------------------
    def c_tp(self, plan: Plan, t: int, i: int, j: int) -> float:
        dp, pp, tp = plan.parallel[t]
        if tp == 1:
            return 0.0
        task = self.wf.task(t)
        nm, mbs = self._nm_mbs(plan, t, i)
        cv = BYTES_BF16 * mbs * self._seq_comm() * task.model.h1 \
            * 2 * (tp - 1) / tp
        devs = list(plan.assignment[t][i, j])
        factor = 6 if task.kind == TaskKind.TRAIN else 2
        nl = plan.stage_layers(self.wf, t, j)
        return factor * nm * nl * ring_cost(self.topo, devs, cv)

    def c_pp(self, plan: Plan, t: int, i: int, j: int) -> float:
        dp, pp, tp = plan.parallel[t]
        if j >= pp - 1:
            return 0.0
        task = self.wf.task(t)
        nm, mbs = self._nm_mbs(plan, t, i)
        cv = BYTES_BF16 * mbs * self._seq_comm() * task.model.h1
        cost = pair_min_cost(self.topo, plan.assignment[t][i, j],
                             plan.assignment[t][i, j + 1], cv)
        factor = 2 if task.kind == TaskKind.TRAIN else 1
        return factor * nm * cost

    def c_dp(self, plan: Plan, t: int) -> float:
        task = self.wf.task(t)
        if task.kind != TaskKind.TRAIN:
            return 0.0
        dp, pp, tp = plan.parallel[t]
        if dp == 1:
            return 0.0
        worst = 0.0
        for j in range(pp):
            nl = plan.stage_layers(self.wf, t, j)
            for k in range(tp):
                devs = [int(plan.assignment[t][i, j, k]) for i in range(dp)]
                cv = BYTES_BF16 * nl * task.model.layer_weight_count \
                    * 2 * (dp - 1) / (dp * tp)
                worst = max(worst, ring_cost(self.topo, devs, cv))
        return worst

    def c_comp(self, plan: Plan, t: int, i: int, j: int) -> float:
        task = self.wf.task(t)
        dp, pp, tp = plan.parallel[t]
        nm, mbs = self._nm_mbs(plan, t, i)
        nl = plan.stage_layers(self.wf, t, j)
        fl = flops_per_layer(task, self._seq_comp(task))
        factor = 3 if task.kind == TaskKind.TRAIN else 1
        worst = 0.0
        for k in range(tp):
            d = int(plan.assignment[t][i, j, k])
            worst = max(worst,
                        factor * nm * mbs * nl * fl / (self.topo.comp(d) * tp))
        return worst

    def gen_decode_wave(self, plan: Plan, t: int, i: int = 0) -> int:
        """Decode-wave width the C_hbm term assumes for GEN replica i —
        the bound the genserve engine enforces at execution time."""
        nm, mbs = self._nm_mbs(plan, t, i)
        return decode_wave(nm * mbs)

    def gen_prefill_chunk(self, plan: Plan, t: int, i: int = 0,
                          j: int = 0, chunk: Optional[int] = None,
                          prefix_hit_rate: float = 0.0) -> float:
        """Price of the prefill half of one *mixed wave-step* round for
        GEN replica i, stage j: a fixed-shape ``[W, C]`` prompt chunk
        through the stage's layers (chunked admission never stalls the
        wave, but each round pays this alongside the decode step).

        Compute term: C-token chunk FLOPs for the whole wave — the
        chunk-local attention from ``flops_per_layer`` plus the C x
        resident-cache cross-attention at the mean prefill cursor
        (seq_in / 2).  HBM term: one weight stream per round plus each
        slot's resident KV (or recurrent-state) read, exactly like the
        decode half's roofline.  Total prompt ingestion cost of a
        request is ``ceil(P / C)`` of these rounds, which is what
        ``plan.predicted_occupancy(prefill_rounds=...)`` charges slots
        for.

        ``prefix_hit_rate`` prices paged prefix-cache admission: the
        expected fraction of prompt tokens found cached never runs a
        prefill chunk, so the *amortized* per-round price scales by
        ``(1 - h)`` — the per-request round count shrinks by the same
        factor in :func:`repro.core.plan.prefill_rounds`, and scaling
        here keeps single-round comparisons (benchmark Fig-7 axes)
        honest without re-deriving the round count."""
        task = self.wf.task(t)
        if task.kind != TaskKind.GEN:
            return 0.0
        h = min(max(float(prefix_hit_rate), 0.0), 1.0)
        C = int(chunk) if chunk else PREFILL_CHUNK
        dp, pp, tp = plan.parallel[t]
        nl = plan.stage_layers(self.wf, t, j)
        dbs = self.gen_decode_wave(plan, t, i)
        m = task.model
        fl = flops_per_layer(task, C)
        cache_len = self.wf.seq_in / 2.0       # mean prefill cursor
        if m.attention_free:
            kv_tok, kv_len = 2.0 * _STATE_DIM * m.h1 * BYTES_BF16, 1.0
        else:
            fl += 2 * 2 * C * cache_len * m.h1     # cache cross-attention
            kv_dim = (m.n_kv_heads * m.head_dim
                      if m.n_kv_heads and m.head_dim else m.h1)
            kv_tok, kv_len = 2.0 * kv_dim * BYTES_BF16, cache_len
        worst = 0.0
        for k in range(tp):
            d = int(plan.assignment[t][i, j, k])
            comp = dbs * nl * fl / (self.topo.comp(d) * tp)
            weights = BYTES_BF16 * nl * m.layer_active_count \
                / (self.topo.hbm(d) * tp)
            kv = dbs * nl * kv_tok * kv_len / (self.topo.hbm(d) * tp)
            worst = max(worst, comp + weights + kv)
        return worst * (1.0 - h)

    def gen_wave_occupancy(self, plan: Plan, t: int) -> float:
        """Predicted mean decode-slot occupancy for GEN task t,
        aggregated over dp replicas (total requests / total waves, since
        every wave decodes the same seq_out under the cost model) —
        comparable against the measured slot-table trace of
        ``repro.genserve`` (cost-model parity on the decode-wave axis)."""
        task = self.wf.task(t)
        if task.kind != TaskKind.GEN:
            return 0.0
        dp, _, _ = plan.parallel[t]
        requests, waves = 0.0, 0.0
        for i in range(dp):
            nm, mbs = self._nm_mbs(plan, t, i)
            n = max(nm * mbs, 1)
            requests += n
            waves += math.ceil(n / MAX_DECODE_WAVE)
        return requests / max(waves, 1.0)

    def c_hbm(self, plan: Plan, t: int, i: int, j: int) -> float:
        """Decode HBM roofline priced the way the wave actually executes
        (flash-decode over a batched wave):

          * weights stream once per *wave* step, amortized over the
            ``dbs`` occupied slots — ``n / dbs`` waves of ``seq_out``
            steps each;
          * each occupied slot additionally reads its own KV cache every
            step — KV bytes do NOT amortize across the wave (``n *
            seq_out`` slot-steps at mean cache length ``seq_in +
            seq_out / 2``); attention-free models read a fixed-size
            recurrent state instead (O(1) in sequence length).
        """
        task = self.wf.task(t)
        if task.kind != TaskKind.GEN:
            return 0.0
        m = task.model
        dp, pp, tp = plan.parallel[t]
        nm, mbs = self._nm_mbs(plan, t, i)
        nl = plan.stage_layers(self.wf, t, j)
        dbs = self.gen_decode_wave(plan, t, i)  # bounded-wave batching
        n = nm * mbs
        if m.attention_free:
            # recurrent state read+write per layer-step (matches the
            # h1 x 64 state the flops model assumes), length-independent
            kv_tok, kv_len = 2.0 * _STATE_DIM * m.h1 * BYTES_BF16, 1.0
        else:
            # k + v per layer-token: GQA stores n_kv_heads * head_dim
            # channels (the flash-decode kernel reads KV heads only);
            # h1 fallback when the spec has no head geometry
            kv_dim = (m.n_kv_heads * m.head_dim
                      if m.n_kv_heads and m.head_dim else m.h1)
            kv_tok = 2.0 * kv_dim * BYTES_BF16
            kv_len = self.wf.seq_in + self.wf.seq_out / 2.0
        worst = 0.0
        for k in range(tp):
            d = int(plan.assignment[t][i, j, k])
            weights = self.wf.seq_out * n * BYTES_BF16 * nl \
                * m.layer_active_count / (dbs * self.topo.hbm(d) * tp)
            kv = self.wf.seq_out * n * nl * kv_tok * kv_len \
                / (self.topo.hbm(d) * tp)
            worst = max(worst, weights + kv)
        return worst

    def gen_speculative_wave(self, plan: Plan, t: int, i: int = 0,
                             j: int = 0, *, spec_k: Optional[int] = None,
                             accept_rate: Optional[float] = None,
                             draft: Optional[LLMSpec] = None) -> float:
        """Decode cost of GEN replica i / stage j under draft-model
        speculative decoding — the drop-in replacement for ``c_hbm``
        when ``plan.gen_spec[t] > 0``.

        One draft/verify round emits ``E = (1 - a^(k+1)) / (1 - a)``
        tokens in expectation (per-token accept rate ``a``), so a slot
        needs ``seq_out / E`` rounds instead of ``seq_out`` sequential
        decode steps.  Per round, per tp shard:

          * k+1 draft decode steps (k proposals plus the trailing step
            that lands d_k's draft k/v for the bonus-token case) — the
            draft's weight stream and its (small) per-slot KV read, HBM
            roofline like ``c_hbm``, plus its (tiny) decode FLOPs;
          * ONE target step over the ``[W, k+1]`` candidate chunk —
            target weights stream once (the amortization that buys the
            speedup), the resident KV is read once, and the chunk pays
            (k+1)-token FLOPs including cache cross-attention (priced
            like ``gen_prefill_chunk``'s chunk compute).

        Draft terms scale by ``nl / n_layers`` so a pp-split target
        charges the (replicated, unsplit) draft exactly once across its
        stages.  Attention-free targets cannot run the verify step
        (``cache.supports_speculative_target``) and fall back to
        ``c_hbm``."""
        task = self.wf.task(t)
        if task.kind != TaskKind.GEN:
            return 0.0
        m = task.model
        k = int(spec_k if spec_k is not None else plan.gen_spec.get(t, 0))
        if k <= 0 or m.attention_free:
            return self.c_hbm(plan, t, i, j)
        a = float(accept_rate if accept_rate is not None
                  else self.spec_accept_rate)
        d = draft or self.draft_specs.get(t) or default_draft_spec(m)
        E = speculative_expected_tokens(k, a)
        dp, pp, tp = plan.parallel[t]
        nm, mbs = self._nm_mbs(plan, t, i)
        nl = plan.stage_layers(self.wf, t, j)
        dbs = self.gen_decode_wave(plan, t, i)
        n = nm * mbs
        rounds = self.wf.seq_out * n / (dbs * E)   # waves x rounds/wave
        kv_len = self.wf.seq_in + self.wf.seq_out / 2.0
        kv_dim = (m.n_kv_heads * m.head_dim
                  if m.n_kv_heads and m.head_dim else m.h1)
        dkv_dim = (d.n_kv_heads * d.head_dim
                   if d.n_kv_heads and d.head_dim else d.h1)
        # draft replicated alongside each target stage: charge its cost
        # proportionally so the stage sum pays the full draft once
        dnl = d.n_layers * nl / max(m.n_layers, 1)
        fl_chunk = model_flops_per_layer(m, k + 1) \
            + 2 * 2 * (k + 1) * kv_len * m.h1      # cache cross-attention
        dfl = model_flops_per_layer(d, 1) + 2 * 2 * kv_len * d.h1
        worst = 0.0
        for s in range(tp):
            dev = int(plan.assignment[t][i, j, s])
            hbm, comp = self.topo.hbm(dev), self.topo.comp(dev)
            tgt_w = BYTES_BF16 * nl * m.layer_active_count / (hbm * tp)
            tgt_kv = dbs * nl * 2.0 * kv_dim * BYTES_BF16 * kv_len \
                / (hbm * tp)
            tgt_c = dbs * nl * fl_chunk / (comp * tp)
            drf_w = (k + 1) * BYTES_BF16 * dnl * d.layer_active_count \
                / (hbm * tp)
            drf_kv = (k + 1) * dbs * dnl * 2.0 * dkv_dim * BYTES_BF16 \
                * kv_len / (hbm * tp)
            drf_c = (k + 1) * dbs * dnl * dfl / (comp * tp)
            worst = max(worst, tgt_w + tgt_kv + tgt_c
                        + drf_w + drf_kv + drf_c)
        return rounds * worst

    def c_bubble(self, plan: Plan, t: int, i: int) -> float:
        task = self.wf.task(t)
        if task.kind != TaskKind.TRAIN:
            return 0.0
        dp, pp, tp = plan.parallel[t]
        if pp == 1:
            return 0.0
        nm, _ = self._nm_mbs(plan, t, i)
        tot = 0.0
        for j in range(1, pp):
            tot += (self.c_comp(plan, t, i, j) + self.c_tp(plan, t, i, j)
                    + self.c_pp(plan, t, i, j))
        return tot / nm

    # -- task-level (Appendix B.3) --------------------------------------
    def task_cost(self, plan: Plan, t: int) -> TaskCost:
        task = self.wf.task(t)
        dp, pp, tp = plan.parallel[t]
        worst_total = 0.0
        agg = TaskCost(0.0)
        for i in range(dp):
            stage_max = 0.0
            for j in range(pp):
                comp = self.c_comp(plan, t, i, j)
                ctp = self.c_tp(plan, t, i, j)
                cpp = self.c_pp(plan, t, i, j)
                if plan.gen_spec.get(t, 0) > 0:
                    chbm = self.gen_speculative_wave(plan, t, i, j)
                else:
                    chbm = self.c_hbm(plan, t, i, j)
                s = comp + ctp + cpp + chbm
                if s > stage_max:
                    stage_max = s
                    agg.comp, agg.tp, agg.pp, agg.hbm = comp, ctp, cpp, chbm
            bub = self.c_bubble(plan, t, i)
            total_i = stage_max + bub
            if total_i > worst_total:
                worst_total = total_i
                agg.bubble = bub
        cdp = self.c_dp(plan, t)
        agg.dp = cdp
        agg.total = worst_total + cdp
        return agg

    # -- resharding / weight sync (Appendix B.2) ------------------------
    def c_reshard(self, plan: Plan, actor_train: int) -> float:
        t = actor_train
        task = self.wf.task(t)
        dp, pp, tp = plan.parallel[t]
        n_shards = pp * tp
        if n_shards == 1:
            return 0.0
        cv = BYTES_BF16 * task.model.n_layers \
            * task.model.layer_weight_count * (n_shards - 1) / n_shards
        worst = 0.0
        for i in range(dp):
            devs = list(plan.assignment[t][i].reshape(-1))
            worst = max(worst, ring_cost(self.topo, devs, cv))
        return worst

    def c_sync(self, plan: Plan, actor_train: int, actor_gen: int) -> float:
        """all-gather at train + p2p across + broadcast at gen."""
        t, tg = actor_train, actor_gen
        m = self.wf.task(t).model
        full = BYTES_BF16 * m.n_layers * m.layer_weight_count
        # all-gather within the fastest training replica
        dp, pp, tp = plan.parallel[t]
        n_sh = pp * tp
        ag = 0.0
        if n_sh > 1:
            cv = full * (n_sh - 1) / n_sh
            ag = min(ring_cost(self.topo, list(plan.assignment[t][i]
                                               .reshape(-1)), cv)
                     for i in range(dp))
        # p2p train -> gen
        p2p = pair_min_cost(self.topo,
                            plan.assignment[t].reshape(-1),
                            plan.assignment[tg].reshape(-1), full)
        # broadcast within gen replicas (worst replica)
        dpg, ppg, tpg = plan.parallel[tg]
        n_shg = ppg * tpg
        bc = 0.0
        if n_shg * dpg > 1:
            cvb = full * (n_shg - 1) / max(n_shg, 1) if n_shg > 1 else full
            bc = max(ring_cost(self.topo,
                               list(plan.assignment[tg][i].reshape(-1)),
                               max(cvb, full / max(n_shg, 1)))
                     for i in range(dpg))
        return ag + p2p + bc

    # -- end-to-end (Appendix B.4) ---------------------------------------
    def _phi(self, plan: Plan, costs: Dict[int, float]) -> float:
        """Φ over independent tasks: colocated tasks serialize, disjoint
        GPU groups run in parallel (derived η); scalar η override."""
        if self.eta is not None:
            vals = list(costs.values())
            return self.eta * max(vals) + (1 - self.eta) * sum(vals)
        by_group: Dict[Tuple[int, ...], float] = {}
        for t, c in costs.items():
            key = plan.group_of(t).devices
            by_group[key] = by_group.get(key, 0.0) + c
        return max(by_group.values())

    def iteration_cost(self, plan: Plan) -> Dict[str, float]:
        wf = self.wf
        costs = {t: self.task_cost(plan, t).total for t in range(wf.n_tasks)}
        if wf.algorithm == "ppo":
            gen, inf, train = costs[0], \
                self._phi(plan, {1: costs[1], 2: costs[2], 3: costs[3]}), \
                self._phi(plan, {4: costs[4], 5: costs[5]})
            actor_train, actor_gen = 4, 0
        else:  # grpo
            gen = costs[0]
            inf = self._phi(plan, {1: costs[1], 2: costs[2]})
            train = costs[3]
            actor_train, actor_gen = 3, 0
        if wf.synchronous:
            extra = self.c_reshard(plan, actor_train)
            total = gen + inf + train + extra
        else:
            extra = self.c_sync(plan, actor_train, actor_gen)
            total = max(gen, inf + train) + extra
        return {"total": total, "gen": gen, "inf": inf, "train": train,
                "reshard_or_sync": extra,
                "throughput": wf.samples_per_iter / total,
                **{f"task{t}": c for t, c in costs.items()}}

    def cost(self, plan: Plan) -> float:
        return self.iteration_cost(plan)["total"]
