"""Execution plans: partitioning strategy ρ + assignment strategy σ (§3.1).

A plan fixes, per the multi-level framework (§3.2):
  L1  task grouping            — disjoint sets of tasks sharing GPUs
  L2  GPU group sizes          — implicit in the device lists
  L3  task-group → device set  — ``TaskGroup.devices``
  L4  intra-model parallelization — (dp, pp, tp) per task
  L5  tasklet → device mapping — ``assignment[t][i, j, k] -> device id``
plus the load-balancing knobs (§4.2): per-stage layer counts and per-replica
batch fractions.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.topology import Topology
from repro.core.workflow import RLWorkflow, Task, TaskKind

BYTES_BF16 = 2
BYTES_FP32 = 4
# mixed-precision Adam: bf16 weights + fp32 master + fp32 m,v + bf16 grads
TRAIN_BYTES_PER_PARAM = 16
INFER_BYTES_PER_PARAM = 2
# generation engines (vLLM-style continuous batching) decode in waves of at
# most this many sequences per replica; bounds KV working memory and C_hbm.
MAX_DECODE_WAVE = 32
# default prompt tokens ingested per mixed wave-step round under chunked
# admission (genserve prefill_chunk); bounds how long admission of a long
# prompt can delay the decode half of a round.
PREFILL_CHUNK = 16


def decode_wave(local_batch: float) -> int:
    return max(min(int(local_batch), MAX_DECODE_WAVE), 1)


def prefill_rounds(prompt_len: int, prefill_chunk: int,
                   prefix_hit_rate: float = 0.0) -> int:
    """Mixed wave-step rounds a request's prompt ingestion occupies a
    decode slot for under chunked admission (0 = one-shot admission,
    which the occupancy model prices as free).

    ``prefix_hit_rate`` is the expected fraction of prompt tokens served
    from the paged prefix cache (genserve ``prefix_cache=True``): only
    the uncached suffix is prefilled, floored at one round — even a
    fully cached prompt runs its landing chunk (the hit is capped at
    plen-1 so first-token logits come from a real forward pass)."""
    if prefill_chunk <= 0:
        return 0
    h = min(max(float(prefix_hit_rate), 0.0), 1.0)
    suffix = max(int(prompt_len), 1) * (1.0 - h)
    return max(math.ceil(suffix / int(prefill_chunk)), 1)


def predicted_occupancy(n_requests: float,
                        wave: Optional[int] = None,
                        gen_lens: Optional[Sequence[int]] = None,
                        prefill_rounds: float = 0.0,
                        max_new_tokens: Optional[int] = None,
                        prefix_hit_rate: float = 0.0) -> float:
    """Predicted mean decode-slot occupancy under continuous batching.

    This is the occupancy the cost model's ``C_hbm`` wave term assumes
    and the number the genserve engine's measured slot-table trace is
    compared against (Fig-7 style parity, decode-wave axis).

    With uniform generation lengths every wave is full except the last
    partial one: occupancy = n / ceil(n / W).  Given per-request lengths,
    ideal continuous batching is bounded by the longest request and by
    total work: steps >= max(max_len, ceil(sum_len / W)), and occupancy
    is total tokens over that lower bound.

    ``prefill_rounds`` > 0 prices *chunked admission* instead of
    assuming prompt ingestion free: each request additionally occupies a
    slot for that many mixed wave-step rounds before its first token
    (see :func:`prefill_rounds`), the work bound grows accordingly, and
    the returned figure is the *busy* occupancy (slots doing a decode
    step or a prefill chunk per round) — comparable against the slot
    table's ``busy_occupancy()``.  A scalar applies to every request;
    a sequence gives per-request rounds (aligned with ``gen_lens`` —
    required for the chain bound to stay a true upper bound under
    heterogeneous prompt lengths).  Uniform lengths then need
    ``max_new_tokens`` (the per-request decode length).

    ``prefix_hit_rate`` scales the admission price for paged
    prefix-cache serving: each request's prefill rounds shrink to the
    expected uncached fraction ``(1 - h)``, floored at one round per
    admitted request (the landing chunk always runs)."""
    W = wave if wave is not None else MAX_DECODE_WAVE
    W = max(int(W), 1)
    n = max(float(n_requests), 1.0)
    scalar_c = not hasattr(prefill_rounds, "__len__")
    if scalar_c and max(float(prefill_rounds), 0.0) == 0.0 \
            and gen_lens is None:
        return n / math.ceil(n / W)
    if gen_lens is None:
        assert max_new_tokens is not None, \
            "uniform-length occupancy with prefill rounds needs " \
            "max_new_tokens"
        lens = [max(int(max_new_tokens), 1)] * int(n)
    else:
        lens = [max(int(l), 1) for l in gen_lens]
    if scalar_c:
        cs = [max(float(prefill_rounds), 0.0)] * len(lens)
    else:
        cs = [max(float(c), 0.0) for c in prefill_rounds]
        assert len(cs) == len(lens), \
            "per-request prefill_rounds must align with gen_lens"
    h = min(max(float(prefix_hit_rate), 0.0), 1.0)
    if h > 0.0:
        cs = [max(c * (1.0 - h), 1.0) if c > 0.0 else 0.0 for c in cs]
    total = sum(lens) + sum(cs)
    chain = max(l + c for l, c in zip(lens, cs))
    steps = max(chain, math.ceil(total / W))
    return total / steps


@dataclasses.dataclass(frozen=True)
class TaskGroup:
    tasks: Tuple[int, ...]
    devices: Tuple[int, ...]


@dataclasses.dataclass
class Plan:
    groups: Tuple[TaskGroup, ...]
    parallel: Dict[int, Tuple[int, int, int]]      # task -> (dp, pp, tp)
    assignment: Dict[int, np.ndarray]              # task -> [dp, pp, tp] dev
    layers_per_stage: Dict[int, Tuple[int, ...]] = dataclasses.field(
        default_factory=dict)
    batch_fraction: Dict[int, Tuple[float, ...]] = dataclasses.field(
        default_factory=dict)
    # GEN task -> draft-k for speculative decoding (absent/0 = plain
    # wave decode); an alternative GEN parallelization the EA can toggle
    # per device class — priced by CostModel.gen_speculative_wave.
    gen_spec: Dict[int, int] = dataclasses.field(default_factory=dict)

    def group_of(self, task: int) -> TaskGroup:
        for g in self.groups:
            if task in g.tasks:
                return g
        raise KeyError(task)

    def stage_layers(self, wf: RLWorkflow, t: int, j: int) -> int:
        if t in self.layers_per_stage:
            return self.layers_per_stage[t][j]
        nl = wf.task(t).model.n_layers
        pp = self.parallel[t][1]
        base = nl // pp
        return base + (1 if j < nl % pp else 0)

    def replica_fraction(self, t: int, i: int) -> float:
        dp = self.parallel[t][0]
        if t in self.batch_fraction:
            return self.batch_fraction[t][i]
        return 1.0 / dp

    def devices_of_stage(self, t: int, i: int, j: int) -> np.ndarray:
        return self.assignment[t][i, j]

    def max_device(self) -> int:
        """Largest device id any tasklet is assigned to (-1 if none)."""
        ids = [int(a.max()) for a in self.assignment.values() if a.size]
        return max(ids, default=-1)

    def fits_topology(self, topo) -> bool:
        """Whether every assigned device id exists in ``topo`` — false
        after a device drop re-indexed the survivors under an incumbent
        plan that still references the old ids (the no-feasible-
        challenger elastic case); simulating such a pair would index
        past the device list."""
        return self.max_device() < topo.n

    def task_grouping_key(self) -> Tuple[Tuple[int, ...], ...]:
        return tuple(sorted(g.tasks for g in self.groups))

    def gpu_sizes_key(self) -> Tuple[int, ...]:
        return tuple(len(g.devices) for g in self.groups)


# ---------------------------------------------------------------------------
# Memory model (constraint C3) — coarse, following verl/Alpa conventions
# ---------------------------------------------------------------------------

def model_memory(wf: RLWorkflow, plan: Plan, t: int, j: int) -> float:
    """M_model for one tasklet of task t in pipeline stage j (bytes)."""
    task = wf.task(t)
    dp, pp, tp = plan.parallel[t]
    nl_j = plan.stage_layers(wf, t, j)
    per_layer = task.model.layer_weight_count
    w = per_layer * nl_j
    if j in (0, pp - 1):  # embedding / lm head
        w += task.model.vocab * task.model.h1
    bpp = TRAIN_BYTES_PER_PARAM if task.kind == TaskKind.TRAIN \
        else INFER_BYTES_PER_PARAM
    return w * bpp / tp


def working_memory(wf: RLWorkflow, plan: Plan, t: int, i: int, j: int) -> float:
    """M_working for tasklets of (t, i, j) (bytes)."""
    task = wf.task(t)
    dp, pp, tp = plan.parallel[t]
    nl_j = plan.stage_layers(wf, t, j)
    seq = wf.seq_in + wf.seq_out
    local_batch = wf.samples_per_iter * plan.replica_fraction(t, i)
    m = task.model
    if task.kind == TaskKind.GEN:
        h_kv = (m.n_kv_heads * m.head_dim) if m.n_kv_heads else m.h1
        if m.attention_free:
            h_kv = m.h1 // 8  # recurrent state, not seq-proportional
            seq_eff = 1
        else:
            seq_eff = seq
        wave = decode_wave(local_batch)
        return 2 * BYTES_BF16 * nl_j * h_kv * seq_eff * wave / tp
    mbs = min(wf.micro_batch, max(local_batch, 1))
    if task.kind == TaskKind.TRAIN:
        # full remat: saved block inputs + transient working set + logits
        act = BYTES_BF16 * mbs * seq * m.h1 * (nl_j + 8) / tp
        act += BYTES_BF16 * mbs * seq * m.vocab / tp * (1 if j == pp - 1 else 0)
        return act
    return BYTES_BF16 * mbs * seq * m.h1 * 8 / tp


def check_constraints(topo: Topology, wf: RLWorkflow, plan: Plan,
                      verbose: bool = False) -> Tuple[bool, str]:
    """C1 (tasklet count), C2 (cover/validity), C3 (memory)."""
    seen_tasks = set()
    for g in plan.groups:
        for t in g.tasks:
            if t in seen_tasks:
                return False, f"task {t} in two groups"
            seen_tasks.add(t)
    if seen_tasks != set(range(wf.n_tasks)):
        return False, "task grouping does not cover all tasks"

    dev_seen = set()
    for g in plan.groups:
        for d in g.devices:
            if d in dev_seen:
                return False, f"device {d} in two groups"
            dev_seen.add(d)

    mem_use = np.zeros(topo.n)          # sum of M_model per device
    mem_peak = np.zeros(topo.n)         # max M_working per device
    for t in range(wf.n_tasks):
        if t not in plan.parallel or t not in plan.assignment:
            return False, f"task {t} missing parallelization/assignment"
        dp, pp, tp = plan.parallel[t]
        asg = plan.assignment[t]
        if asg.shape != (dp, pp, tp):
            return False, f"task {t} assignment shape {asg.shape}"
        n_tasklets = dp * pp * tp
        if n_tasklets > topo.n:
            return False, f"task {t}: C1 violated ({n_tasklets} > {topo.n})"
        group = plan.group_of(t)
        gset = set(group.devices)
        flat = asg.reshape(-1)
        if len(set(flat.tolist())) != len(flat):
            return False, f"task {t}: tasklet devices not distinct"
        if not set(flat.tolist()) <= gset:
            return False, f"task {t}: devices outside its group"
        for i in range(dp):
            for j in range(pp):
                mm = model_memory(wf, plan, t, j)
                wm = working_memory(wf, plan, t, i, j)
                for d in asg[i, j]:
                    mem_use[d] += mm
                    mem_peak[d] = max(mem_peak[d], wm)
        if t in plan.layers_per_stage:
            if sum(plan.layers_per_stage[t]) != wf.task(t).model.n_layers:
                return False, f"task {t}: layer split does not sum"
        if t in plan.batch_fraction:
            if abs(sum(plan.batch_fraction[t]) - 1.0) > 1e-6:
                return False, f"task {t}: batch fractions do not sum to 1"

    for d in range(topo.n):
        if mem_use[d] + mem_peak[d] > topo.mem(d):
            return False, (f"OOM device {d} ({topo.devices[d].spec.name}): "
                           f"{mem_use[d] + mem_peak[d]:.2e} > "
                           f"{topo.mem(d):.2e}")
    return True, "ok"


def memory_overflow(topo: Topology, wf: RLWorkflow, plan: Plan) -> float:
    """max over devices of (required / capacity - 1), 0 if all fit.

    Assumes the plan is structurally valid (check_constraints covers that);
    used by the EA to grade infeasible-but-close candidates."""
    mem_use = np.zeros(topo.n)
    mem_peak = np.zeros(topo.n)
    for t in range(wf.n_tasks):
        dp, pp, tp = plan.parallel[t]
        asg = plan.assignment[t]
        for i in range(dp):
            for j in range(pp):
                mm = model_memory(wf, plan, t, j)
                wm = working_memory(wf, plan, t, i, j)
                for d in asg[i, j]:
                    mem_use[d] += mm
                    mem_peak[d] = max(mem_peak[d], wm)
    ratios = (mem_use + mem_peak) / np.array(
        [topo.mem(d) for d in range(topo.n)])
    return max(float(ratios.max()) - 1.0, 0.0)


def feasible_parallelizations(n_devices: int, n_layers: int,
                              max_tp: int = 8) -> List[Tuple[int, int, int]]:
    """All (dp, pp, tp) with dp*pp*tp <= n_devices, pp <= n_layers."""
    out = []
    for tp in [1, 2, 4, 8]:
        if tp > max_tp or tp > n_devices:
            continue
        for pp in range(1, min(n_layers, n_devices // tp) + 1):
            rem = n_devices // (tp * pp)
            for dp in range(1, rem + 1):
                out.append((dp, pp, tp))
    return out
