"""Device topology graph G_D for heterogeneous environments (§3.1, §5.1).

Devices carry compute capability (TFLOPS), memory capacity and HBM
bandwidth; every device pair carries latency alpha (s) and bandwidth beta
(GB/s).  Builders reproduce the paper's 64-GPU testbed (24×A100, 24×L40S,
16×L4 — Table 1) under the four network scenarios of §5.1, and additionally
a TPU-native pool (DESIGN.md hardware adaptation).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class GPUSpec:
    name: str
    fp16_tflops: float
    mem_gb: float
    hbm_gbps: float
    intra_node_gbps: float  # NVLink / PCIe / ICI, GB/s per pair


# Table 1 of the paper
A100 = GPUSpec("A100", 312.0, 40.0, 2039.0, 600.0 / 8)
L40S = GPUSpec("L40S", 366.0, 48.0, 864.0, 64.0 / 8)
L4 = GPUSpec("L4", 121.0, 24.0, 300.0, 64.0 / 8)
# TPU-native (v5e; DESIGN.md) — 197 TF bf16, 16 GB, 819 GB/s HBM, ICI 50 GB/s
TPU_V5E = GPUSpec("TPUv5e", 197.0, 16.0, 819.0, 50.0)
TPU_V4 = GPUSpec("TPUv4", 275.0, 32.0, 1200.0, 50.0)


@dataclasses.dataclass(frozen=True)
class Device:
    id: int
    spec: GPUSpec
    machine: int
    zone: int
    region: str


@dataclasses.dataclass
class Topology:
    devices: List[Device]
    latency_s: np.ndarray     # [N, N] seconds
    bandwidth_gbps: np.ndarray  # [N, N] GB/s

    @property
    def n(self) -> int:
        return len(self.devices)

    def alpha(self, a: int, b: int) -> float:
        return float(self.latency_s[a, b])

    def beta(self, a: int, b: int) -> float:
        return float(self.bandwidth_gbps[a, b])

    def comp(self, d: int) -> float:
        return self.devices[d].spec.fp16_tflops * 1e12

    def mem(self, d: int) -> float:
        return self.devices[d].spec.mem_gb * 1e9

    def hbm(self, d: int) -> float:
        return self.devices[d].spec.hbm_gbps * 1e9

    def locality(self, a: int, b: int) -> int:
        """Affinity score: 3 same machine, 2 same zone, 1 same region."""
        da, db = self.devices[a], self.devices[b]
        if da.machine == db.machine:
            return 3
        if da.zone == db.zone and da.region == db.region:
            return 2
        if da.region == db.region:
            return 1
        return 0

    def locality_matrix(self) -> np.ndarray:
        """[N, N] pairwise locality scores (cached)."""
        cached = getattr(self, "_loc_mat", None)
        if cached is not None:
            return cached
        n = self.n
        mat = np.zeros((n, n), np.float64)
        for a in range(n):
            for b in range(a + 1, n):
                mat[a, b] = mat[b, a] = self.locality(a, b)
        self._loc_mat = mat
        return mat

    def subset_mean_tflops(self, ids: Sequence[int]) -> float:
        return float(np.mean([self.devices[d].spec.fp16_tflops
                              for d in ids])) if ids else 0.0


# ---------------------------------------------------------------------------
# Network synthesis
# ---------------------------------------------------------------------------

_EU_REGIONS = ["paris", "stockholm", "london", "ireland", "spain", "zurich",
               "frankfurt", "milan"]
_US_REGIONS = ["virginia", "ohio"]

_INTRA_REGION_LAT_S = 2e-4       # 0.2 ms within an AZ
_INTRA_REGION_BW = 100.0 / 8     # 100 Gbps EFA -> GB/s
_INTRA_MACHINE_LAT_S = 5e-6


def _pair_params(rng, lat_range_ms, bw_range_gbps):
    lat = rng.uniform(*lat_range_ms) * 1e-3
    bw = rng.uniform(*bw_range_gbps) / 8.0  # Gbps -> GB/s
    return lat, bw


def _build(devices: List[Device], region_lat_bw, seed=0) -> Topology:
    n = len(devices)
    lat = np.zeros((n, n))
    bw = np.zeros((n, n))
    for i, j in itertools.product(range(n), range(n)):
        if i == j:
            bw[i, j] = max(devices[i].spec.hbm_gbps, 1.0)
            continue
        di, dj = devices[i], devices[j]
        if di.machine == dj.machine:
            lat[i, j] = _INTRA_MACHINE_LAT_S
            bw[i, j] = min(di.spec.intra_node_gbps, dj.spec.intra_node_gbps)
        elif di.region == dj.region:
            lat[i, j] = _INTRA_REGION_LAT_S
            bw[i, j] = _INTRA_REGION_BW
        else:
            key = tuple(sorted((di.region, dj.region)))
            lat[i, j], bw[i, j] = region_lat_bw[key]
    return Topology(devices, lat, bw)


def _mk_devices(counts: Dict[str, int], region_of, zone_of=None,
                gpus_per_machine=8) -> List[Device]:
    """counts: spec-name -> count. region_of(idx)->region."""
    specs = {"A100": A100, "L40S": L40S, "L4": L4,
             "TPUv5e": TPU_V5E, "TPUv4": TPU_V4}
    devices = []
    machine = 0
    idx = 0
    for name, cnt in counts.items():
        per = 4 if name == "L4" else gpus_per_machine
        for m in range(0, cnt, per):
            nm = min(per, cnt - m)
            for _ in range(nm):
                region = region_of(idx)
                zone = zone_of(idx) if zone_of else 0
                devices.append(Device(idx, specs[name], machine, zone, region))
                idx += 1
            machine += 1
    return devices


def build_testbed(scenario: str, seed: int = 0,
                  counts: Optional[Dict[str, int]] = None) -> Topology:
    """The paper's 64-GPU testbed under one of the four §5.1 scenarios."""
    rng = np.random.default_rng(seed)
    counts = counts or {"A100": 24, "L40S": 24, "L4": 16}
    total = sum(counts.values())

    if scenario == "single_region":
        devices = _mk_devices(counts, lambda i: "virginia")
        return _build(devices, {})

    if scenario == "multi_region_hybrid":
        # Ohio + Virginia; last quarter of Virginia GPUs are at the edge.
        def region_of(i):
            return "ohio" if i < total // 2 else "virginia"
        devices = _mk_devices(counts, region_of)
        pair = {("ohio", "virginia"): (10e-3, 5.0 / 8)}
        topo = _build(devices, pair)
        edge = [d.id for d in devices if d.region == "virginia"][-total // 4:]
        for e in edge:
            for j in range(total):
                if j == e or devices[j].machine == devices[e].machine:
                    continue
                cap = 1.0 / 8
                topo.bandwidth_gbps[e, j] = min(topo.bandwidth_gbps[e, j], cap)
                topo.bandwidth_gbps[j, e] = min(topo.bandwidth_gbps[j, e], cap)
                if devices[j].region != "virginia":
                    # edge GPUs reach other regions only via Virginia relay:
                    # slow but not disconnected
                    relay = 0.5 / 8
                    topo.bandwidth_gbps[e, j] = topo.bandwidth_gbps[j, e] = relay
                    topo.latency_s[e, j] = topo.latency_s[j, e] = 25e-3
        return topo

    if scenario == "multi_country":
        regions = _EU_REGIONS
        def region_of(i):
            return regions[i * len(regions) // total]
        devices = _mk_devices(counts, region_of)
        pair = {tuple(sorted(p)): _pair_params(rng, (5, 30), (1.9, 5.0))
                for p in itertools.combinations(regions, 2)}
        return _build(devices, pair)

    if scenario == "multi_continent":
        regions = _EU_REGIONS[:6] + _US_REGIONS
        def region_of(i):
            return regions[i * len(regions) // total]
        devices = _mk_devices(counts, region_of)
        pair = {}
        for p in itertools.combinations(regions, 2):
            cross = (p[0] in _US_REGIONS) != (p[1] in _US_REGIONS)
            rng_lat = (30, 60) if cross else (5, 30)
            rng_bw = (0.9, 3.0) if cross else (1.9, 5.0)
            pair[tuple(sorted(p))] = _pair_params(rng, rng_lat, rng_bw)
        return _build(devices, pair)

    raise ValueError(f"unknown scenario {scenario!r}")


SCENARIOS = ["single_region", "multi_region_hybrid", "multi_country",
             "multi_continent"]


def build_host(n: int, spec: GPUSpec = A100) -> Topology:
    """Homogeneous single-machine topology with `n` devices — the proxy
    the execution engine plans against when no scheduler plan is given
    (one device id per local accelerator)."""
    devices = [Device(i, spec, 0, 0, "local") for i in range(n)]
    return _build(devices, {})


# ---------------------------------------------------------------------------
# Topology drift (§6 online redeployment): constructible degradations
# ---------------------------------------------------------------------------

def topo_equal(a: Optional["Topology"], b: Optional["Topology"]) -> bool:
    """Structural equality — same devices (spec/placement) and the same
    latency/bandwidth matrices.  Used by the elasticity controller to
    decide whether a topology feed actually drifted."""
    if a is b:
        return True
    if a is None or b is None or a.n != b.n:
        return False
    for da, db in zip(a.devices, b.devices):
        if (da.spec, da.machine, da.zone, da.region) != \
                (db.spec, db.machine, db.zone, db.region):
            return False
    return np.array_equal(a.latency_s, b.latency_s) and \
        np.array_equal(a.bandwidth_gbps, b.bandwidth_gbps)


def degrade_links(topo: Topology, *, bw_factor: float = 0.05,
                  lat_factor: float = 10.0,
                  pairs: Optional[Sequence[Tuple[int, int]]] = None,
                  regions: Optional[Sequence[str]] = None,
                  fraction: float = 1.0, seed: int = 0) -> Topology:
    """A degraded copy of `topo`: selected inter-device links lose
    bandwidth (×bw_factor) and gain latency (×lat_factor).

    Link selection, most specific first:
      * ``pairs``   — explicit (a, b) device pairs (applied symmetrically);
      * ``regions`` — two names degrade the links *between* those regions,
        one name degrades every cross-machine link touching it;
      * default     — every cross-machine link, subsampled to ``fraction``
        with a seeded rng so scenarios are reproducible.

    The diagonal (HBM) is never touched; the input topology is not
    mutated."""
    n = topo.n
    mask = np.zeros((n, n), dtype=bool)
    if pairs is not None:
        for a, b in pairs:
            mask[a, b] = mask[b, a] = True
    else:
        cross = np.array(
            [[topo.devices[i].machine != topo.devices[j].machine
              for j in range(n)] for i in range(n)])
        if regions is not None:
            regs = list(regions)
            in_r = np.array([d.region in regs for d in topo.devices])
            if len(regs) >= 2:
                r0 = np.array([d.region == regs[0] for d in topo.devices])
                r1 = np.array([d.region in regs[1:] for d in topo.devices])
                mask = cross & (np.outer(r0, r1) | np.outer(r1, r0))
            else:
                mask = cross & (in_r[:, None] | in_r[None, :])
        else:
            mask = cross
        if fraction < 1.0:
            rng = np.random.default_rng(seed)
            iu = np.triu_indices(n, k=1)
            keep = rng.random(len(iu[0])) < fraction
            sub = np.zeros((n, n), dtype=bool)
            sub[iu[0][keep], iu[1][keep]] = True
            sub |= sub.T
            mask &= sub
    np.fill_diagonal(mask, False)
    lat = topo.latency_s.copy()
    bw = topo.bandwidth_gbps.copy()
    lat[mask] *= lat_factor
    bw[mask] *= bw_factor
    return Topology(list(topo.devices), lat, bw)


def scale_compute(topo: Topology, factor: float, *,
                  device_class: Optional[str] = None,
                  ids: Optional[Sequence[int]] = None) -> Topology:
    """A copy of `topo` with selected devices' compute and HBM throughput
    multiplied by ``factor`` (< 1 models thermal throttling / preemption
    pressure on a device class).  Selection: explicit ``ids``, else every
    device whose spec name equals ``device_class``, else all devices.
    Link matrices are untouched; the input topology is not mutated."""
    chosen = set(int(d) for d in ids) if ids is not None else None
    devices = []
    for d in topo.devices:
        hit = (chosen is not None and d.id in chosen) or \
            (chosen is None and (device_class is None
                                 or d.spec.name == device_class))
        if hit:
            spec = dataclasses.replace(
                d.spec, fp16_tflops=d.spec.fp16_tflops * factor,
                hbm_gbps=d.spec.hbm_gbps * factor)
            devices.append(dataclasses.replace(d, spec=spec))
        else:
            devices.append(d)
    return Topology(devices, topo.latency_s.copy(),
                    topo.bandwidth_gbps.copy())


def drop_devices(topo: Topology, ids: Sequence[int]) -> Topology:
    """The topology with `ids` removed and the survivors re-indexed to a
    dense 0..n'-1 id space (matrices restricted accordingly).  Plans built
    against the old topology become invalid on the result — ``reschedule``
    treats such incumbents as infinitely costly."""
    gone = set(int(d) for d in ids)
    keep = [d.id for d in topo.devices if d.id not in gone]
    if not keep:
        raise ValueError("cannot drop every device")
    devices = [dataclasses.replace(topo.devices[d], id=i)
               for i, d in enumerate(keep)]
    idx = np.asarray(keep)
    return Topology(devices, topo.latency_s[np.ix_(idx, idx)].copy(),
                    topo.bandwidth_gbps[np.ix_(idx, idx)].copy())


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """One medium-granularity fleet/network change: from `iteration` on,
    the environment looks like `topo`."""
    iteration: int
    description: str
    topo: Topology


@dataclasses.dataclass
class DriftSchedule:
    """A deterministic topology feed for tests/benchmarks: ``topo_at(it)``
    returns the environment the fleet is in at iteration ``it`` (the base
    topology until the first event fires)."""
    base: Topology
    events: List[DriftEvent]

    def __post_init__(self):
        self.events = sorted(self.events, key=lambda e: e.iteration)

    def topo_at(self, iteration: int) -> Topology:
        topo = self.base
        for e in self.events:
            if e.iteration <= iteration:
                topo = e.topo
        return topo

    @classmethod
    def generate(cls, base: Topology, *, seed: int = 0, n_events: int = 2,
                 first_iteration: int = 5, every: int = 10,
                 recover: bool = True) -> "DriftSchedule":
        """Seeded random degradation scenario: `n_events` successive
        cross-machine link degradations (random severity and coverage),
        optionally followed by a recovery back to the base topology."""
        rng = np.random.default_rng(seed)
        events = []
        it = first_iteration
        for k in range(n_events):
            bw_f = float(rng.uniform(0.02, 0.2))
            lat_f = float(rng.uniform(5.0, 20.0))
            frac = float(rng.uniform(0.5, 1.0))
            events.append(DriftEvent(
                it, f"degrade x{1 / bw_f:.0f} bw on {frac:.0%} of "
                    f"cross-machine links",
                degrade_links(base, bw_factor=bw_f, lat_factor=lat_f,
                              fraction=frac, seed=seed + k)))
            it += every
        if recover:
            events.append(DriftEvent(it, "network recovers", base))
        return cls(base, events)


DRIFT_SCENARIOS = ["degrade_cross", "degrade_severe", "drop_tail", "flaky"]


def drift_scenario(name: str, base: Topology, *, at: int = 5,
                   seed: int = 0) -> DriftSchedule:
    """Named degradation scenarios for the launcher/benchmarks:
      degrade_cross  — cross-machine bandwidth /20, latency ×10 at `at`;
      degrade_severe — cross-machine bandwidth /100, latency ×50 at `at`;
      drop_tail      — the last quarter of the fleet disappears at `at`;
      flaky          — seeded multi-event drift (DriftSchedule.generate)."""
    if name == "degrade_cross":
        ev = DriftEvent(at, "cross-machine links degrade 20x",
                        degrade_links(base, bw_factor=0.05, lat_factor=10.0))
        return DriftSchedule(base, [ev])
    if name == "degrade_severe":
        ev = DriftEvent(at, "cross-machine links degrade 100x",
                        degrade_links(base, bw_factor=0.01, lat_factor=50.0))
        return DriftSchedule(base, [ev])
    if name == "drop_tail":
        tail = [d.id for d in base.devices[-max(base.n // 4, 1):]]
        ev = DriftEvent(at, f"devices {tail} leave the fleet",
                        drop_devices(base, tail))
        return DriftSchedule(base, [ev])
    if name == "flaky":
        return DriftSchedule.generate(base, seed=seed, first_iteration=at)
    raise ValueError(f"unknown drift scenario {name!r}; "
                     f"options: {DRIFT_SCENARIOS}")


def build_tpu_pool(n_v5e: int = 32, n_v4: int = 16, seed: int = 0) -> Topology:
    """TPU-native heterogeneous pool: a v5e slice + a v4 slice joined by DCN
    (the TPU analogue of the paper's cross-region setting)."""
    counts = {"TPUv5e": n_v5e, "TPUv4": n_v4}
    total = n_v5e + n_v4
    def region_of(i):
        return "v5e-slice" if i < n_v5e else "v4-slice"
    devices = _mk_devices(counts, region_of, gpus_per_machine=4)
    pair = {("v4-slice", "v5e-slice"): (1e-3, 6.25)}  # DCN ~50 Gbps, 1 ms
    return _build(devices, pair)
