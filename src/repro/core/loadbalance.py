"""Load balancing (§4.2): data-level and layer-level strategies.

Data-level: skew per-replica batch fractions toward faster DP replicas for
the actor rollout (and, for fixed-length tasks, assign longer sequences to
faster GPUs — here expressed through the same fraction knob since the cost
model is sequence-homogeneous per iteration).

Layer-level: apportion pipeline-stage layer counts proportionally to each
stage's effective compute speed.

Both operate as plan post-processing, enlarging the search space exactly as
the paper describes (no invasive engine changes).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.core.plan import Plan
from repro.core.topology import Topology
from repro.core.workflow import RLWorkflow, TaskKind


def _replica_speed(topo: Topology, plan: Plan, t: int, i: int,
                   kind: TaskKind) -> float:
    """Effective speed of DP replica i (bottleneck tasklet).

    Generation is HBM-bandwidth bound (C_hbm dominates decode), so its
    replicas are balanced by HBM bandwidth; training/inference by TFLOPS."""
    devs = plan.assignment[t][i].reshape(-1)
    if kind == TaskKind.GEN:
        return min(topo.devices[int(d)].spec.hbm_gbps for d in devs)
    return min(topo.devices[int(d)].spec.fp16_tflops for d in devs)


def _stage_speed(topo: Topology, plan: Plan, t: int, j: int) -> float:
    dp, pp, tp = plan.parallel[t]
    speeds = []
    for i in range(dp):
        for d in plan.assignment[t][i, j]:
            speeds.append(topo.devices[int(d)].spec.fp16_tflops)
    return min(speeds)


def balance_data(topo: Topology, wf: RLWorkflow, plan: Plan) -> Plan:
    """Set batch fractions proportional to replica speed (GEN + TRAIN)."""
    fractions = dict(plan.batch_fraction)
    for t in range(wf.n_tasks):
        kind = wf.task(t).kind
        if kind not in (TaskKind.GEN, TaskKind.TRAIN, TaskKind.INF):
            continue
        dp = plan.parallel[t][0]
        if dp == 1:
            continue
        speeds = np.array([_replica_speed(topo, plan, t, i, kind)
                           for i in range(dp)], float)
        frac = speeds / speeds.sum()
        fractions[t] = tuple(float(f) for f in frac)
    return dataclasses.replace(plan, batch_fraction=fractions)


def balance_layers(topo: Topology, wf: RLWorkflow, plan: Plan) -> Plan:
    """Apportion layers per pipeline stage ∝ stage speed (Hamilton)."""
    layers = dict(plan.layers_per_stage)
    for t in range(wf.n_tasks):
        dp, pp, tp = plan.parallel[t]
        if pp == 1:
            continue
        nl = wf.task(t).model.n_layers
        speeds = np.array([_stage_speed(topo, plan, t, j)
                           for j in range(pp)], float)
        quota = speeds / speeds.sum() * nl
        alloc = np.maximum(np.floor(quota).astype(int), 1)
        while alloc.sum() > nl:
            alloc[int(np.argmax(alloc))] -= 1
        while alloc.sum() < nl:
            alloc[int(np.argmax(quota - alloc))] += 1
        layers[t] = tuple(int(a) for a in alloc)
    return dataclasses.replace(plan, layers_per_stage=layers)


def balance(topo: Topology, wf: RLWorkflow, plan: Plan,
            data: bool = True, layer: bool = True,
            guard: bool = True) -> Plan:
    """Apply both strategies; with `guard`, keep each only if the cost
    model confirms it does not regress (the knob-gating a real deployment
    would do)."""
    if not guard:
        if data:
            plan = balance_data(topo, wf, plan)
        if layer:
            plan = balance_layers(topo, wf, plan)
        return plan
    from repro.core.costmodel import CostModel
    cm = CostModel(topo, wf)
    cand = plan
    if data:
        cand = balance_data(topo, wf, cand)
    if layer:
        cand = balance_layers(topo, wf, cand)
    if cand is plan:
        return plan
    return cand if cm.cost(cand) <= cm.cost(plan) else plan
