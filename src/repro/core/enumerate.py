"""Search-space enumeration for the multi-level framework (§3.2).

Level 1: task groupings — set partitions of the task set (Bell number B_T).
Level 2: GPU group sizes — compositions of N into |grouping| positive parts;
         candidates are generated load-proportionally + perturbations.
Level 4: intra-model parallelizations — (dp, pp, tp) with dp*pp*tp <= n_t.
Plan construction helpers turn (grouping, sizes, device order, par) into a
full Plan with contiguous tasklet mapping (Level 3/5 defaults the EA mutates).
"""
from __future__ import annotations

import itertools
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.plan import Plan, TaskGroup, feasible_parallelizations
from repro.core.topology import Topology
from repro.core.workflow import RLWorkflow, TaskKind
from repro.core.costmodel import flops_per_layer


def set_partitions(items: Sequence[int]) -> List[Tuple[Tuple[int, ...], ...]]:
    """All set partitions (B_|items| of them), each sorted canonically."""
    items = list(items)
    if not items:
        return [()]
    first, rest = items[0], items[1:]
    out = []
    for part in set_partitions(rest):
        # put `first` into each existing block, or into its own block
        for i in range(len(part)):
            blocks = [tuple(sorted((first,) + part[i])) if j == i else part[j]
                      for j in range(len(part))]
            out.append(tuple(sorted(blocks)))
        out.append(tuple(sorted(part + ((first,),))))
    return sorted(set(out))


def task_groupings(wf: RLWorkflow) -> List[Tuple[Tuple[int, ...], ...]]:
    return set_partitions(range(wf.n_tasks))


def priority_groupings(wf: RLWorkflow) -> List[Tuple[Tuple[int, ...], ...]]:
    """Canonical groupings worth always trying first: colocate-all,
    fully-disaggregated, gen|rest (StreamRL's), by-kind, train|rest."""
    all_t = tuple(range(wf.n_tasks))
    gen = tuple(t for t in all_t if wf.task(t).kind == TaskKind.GEN)
    inf = tuple(t for t in all_t if wf.task(t).kind == TaskKind.INF)
    train = tuple(t for t in all_t if wf.task(t).kind == TaskKind.TRAIN)
    non_gen = tuple(t for t in all_t if t not in gen)
    non_train = tuple(t for t in all_t if t not in train)
    cands = [
        (all_t,),
        tuple((t,) for t in all_t),
        tuple(sorted((gen, non_gen))),
        tuple(sorted(g for g in (gen, inf, train) if g)),
        tuple(sorted((train, non_train))),
    ]
    out = []
    for c in cands:
        c = tuple(sorted(tuple(sorted(b)) for b in c if b))
        if c not in out:
            out.append(c)
    return out


def full_group_factorizations(n: int, n_layers: int,
                              max_tp: int = 8) -> List[Tuple[int, int, int]]:
    """(dp, pp, tp) with dp*pp*tp == n exactly (every device used)."""
    out = []
    for tp in (1, 2, 4, 8):
        if tp > max_tp or n % tp:
            continue
        rest = n // tp
        for pp in range(1, min(n_layers, rest) + 1):
            if rest % pp:
                continue
            out.append((rest // pp, pp, tp))
    return out


def task_load(wf: RLWorkflow, tasks: Sequence[int]) -> float:
    """Relative FLOP load of a set of tasks (drives proportional sizing)."""
    tot = 0.0
    for t in tasks:
        task = wf.task(t)
        seq = wf.seq_in if task.kind == TaskKind.GEN \
            else wf.seq_in + wf.seq_out
        f = flops_per_layer(task, seq) * task.model.n_layers \
            * wf.samples_per_iter
        if task.kind == TaskKind.TRAIN:
            f *= 3
        if task.kind == TaskKind.GEN:
            f *= 3  # decode passes dominate wall-clock despite low FLOPs
        tot += f
    return tot


def proportional_sizes(wf: RLWorkflow, grouping, n_devices: int) -> List[int]:
    loads = np.array([task_load(wf, g) for g in grouping], float)
    raw = loads / loads.sum() * n_devices
    sizes = np.maximum(np.floor(raw).astype(int), 1)
    while sizes.sum() > n_devices:
        sizes[int(np.argmax(sizes))] -= 1
    while sizes.sum() < n_devices:
        sizes[int(np.argmax(raw - sizes))] += 1
    return sizes.tolist()


def candidate_group_sizes(wf: RLWorkflow, grouping, n_devices: int,
                          max_candidates: int = 16,
                          seed: int = 0) -> List[Tuple[int, ...]]:
    """Level-2 candidates: proportional + perturbations + random."""
    G = len(grouping)
    if G > n_devices:
        # more groups than devices: no composition of n_devices into G
        # positive parts exists (small pools after a fleet shrink)
        return []
    rng = np.random.default_rng(seed)
    base = proportional_sizes(wf, grouping, n_devices)
    cands = {tuple(base)}
    # single-device transfers between group pairs at several magnitudes
    for delta in (1, 2, 4, 8):
        for a in range(G):
            for b in range(G):
                if a == b or base[a] - delta < 1:
                    continue
                s = list(base)
                s[a] -= delta
                s[b] += delta
                cands.add(tuple(s))
    # random compositions
    tries = 0
    while len(cands) < max_candidates * 2 and tries < 200:
        tries += 1
        cuts = sorted(rng.choice(np.arange(1, n_devices), G - 1,
                                 replace=False)) if G > 1 else []
        sizes = np.diff([0] + list(cuts) + [n_devices])
        if (sizes >= 1).all():
            cands.add(tuple(int(x) for x in sizes))
    ordered = sorted(cands, key=lambda s: sum(
        abs(x - y) for x, y in zip(s, base)))
    return ordered[:max_candidates]


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------

def default_parallelization(topo: Topology, wf: RLWorkflow, t: int,
                            devices: Sequence[int],
                            max_tp: int = 8) -> Tuple[int, int, int]:
    """Heuristic (dp,pp,tp) for a task on a device set: use all devices,
    prefer TP within machines, PP only when memory demands it."""
    n = len(devices)
    task = wf.task(t)
    # max co-located devices on one machine within the set
    by_machine: Dict[int, int] = {}
    for d in devices:
        m = topo.devices[d].machine
        by_machine[m] = by_machine.get(m, 0) + 1
    tp = 1
    for cand in (8, 4, 2):
        if cand <= max_tp and cand <= max(by_machine.values()) \
                and n % cand == 0:
            tp = cand
            break
    # rough memory need per device at pp=1
    bytes_pp = task.model.total_weight_count * \
        (16 if task.kind == TaskKind.TRAIN else 2)
    if task.kind == TaskKind.GEN:
        from repro.core.plan import MAX_DECODE_WAVE
        m = task.model
        h_kv = (m.n_kv_heads * m.head_dim) if m.n_kv_heads else m.h1
        bytes_pp += 4 * m.n_layers * h_kv * (wf.seq_in + wf.seq_out) \
            * MAX_DECODE_WAVE
    mem_min = min(topo.mem(d) for d in devices)
    pp = 1
    while bytes_pp / (tp * pp) > 0.6 * mem_min and pp < task.model.n_layers:
        pp += 1
    while (n // tp) % pp != 0 and pp < n // tp:
        pp += 1
    if (n // tp) % pp != 0:
        pp = 1
    dp = max(n // (tp * pp), 1)
    return dp, pp, tp


def build_plan(topo: Topology, wf: RLWorkflow, grouping,
               sizes: Sequence[int], device_order: Sequence[int],
               parallel: Optional[Dict[int, Tuple[int, int, int]]] = None,
               tasklet_order: Optional[Dict[int, Sequence[int]]] = None,
               ) -> Plan:
    """Contiguous plan: device_order is split by sizes into groups; each
    task maps tasklets onto its group's devices in order (dp-major)."""
    groups = []
    off = 0
    dev_of_group = {}
    for gi, g in enumerate(grouping):
        devs = tuple(int(d) for d in device_order[off:off + sizes[gi]])
        off += sizes[gi]
        groups.append(TaskGroup(tuple(g), devs))
        dev_of_group[gi] = devs
    parallel = dict(parallel or {})
    assignment = {}
    for gi, g in enumerate(grouping):
        devs = dev_of_group[gi]
        for t in g:
            if t not in parallel:
                parallel[t] = default_parallelization(topo, wf, t, devs)
            dp, pp, tp = parallel[t]
            need = dp * pp * tp
            order = list(tasklet_order[t]) if tasklet_order and \
                t in tasklet_order else list(devs)
            if len(order) < need:
                # parallelization larger than group: shrink dp
                dp = max(len(order) // (pp * tp), 1)
                while dp * pp * tp > len(order):
                    if pp > 1:
                        pp -= 1
                    elif tp > 1:
                        tp //= 2
                    else:
                        dp = 1
                        break
                parallel[t] = (dp, pp, tp)
                need = dp * pp * tp
            assignment[t] = np.array(order[:need]).reshape(dp, pp, tp)
    return Plan(tuple(groups), parallel, assignment)
