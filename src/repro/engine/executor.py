"""Task-graph executor: runs the RL iteration as the plan dictates.

The engine walks ``wf.stages()`` (topological stages of the workflow
graph) and dispatches the registered per-task executors.  Within a stage,
tasks are partitioned into *lanes* by their plan task group: colocated
tasks (same GPU group) serialize in task order, disjoint groups run
concurrently (one thread per lane — jitted JAX computations release the
GIL, so disjoint submeshes genuinely overlap).

Every task execution is measured, and the measured durations are replayed
through the same device-availability logic as ``core.simulator.simulate``
— producing an ``Event`` timeline on the *plan's* device ids that shares
the ``Event``/``SimResult`` dataclasses with the simulator, so a Fig-7
style measured-vs-predicted comparison is ``compare_with_simulator()``.

Plan epochs (§6 online redeployment): everything the engine derives from
the scheduler's ``Plan`` lives in a swappable ``PlanContext``; a running
session swaps plans at an iteration boundary through ``apply_plan`` —
trainer/optimizer state is untouched, a weight-migration event priced by
``core.redeploy.transition_cost`` is replayed onto the timeline, and all
subsequent events carry the new plan epoch so steady-state estimates
never straddle a swap.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core import retry as retry_mod
from repro.core.costmodel import CostModel
from repro.core.plan import Plan, predicted_occupancy
from repro.core.simulator import Event, SimResult, simulate
from repro.core.topology import Topology
from repro.core.workflow import RLWorkflow, TaskKind
from repro.engine import tasks as tasks_mod
from repro.engine.pipeline import AsyncPipeline, sync_actor_weights
from repro.engine.placement import build_placement, fold_plan
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

# pseudo task id for the weight-migration event a plan swap replays onto
# the timeline (real workflow tasks are 0..n_tasks-1)
MIGRATION_TASK = -1


class TaskExecutionError(Exception):
    """A task executor failed beyond the engine's retry budget (or
    permanently).  Carries enough context for the elastic controller to
    escalate: the task, its assigned plan devices, and — for permanent
    faults — the device ids presumed dead."""

    def __init__(self, task: int, name: str, devices, attempts: int,
                 cause: BaseException, *, permanent: bool = False,
                 dead_devices=()):
        super().__init__(
            f"task {task} ({name}) failed "
            f"{'permanently' if permanent else f'after {attempts} attempts'}"
            f": {cause!r}")
        self.task = task
        self.task_name = name
        self.devices = tuple(int(d) for d in devices)
        self.attempts = attempts
        self.cause = cause
        self.permanent = permanent
        self.dead_devices = tuple(int(d) for d in dead_devices)


@dataclasses.dataclass
class EngineResult:
    metrics: Dict[str, float]
    events: List[Event]          # this iteration's replayed timeline
    iteration: int
    epoch: int = 0               # plan epoch the iteration executed under


@dataclasses.dataclass
class PlanContext:
    """Everything the engine derives from one ``Plan`` — the swappable
    unit of the plan-epoch model.  ``apply_plan`` builds a fresh context;
    nothing in it survives a swap except through the explicit transition
    (device availability is re-seeded at the migration end time)."""
    epoch: int
    plan: Plan
    topo: Optional[Topology]
    placements: Dict[int, Any]            # task -> TaskPlacement
    gen_task: int
    actor_train: int
    dev_free: Dict[int, float]            # plan-device availability (replay)
    start_iter: int = 0                   # first engine iteration in epoch
    folding: Any = None                   # placement.DeviceFolding
    multidev: bool = False                # placements span > 1 real device


class Engine:
    def __init__(self, wf: RLWorkflow, plan: Plan, state,
                 *, topo: Optional[Topology] = None,
                 asynchronous: Optional[bool] = None,
                 devices: Optional[Sequence] = None,
                 overlap: Optional[bool] = None):
        self.wf = wf
        self.state = state
        self._devices = list(devices) if devices is not None else None
        if asynchronous is None:
            asynchronous = not wf.synchronous
        self.pipeline = AsyncPipeline(asynchronous)
        # gen/train wall-clock overlap: None = auto (on when async and
        # the GEN group's folded devices are disjoint from every other
        # task's), False = force the serialized stage walk
        self._overlap_opt = overlap
        self.ctx = self._make_context(plan, topo, epoch=0, start_iter=0)
        self.ctx_history: List[PlanContext] = []   # retired epochs, oldest first
        # set when a topology drift could not be adopted because the
        # incumbent plan references dropped devices (predictions keep
        # pricing the old environment until a feasible plan lands)
        self.topology_stale = False
        self._done_at: Dict[tuple, float] = {}
        self._sync_done = 0.0
        self._iter = 0
        self._samples = 0
        self.timeline: List[Event] = []
        # decode-wave sub-timeline (genserve): one start/end Event pair
        # per wave round, annotated with measured slot occupancy.  Kept
        # separate from `timeline` so task-level measured-vs-simulated
        # parity is unaffected; times are host-monotonic relative to
        # engine construction.
        self.wave_timeline: List[Event] = []
        self._wave_slot_steps = 0
        self._wave_decode_steps = 0
        self._wave_pred_sum = 0.0
        self._wave_calls = 0
        self._t0 = time.monotonic()
        # measured weight-sync wall-clock per trained iteration — the
        # sample obs.calibrate fits the reshard/sync coefficient from
        self.sync_durations: List[float] = []
        # optional reactive-drift hook (obs.calibrate.DivergenceMonitor)
        self.divergence_monitor = None
        self._div_cost_model = None
        self._pred_cache: Optional[tuple] = None
        # fault hardening: optional injector (repro.faults), bounded
        # retry for transient task failures, calibrated per-task
        # deadlines (predicted × slack; post-hoc — jitted computations
        # cannot be preempted, so a miss is a signal, not an abort)
        self.fault_injector = None
        self.task_retry = retry_mod.RetryPolicy(max_attempts=3,
                                                base_delay_s=0.02)
        self._retry_sleep = time.sleep
        self._deadline_slack: Optional[float] = None
        self._deadline_cm = None
        self._deadline_cache: Optional[tuple] = None

    # -- plan context ---------------------------------------------------
    def _make_context(self, plan: Plan, topo: Optional[Topology],
                      epoch: int, start_iter: int) -> PlanContext:
        missing = set(range(self.wf.n_tasks)) - set(plan.parallel)
        if missing:
            raise ValueError(f"plan does not cover workflow tasks {missing}")
        devices = list(self._devices) if self._devices is not None \
            else jax.devices()
        folding = fold_plan(plan, devices)
        placements = {t: build_placement(plan, t, devices, folding)
                      for t in range(self.wf.n_tasks)}
        gen_task = next(t for t in range(self.wf.n_tasks)
                        if self.wf.task(t).kind == TaskKind.GEN)
        actor_train = next(
            t for t in range(self.wf.n_tasks)
            if self.wf.task(t).kind == TaskKind.TRAIN
            and self.wf.task(t).name.startswith("actor"))
        dev_free = {int(d): 0.0 for t in range(self.wf.n_tasks)
                    for d in plan.assignment[t].reshape(-1)}
        multidev = len({id(d) for pl in placements.values()
                        for d in pl.local_devices}) > 1
        # commit each task's state onto its owning placement (no-op on
        # single-device hosts; rebuilds on every elastic plan swap)
        install = getattr(self.state, "install_placements", None)
        if install is not None:
            install(placements, self.wf)
        # per-placement gauges: which device group / realized tp each
        # task actually got, and whether folding collided at all
        obs_metrics.gauge("placement.collisions").set(
            float(folding.n_collisions))
        for t, pl in placements.items():
            name = self.wf.task(t).name
            obs_metrics.gauge(f"placement.devices.{name}").set(
                float(pl.n_devices))
            obs_metrics.gauge(f"placement.tp_realized.{name}").set(
                float(pl.tp_eff))
        return PlanContext(epoch, plan, topo, placements, gen_task,
                           actor_train, dev_free, start_iter,
                           folding=folding, multidev=multidev)

    # back-compat accessors: the live context is authoritative
    @property
    def plan(self) -> Plan:
        return self.ctx.plan

    @property
    def topo(self) -> Optional[Topology]:
        return self.ctx.topo

    @property
    def placements(self) -> Dict[int, Any]:
        return self.ctx.placements

    @property
    def epoch(self) -> int:
        return self.ctx.epoch

    @property
    def _gen_task(self) -> int:
        return self.ctx.gen_task

    @property
    def _actor_train(self) -> int:
        return self.ctx.actor_train

    @property
    def _dev_free(self) -> Dict[int, float]:
        return self.ctx.dev_free

    def update_topology(self, topo: Topology) -> None:
        """Adopt a drifted topology *without* swapping plans (the elastic
        controller stays on the incumbent): predictions now price the new
        environment; no epoch bump, no migration, no placement rebuild.

        If the incumbent plan no longer fits the drifted topology (a
        device drop re-indexed the survivors and ``reschedule`` found no
        feasible challenger), the old topology is kept for prediction —
        simulating the incumbent on the shrunken device list would index
        out of range — and the epoch is marked unpredictable
        (``topology_stale``): ``epoch_report`` rows show it explicitly
        instead of crashing, and ``compare_with_simulator`` keeps
        pricing the environment the plan actually describes."""
        if self.ctx.plan is not None and topo is not None \
                and not self.ctx.plan.fits_topology(topo):
            self.topology_stale = True
            return
        self.topology_stale = False
        self.ctx = dataclasses.replace(self.ctx, topo=topo)

    def apply_plan(self, plan: Plan, *, topo: Optional[Topology] = None,
                   carry_pending: bool = True) -> Dict[str, float]:
        """Swap the execution plan at an iteration boundary (§6 online
        redeployment) without losing trainer/optimizer state.

        The swap replays a weight-migration event priced by
        ``core.redeploy.transition_cost`` (old plan -> new plan on the
        new topology): it starts once every device of the outgoing plan
        is quiesced, occupies the transition window, and re-seeds the new
        plan's device availability at its end — so the replayed timeline
        accounts for the §6 "applied immediately after checkpointing"
        pause.  Generation additionally waits for the migrated weights
        the way it waits for a weight sync.

        The async one-step-staleness invariant is preserved explicitly:
        with ``carry_pending`` (default) the in-flight rollout bundle
        generated under the old plan is carried across the swap and
        trained next iteration (still exactly one sync behind); with
        ``carry_pending=False`` the bundle is drained and the pipeline
        refills, making the first post-swap iteration a fill iteration.

        Returns transition telemetry (seconds, epoch, migration window).
        """
        from repro.core import redeploy
        old = self.ctx
        with obs_trace.span("engine.swap", iteration=self._iter,
                            epoch=old.epoch + 1) as sp:
            topo = topo if topo is not None else old.topo
            trans_s = 0.0
            if topo is not None:
                trans_s = redeploy.transition_cost(topo, self.wf, old.plan,
                                                   plan, topo_old=old.topo)
            # migration window on the replay clock: begins when the
            # outgoing plan's devices are all idle (iteration boundary +
            # in-flight sync)
            t0 = max(list(old.dev_free.values()) + [self._sync_done])
            t1 = t0 + trans_s
            new_epoch = old.epoch + 1
            # the replay window [t0, t1) is priced, not measured — the
            # host observes the swap as one instant
            wall = time.monotonic() - self._t0
            self.timeline.append(Event(t0, "start", self._iter,
                                       MIGRATION_TASK, epoch=new_epoch,
                                       t_wall=wall, span=sp.id or None))
            self.timeline.append(Event(t1, "end", self._iter,
                                       MIGRATION_TASK, epoch=new_epoch,
                                       t_wall=wall, span=sp.id or None))
            ctx = self._make_context(plan, topo, epoch=new_epoch,
                                     start_iter=self._iter)
            for d in ctx.dev_free:
                ctx.dev_free[d] = t1
            self._sync_done = max(self._sync_done, t1)
            dropped = 0
            if not carry_pending:
                dropped = int(self.pipeline.drain() is not None)
            self.ctx_history.append(old)
            self.ctx = ctx
            self.topology_stale = False  # the new plan fits its topology
            sp.set("transition_cost_s", trans_s)
        obs_metrics.counter("engine.swaps").inc()
        obs_metrics.gauge("engine.plan_epoch").set(new_epoch)
        return {"transition_cost_s": trans_s, "epoch": float(new_epoch),
                "migration_start_s": t0, "migration_end_s": t1,
                "dropped_bundles": float(dropped)}

    # -- stage dispatch ------------------------------------------------
    def _lanes(self, stage: Sequence[int]) -> List[List[int]]:
        """Partition a stage's tasks by plan task group (colocated tasks
        share a lane and serialize; lanes run concurrently)."""
        lanes: Dict[tuple, List[int]] = {}
        for t in sorted(stage):
            lanes.setdefault(self.plan.group_of(t).devices, []).append(t)
        return list(lanes.values())

    def _run_stage(self, stage: Sequence[int], bb: Dict[str, Any],
                   durations: Dict[int, float],
                   meta: Dict[int, tuple]) -> None:
        inj = self.fault_injector

        def attempt_task(t, task, fn, attempt):
            if inj is not None:
                inj.before_task(t, attempt)
            out = fn(self.state, bb, self.placements[t])
            if out is not None:
                jax.block_until_ready(out)

        def run_lane(lane: List[int]) -> None:
            for t in lane:
                task = self.wf.task(t)
                fn = tasks_mod.executor_for(task)
                devs = [int(d) for d in self.plan.assignment[t].reshape(-1)]
                pl = self.placements[t]
                mesh_attr = "x".join(str(s) for s in pl.mesh_shape) + "@" \
                    + ",".join(str(getattr(d, "id", d))
                               for d in pl.local_devices)
                with obs_trace.span(f"task.{task.name}", task=t,
                                    iteration=self._iter,
                                    epoch=self.ctx.epoch,
                                    mesh=mesh_attr) as sp:
                    t0 = time.monotonic()
                    try:
                        retry_mod.retry_call(
                            lambda a, t=t, task=task, fn=fn:
                                attempt_task(t, task, fn, a),
                            policy=self.task_retry,
                            on_retry=lambda a, e, t=t, sp=sp:
                                self._on_task_retry(t, a, e, sp),
                            sleep=self._retry_sleep)
                    except retry_mod.RetryExhausted as e:
                        obs_metrics.counter("engine.task_failures").inc()
                        sp.set("failed", True)
                        raise TaskExecutionError(
                            t, task.name, devs, e.attempts, e.last) from e
                    except retry_mod.PermanentError as e:
                        obs_metrics.counter("engine.task_failures").inc()
                        sp.set("failed", True)
                        raise TaskExecutionError(
                            t, task.name, devs, 1, e, permanent=True,
                            dead_devices=getattr(e, "devices", ())) from e
                    t1 = time.monotonic()
                dur = t1 - t0
                if inj is not None:
                    # undeclared degradations stretch the replay clock,
                    # never the host clock — this is what the divergence
                    # monitor "measures"
                    dur *= inj.dilation(t)
                durations[t] = dur
                meta[t] = (t0 - self._t0, sp.id)
                deadline = self._task_deadline(t)
                if deadline is not None and dur > deadline:
                    obs_metrics.counter("engine.deadline_misses").inc()
                    sp.set("deadline_s", deadline)
                    sp.set("deadline_exceeded", True)

        lanes = self._lanes(stage)
        if len(lanes) == 1:
            run_lane(lanes[0])
            return
        with ThreadPoolExecutor(max_workers=len(lanes)) as pool:
            for f in [pool.submit(run_lane, lane) for lane in lanes]:
                f.result()

    # -- measured-timeline replay --------------------------------------
    def _replay_iteration(self, durations: Dict[int, float],
                          sync_dur: float, trained: bool,
                          meta: Optional[Dict[int, tuple]] = None
                          ) -> List[Event]:
        """Replay measured durations through the simulator's scheduling
        rules on the plan's device ids (same event ordering semantics).
        ``meta`` carries each task's host wall-clock start (relative to
        engine construction) and obs.trace span id, stamped onto the
        replayed events for calibration/trace correlation."""
        it = self._iter
        epoch = self.ctx.epoch
        events: List[Event] = []
        for t in sorted(durations):
            task = self.wf.task(t)
            dep_ready = max([self._done_at.get((it, d), 0.0)
                             for d in task.depends_on], default=0.0)
            if task.kind == TaskKind.GEN:
                dep_ready = max(dep_ready, self._sync_done)
            devs = [int(d) for d in self.plan.assignment[t].reshape(-1)]
            start = max([dep_ready] + [self._dev_free[d] for d in devs])
            end = start + durations[t]
            for d in devs:
                self._dev_free[d] = end
            wall0, sid = (meta or {}).get(t, (None, 0))
            wall1 = wall0 + durations[t] if wall0 is not None else None
            # honest overlap accounting: tag events of tasks whose plan
            # group folded onto a device shared with another group
            coll = True if self.placements[t].collision else None
            events.append(Event(start, "start", it, t, epoch=epoch,
                                t_wall=wall0, span=sid or None,
                                collision=coll))
            events.append(Event(end, "end", it, t, epoch=epoch,
                                t_wall=wall1, span=sid or None,
                                collision=coll))
            self._done_at[(it, t)] = end
        if trained:
            train_end = self._done_at[(it, self._actor_train)]
            self._sync_done = train_end + sync_dur
            if self.wf.synchronous:
                for d in self._dev_free:
                    self._dev_free[d] = max(self._dev_free[d],
                                            self._sync_done)
            else:
                for d in self.plan.assignment[self._gen_task].reshape(-1):
                    d = int(d)
                    self._dev_free[d] = max(self._dev_free[d],
                                            self._sync_done)
        events.sort(key=lambda e: e.time)
        self.timeline.extend(events)
        self._iter += 1
        return events

    # -- fault hardening -------------------------------------------------
    def attach_fault_injector(self, injector) -> None:
        """Bind a ``repro.faults.FaultInjector`` to this engine: it is
        clocked at the top of every ``run_iteration``, consulted before
        each task attempt (raising injected faults), and its dilation
        stretches measured durations on the replay timeline."""
        self.fault_injector = injector.bind(self)

    def set_task_retry(self, policy, *, sleep=None) -> None:
        """Override the transient-failure retry policy (and, for tests,
        the backoff sleep)."""
        self.task_retry = policy
        if sleep is not None:
            self._retry_sleep = sleep

    def set_task_deadlines(self, cost_model=None, *,
                           slack: float = 3.0) -> None:
        """Arm per-task deadlines at predicted × ``slack``.  Only
        meaningful with a calibrated model (``obs.calibrate.Calibration``
        or a ``CostModel`` built from one) — the uncalibrated analytical
        model is orders of magnitude off wall clock.  Misses are counted
        (``engine.deadline_misses``) and stamped on the task span; the
        task is never aborted (jitted computations cannot be preempted)."""
        self._deadline_slack = slack
        self._deadline_cm = cost_model
        self._deadline_cache = None

    def _task_deadline(self, t: int) -> Optional[float]:
        if self._deadline_slack is None or self.topo is None \
                or self.topology_stale:
            return None
        if self._deadline_cache is None \
                or self._deadline_cache[0] != self.ctx.epoch:
            src = self._deadline_cm
            if src is None:
                return None
            cm = src.cost_model(self.topo, self.wf) \
                if hasattr(src, "cost_model") else src
            self._deadline_cache = (
                self.ctx.epoch,
                {tt: cm.task_cost(self.plan, tt).total
                 * self._deadline_slack
                 for tt in range(self.wf.n_tasks)})
        return self._deadline_cache[1].get(t)

    def _on_task_retry(self, t: int, attempt: int,
                       exc: BaseException, sp) -> None:
        obs_metrics.counter("engine.task_retries").inc()
        sp.set("retries", attempt + 1)
        sp.set("retry_error", type(exc).__name__)

    # -- one iteration --------------------------------------------------
    def run_iteration(self, prompts, answers, rng) -> EngineResult:
        t_iter0 = time.monotonic()
        if self.fault_injector is not None:
            self.fault_injector.begin_iteration(self._iter)
        with obs_trace.span("engine.iteration", iteration=self._iter,
                            epoch=self.ctx.epoch):
            result = self._run_iteration(prompts, answers, rng)
        obs_metrics.histogram("engine.iter_wall_s").observe(
            time.monotonic() - t_iter0)
        obs_metrics.gauge("engine.plan_epoch").set(self.ctx.epoch)
        if self.pipeline.records:
            rec = self.pipeline.records[-1]
            obs_metrics.gauge("engine.staleness").set(
                rec.weight_version - rec.gen_version)
        self._observe_divergence(result)
        return result

    def overlap_active(self) -> bool:
        """Whether this epoch runs generation wall-clock concurrent with
        the inference/training stages: asynchronous pipeline and the GEN
        group's folded devices disjoint from every other task's (which a
        collision-free group-aware folding guarantees for disjoint plan
        groups).  With shared devices the serialized stage walk is the
        honest execution — threads would only fake overlap."""
        if self._overlap_opt is False or not self.pipeline.asynchronous:
            return False
        ctx = self.ctx
        gen_devs = {id(d)
                    for d in ctx.placements[ctx.gen_task].local_devices}
        rest = {id(d) for t, pl in ctx.placements.items()
                if t != ctx.gen_task for d in pl.local_devices}
        return bool(rest) and not (gen_devs & rest)

    def _run_iteration(self, prompts, answers, rng) -> EngineResult:
        bb: Dict[str, Any] = {"lock": threading.Lock(), "metrics": {}}
        if self.fault_injector is not None:
            bb["fault"] = self.fault_injector
        if self.ctx.multidev:
            # executors must pull cross-mesh tensors to their own devices
            bb["multidev"] = True
        bb.update(self.state.prepare_inputs(prompts, answers, rng))
        self._samples = int(bb["prompts_rep"].shape[0])
        durations: Dict[int, float] = {}
        meta: Dict[int, tuple] = {}
        before_stage = getattr(self.state, "before_stage", None)
        if self.overlap_active():
            bundle = self._run_stages_overlapped(bb, durations, meta,
                                                 before_stage)
            if bundle is None:
                events = self._replay_iteration(durations, 0.0,
                                                trained=False, meta=meta)
                return EngineResult(self.state.fill_metrics(), events,
                                    self._iter - 1, self.ctx.epoch)
        else:
            for stage in self.wf.stages():
                has_gen = any(self.wf.task(t).kind == TaskKind.GEN
                              for t in stage)
                if before_stage is not None:
                    # shared cross-task prep (e.g. advantages) runs
                    # outside the per-task timers so lane measurements
                    # stay honest
                    before_stage([self.wf.task(t) for t in stage], bb)
                with obs_trace.span("engine.stage", tasks=len(stage)):
                    self._run_stage(stage, bb, durations, meta)
                if has_gen:
                    self._record_gen_stats(bb)
                    bundle = self.pipeline.push(bb.pop("fresh"))
                    if bundle is None:
                        # pipeline fill: nothing to train on yet, no sync
                        events = self._replay_iteration(durations, 0.0,
                                                        trained=False,
                                                        meta=meta)
                        return EngineResult(self.state.fill_metrics(),
                                            events, self._iter - 1,
                                            self.ctx.epoch)
                    bb["bundle"] = bundle
                    self.pipeline.record(self._iter, bundle,
                                         self.state.weight_version)

        t0 = time.monotonic()
        with obs_trace.span("engine.sync", iteration=self._iter):
            nbytes = sync_actor_weights(
                self.state, self.placements[self._gen_task],
                self.placements[self._actor_train])
            jax.block_until_ready(self.state.gen_params)
        sync_dur = time.monotonic() - t0
        self.sync_durations.append(sync_dur)
        obs_metrics.histogram("engine.sync_s").observe(sync_dur)
        metrics = dict(bb["metrics"])
        metrics["sync_gb"] = nbytes / 1e9
        events = self._replay_iteration(durations, sync_dur, trained=True,
                                        meta=meta)
        return EngineResult(metrics, events, self._iter - 1, self.ctx.epoch)

    def _run_stages_overlapped(self, bb: Dict[str, Any],
                               durations: Dict[int, float],
                               meta: Dict[int, tuple],
                               before_stage) -> Optional[Dict[str, Any]]:
        """Disjoint-group execution: the GEN lane decodes iteration t+1's
        rollouts on its own device group while the INF/TRAIN stages
        consume iteration t's bundle on theirs — the async pipeline's
        one-step staleness realized as wall-clock overlap, not just on
        the replay timeline.  Returns the bundle trained on (None on the
        pipeline-fill iteration).

        Safe because overlap_active() guarantees disjoint device sets and
        the weight sync at the end of the previous iteration gave the gen
        group its own committed copy of the weights — the training lane
        updating ``state.actor`` never touches what the gen lane reads."""
        stages = self.wf.stages()
        gen_tasks = [t for stage in stages for t in stage
                     if self.wf.task(t).kind == TaskKind.GEN]
        rest_stages = [[t for t in stage
                        if self.wf.task(t).kind != TaskKind.GEN]
                       for stage in stages]
        # take the pending bundle *before* generation pushes a new one
        bundle = self.pipeline.drain()

        def gen_lane():
            with obs_trace.span("engine.stage", tasks=len(gen_tasks),
                                overlapped=True):
                self._run_stage(gen_tasks, bb, durations, meta)

        def train_lanes():
            if bundle is None:
                return
            bb["bundle"] = bundle
            self.pipeline.record(self._iter, bundle,
                                 self.state.weight_version)
            for stage in rest_stages:
                if not stage:
                    continue
                if before_stage is not None:
                    before_stage([self.wf.task(t) for t in stage], bb)
                with obs_trace.span("engine.stage", tasks=len(stage),
                                    overlapped=True):
                    self._run_stage(stage, bb, durations, meta)

        with ThreadPoolExecutor(max_workers=2) as pool:
            futs = [pool.submit(gen_lane), pool.submit(train_lanes)]
            for f in futs:
                f.result()
        self._record_gen_stats(bb)
        self.pipeline.push(bb.pop("fresh"))
        return bundle

    # -- reactive drift hook ---------------------------------------------
    def attach_divergence_monitor(self, monitor,
                                  cost_model=None) -> None:
        """Feed every iteration's measured vs predicted task durations
        into an ``obs.calibrate.DivergenceMonitor``.  ``cost_model`` may
        be a ``CostModel`` instance or an ``obs.calibrate.Calibration``
        (rebuilt against the live topology at each plan epoch); omitted,
        the uncalibrated analytical model is used — only meaningful if
        the monitor's threshold accounts for the wall-clock offset."""
        self.divergence_monitor = monitor
        self._div_cost_model = cost_model
        self._pred_cache = None

    def _observe_divergence(self, result: EngineResult) -> None:
        mon = self.divergence_monitor
        if mon is None or self.topo is None or self.topology_stale:
            return
        if self._pred_cache is None \
                or self._pred_cache[0] != self.ctx.epoch:
            src = self._div_cost_model
            if src is None:
                cm = CostModel(self.topo, self.wf)
            elif hasattr(src, "cost_model"):     # a Calibration
                cm = src.cost_model(self.topo, self.wf)
            else:
                cm = src
            self._pred_cache = (
                self.ctx.epoch,
                {t: cm.task_cost(self.plan, t).total
                 for t in range(self.wf.n_tasks)})
        starts: Dict[int, float] = {}
        measured: Dict[int, float] = {}
        for e in result.events:
            if e.task < 0:
                continue
            if e.kind == "start":
                starts[e.task] = e.time
            elif e.kind == "end" and e.task in starts:
                measured[e.task] = e.time - starts.pop(e.task)
        mon.observe_iteration(measured, self._pred_cache[1])

    # -- decode-wave telemetry -------------------------------------------
    def _record_gen_stats(self, bb: Dict[str, Any]) -> None:
        """Fold the GEN executor's wave stats into metrics + the per-wave
        Event sub-timeline (each wave round annotated with its measured
        slot occupancy, comparable against the cost model's decode_wave
        prediction)."""
        stats = bb.pop("gen_stats", None)
        if stats is None:
            return
        # busy accounting: decode slot-steps plus prefill-chunk slot-
        # rounds (chunked admission) over all device rounds — for the
        # one-shot admission path prefill_slot_steps is 0 and this
        # reduces to the pure decode occupancy
        self._wave_slot_steps += int(stats["slot_steps"]) \
            + int(stats.get("prefill_slot_steps", 0))
        self._wave_decode_steps += int(stats["decode_steps"])
        # ideal occupancy for the batch this executor actually ran (the
        # engine folds all plan replicas onto the host, so the per-call
        # request count — not the cost model's per-replica batch — is the
        # like-for-like prediction baseline); chunked admission charges
        # each request its prefill rounds instead of free admission
        self._wave_pred_sum += predicted_occupancy(
            stats["admitted"], wave=stats["wave"],
            prefill_rounds=stats.get("prefill_rounds_per_req", 0.0),
            max_new_tokens=stats.get("max_new_tokens"))
        self._wave_calls += 1
        bb["metrics"].update({
            "gen_wave": float(stats["wave"]),
            "gen_wave_occupancy": float(stats["mean_occupancy"]),
            "gen_decode_steps": float(stats["decode_steps"]),
        })
        if stats.get("prefill_rounds", 0):
            bb["metrics"]["gen_prefill_rounds"] = \
                float(stats["prefill_rounds"])
            bb["metrics"]["gen_busy_occupancy"] = \
                float(stats["busy_occupancy"])
        rounds = stats.get("rounds") or []
        if not rounds and stats["decode_steps"]:
            # single-wave fast path: one synthesized zero-length wave
            # round stamped now, keeping the sub-timeline monotonic
            now = time.monotonic()
            rounds = [(now, now, stats["mean_occupancy"], 0)]
        for w, (t0, t1, occ, _adm) in enumerate(rounds):
            self.wave_timeline.append(Event(
                t0 - self._t0, "start", self._iter, self._gen_task,
                wave=w, occupancy=occ, epoch=self.ctx.epoch))
            self.wave_timeline.append(Event(
                t1 - self._t0, "end", self._iter, self._gen_task,
                wave=w, occupancy=occ, epoch=self.ctx.epoch))

    def wave_occupancy_summary(self) -> Dict[str, float]:
        """Measured mean *busy* slot occupancy (over all iterations) vs
        the ideal occupancy for the batches the engine actually ran —
        under chunked admission both sides count prefill-chunk rounds
        (a slot mid-prefill is busy, and admission is priced, not free;
        with one-shot admission both reduce to pure decode occupancy).

        ``predicted_occupancy`` is the engine-view ideal (whole rollout
        batch, since the engine folds every plan replica onto the host);
        ``predicted_occupancy_plan`` is the cost model's per-replica
        figure for the GEN task on the reference pool — the two differ
        exactly when the plan shards generation over dp > 1 replicas."""
        measured = self._wave_slot_steps / max(self._wave_decode_steps, 1)
        pred = self._wave_pred_sum / max(self._wave_calls, 1)
        out = {"measured_occupancy": measured,
               "measured_decode_steps": float(self._wave_decode_steps),
               "predicted_occupancy": pred,
               "ratio": measured / max(pred, 1e-9)}
        # honest overlap accounting: when folding collided, the lanes the
        # replay timeline shows as concurrent actually serialized on a
        # shared real device — flag it next to the occupancy figures
        folding = self.ctx.folding
        if folding is not None:
            out["folding_collisions"] = float(folding.n_collisions)
            out["overlap_honest"] = float(folding.n_collisions == 0)
        if self.topo is not None:
            cm = CostModel(self.topo, self.wf)
            out["predicted_occupancy_plan"] = \
                cm.gen_wave_occupancy(self.plan, self._gen_task)
        return out

    # -- measured vs predicted -------------------------------------------
    def _epoch_gen_starts(self, ctx: PlanContext) -> List[float]:
        return sorted(e.time for e in self.timeline
                      if e.task == ctx.gen_task and e.kind == "start"
                      and e.epoch == ctx.epoch)

    def measured_result(self) -> SimResult:
        """Measured timeline in the simulator's SimResult shape.

        The steady-state iteration time is derived from generation-start
        deltas *within the current plan epoch* — a swap between the last
        two generation starts would otherwise fold the migration window
        (and the old plan's cadence) into the estimate."""
        if not self.timeline:
            return SimResult(0.0, 0.0, 0.0, [])
        makespan = max(e.time for e in self.timeline)
        gen_starts = self._epoch_gen_starts(self.ctx)
        # epoch 0's first delta absorbs jit compilation, so demand one
        # extra start there; post-swap epochs run warm
        need = 3 if self.ctx.epoch == 0 else 2
        if len(gen_starts) >= need:
            iter_time = gen_starts[-1] - gen_starts[-2]
        else:
            # too few starts for a delta: average over the live epoch's
            # own span (post-migration), never over retired epochs or
            # the migration window
            span = [e.time for e in self.timeline
                    if e.epoch == self.ctx.epoch
                    and e.task != MIGRATION_TASK]
            epoch_iters = max(self._iter - self.ctx.start_iter, 1)
            if span:
                iter_time = (max(span) - min(span)) / epoch_iters
            else:
                iter_time = makespan / max(self._iter, 1)
        iter_time = max(iter_time, 1e-9)
        return SimResult(iter_time, makespan, self._samples / iter_time,
                         sorted(self.timeline, key=lambda e: e.time))

    def realized_plan(self) -> Plan:
        """The plan as the host actually executed it after folding: each
        task's parallelization shrinks to its folded device count — tp to
        ``gcd(tp, n)``, pp collapses to 1, dp takes the rest — and the
        assignment keeps one representative plan id per real device, so
        the cost model prices the submeshes that really ran.  Identical
        to ``plan`` when the host has every planned device.  Layer/batch
        splits are dropped (they describe the planned pp/dp)."""
        parallel: Dict[int, tuple] = {}
        assignment: Dict[int, np.ndarray] = {}
        for t, pl in self.placements.items():
            reps = list(pl.rep_plan_devices) or \
                [int(self.plan.assignment[t].reshape(-1)[0])]
            n = len(reps)
            tp_eff = math.gcd(pl.tp, n)
            parallel[t] = (n // tp_eff, 1, tp_eff)
            assignment[t] = np.array(reps, dtype=int).reshape(
                n // tp_eff, 1, tp_eff)
        return dataclasses.replace(self.plan, parallel=parallel,
                                   assignment=assignment,
                                   layers_per_stage={}, batch_fraction={})

    def compare_with_simulator(self, cost_model: Optional[CostModel] = None,
                               n_iterations: Optional[int] = None
                               ) -> Dict[str, float]:
        """Fig-7 style: measured iteration time vs the cost model's
        event-driven prediction for the same (wf, plan) on `topo` —
        plan-epoch aware, so both sides describe the *current* plan.

        When gcd-folding shrank a task's parallelization (the host has
        fewer devices than the plan assumed), the planned-plan prediction
        prices submeshes that never ran; the ``*_realized`` keys re-price
        against ``realized_plan()`` so the parity figure accounts for the
        realized tp/dp."""
        if self.topo is None:
            raise ValueError("engine was built without a Topology")
        epoch_iters = self._iter - self.ctx.start_iter
        n_it = n_iterations or max(epoch_iters, 4)
        sim = simulate(self.topo, self.wf, self.plan,
                       n_iterations=n_it, cost_model=cost_model)
        meas = self.measured_result()
        out = {"measured_iter_s": meas.iteration_time,
               "predicted_iter_s": sim.iteration_time,
               "ratio": meas.iteration_time / sim.iteration_time,
               "measured_makespan_s": meas.makespan,
               "predicted_makespan_s": sim.makespan,
               "epoch": float(self.ctx.epoch)}
        rplan = self.realized_plan()
        shrunk = any(rplan.parallel[t] != tuple(self.plan.parallel[t])
                     for t in rplan.parallel)
        out["tp_shrunk"] = float(shrunk)
        if shrunk and rplan.fits_topology(self.topo):
            rsim = simulate(self.topo, self.wf, rplan,
                            n_iterations=n_it, cost_model=cost_model)
            out["predicted_iter_realized_s"] = rsim.iteration_time
            out["ratio_realized"] = \
                meas.iteration_time / rsim.iteration_time
        else:
            out["predicted_iter_realized_s"] = sim.iteration_time
            out["ratio_realized"] = out["ratio"]
        return out

    def epoch_report(self, cost_model: Optional[CostModel] = None
                     ) -> List[Dict[str, float]]:
        """Per plan-epoch measured-vs-predicted iteration time: one row
        per epoch (retired and live), each measured from gen-start deltas
        strictly inside that epoch and predicted by simulating that
        epoch's own plan on that epoch's own topology."""
        rows = []
        for ctx in self.ctx_history + [self.ctx]:
            starts = self._epoch_gen_starts(ctx)
            measured = float("nan")
            if len(starts) >= 2:
                measured = starts[-1] - starts[-2]
            predicted = float("nan")
            if ctx.topo is not None and ctx.plan.fits_topology(ctx.topo):
                predicted = simulate(
                    ctx.topo, self.wf, ctx.plan,
                    n_iterations=max(len(starts), 4),
                    cost_model=cost_model).iteration_time
            rows.append({"epoch": ctx.epoch,
                         "iterations": len(starts),
                         "measured_iter_s": measured,
                         "predicted_iter_s": predicted,
                         "ratio": measured / predicted
                         if predicted and np.isfinite(predicted)
                         else float("nan")})
        return rows
