"""Plan → JAX placement: map each task's (dp, pp, tp) + tasklet assignment
onto the host's real devices.

The scheduler plans against the paper's heterogeneous pools (up to 64
GPUs); the host executing the plan usually has fewer devices.  The folding
rule is deterministic so the same plan always lands on the same submeshes:

  1. every plan device id ``d`` folds onto ``local_devices[d % L]``
     (L = number of real devices), preserving the plan's tasklet order;
  2. duplicates collapse (first occurrence wins), giving ``n`` distinct
     real devices for the task;
  3. the task's mesh is ``(data=n/tp', model=tp')`` with
     ``tp' = gcd(tp, n)`` — tensor parallelism survives when it divides
     the folded device count, pipeline stages collapse into the data
     axis (no cross-host pipeline runtime on a single host).

Mesh axes are ``("data", "model")`` so ``parallel.sharding`` param rules
apply unchanged.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh
import numpy as np

from repro.core.plan import Plan
from repro.parallel import sharding as sh


@dataclasses.dataclass
class TaskPlacement:
    task: int
    dp: int                       # plan-level parallelization
    pp: int
    tp: int
    plan_devices: Tuple[int, ...]   # plan device ids, tasklet order
    local_devices: Tuple            # distinct folded jax devices
    mesh: Mesh                      # ("data", "model") over local_devices

    @property
    def mesh_shape(self) -> Tuple[int, int]:
        return tuple(self.mesh.devices.shape)

    def param_shardings(self, params):
        """NamedShardings for a parameter pytree under this placement
        (specs sanitized so non-dividing axes drop to replication)."""
        specs = sh.param_tree_specs(params)
        return sh.named_shardings(self.mesh, specs, params)


def fold_devices(plan_devices: Sequence[int], local_devices) -> List:
    """Deterministic device folding: plan id d -> local_devices[d % L]."""
    L = len(local_devices)
    folded = [local_devices[int(d) % L] for d in plan_devices]
    distinct, seen = [], set()
    for dev in folded:
        if id(dev) not in seen:
            seen.add(id(dev))
            distinct.append(dev)
    return distinct


def build_placement(plan: Plan, t: int,
                    devices: Optional[Sequence] = None) -> TaskPlacement:
    devices = list(devices) if devices is not None else jax.devices()
    dp, pp, tp = plan.parallel[t]
    plan_devs = tuple(int(d) for d in plan.assignment[t].reshape(-1))
    distinct = fold_devices(plan_devs, devices)
    n = len(distinct)
    tp_eff = math.gcd(tp, n)
    mesh = Mesh(np.array(distinct).reshape(n // tp_eff, tp_eff),
                ("data", "model"))
    return TaskPlacement(t, dp, pp, tp, plan_devs, tuple(distinct), mesh)


def build_placements(plan: Plan, tasks: Sequence[int],
                     devices: Optional[Sequence] = None
                     ) -> Dict[int, TaskPlacement]:
    devices = list(devices) if devices is not None else jax.devices()
    return {t: build_placement(plan, t, devices) for t in tasks}
