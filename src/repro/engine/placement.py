"""Plan → JAX placement: map each task's (dp, pp, tp) + tasklet assignment
onto the host's real devices.

The scheduler plans against the paper's heterogeneous pools (up to 64
GPUs); the host executing the plan usually has a different device count.
Folding is *group-aware* and deterministic, so the same plan always lands
on the same submeshes:

  1. collect the distinct plan device ids used by the whole plan, sorted
     ascending.  When they fit on the host (``#ids <= L`` real devices)
     the i-th smallest plan id folds onto ``local_devices[i]`` — an
     *injective* map, so disjoint plan groups land on disjoint real
     device sets and the executor's disjoint-concurrent lanes genuinely
     overlap.  (A plan over ids 0..L-1 folds to the identity map.)
  2. only when the host is oversubscribed (``#ids > L``) does folding
     fall back to ``d % L``; distinct groups can then collide on a real
     device.  Every such collision is recorded in
     ``DeviceFolding.collisions`` so the engine can tag the affected
     events instead of reporting fake concurrency.
  3. per task, duplicates collapse (first plan-order occurrence wins),
     giving ``n`` distinct real devices; the task's mesh is
     ``(data=n/tp', model=tp')`` with ``tp' = gcd(tp, n)`` — tensor
     parallelism survives when it divides the folded device count,
     pipeline stages collapse into the data axis (no cross-host pipeline
     runtime inside one process).

Mesh axes are ``("data", "model")`` so ``parallel.sharding`` param rules
apply unchanged.  ``TaskPlacement`` carries everything the execution path
needs to shard for real: the mesh, ``param_shardings`` /
``batch_shardings`` built from ``parallel.sharding``, the realized
``tp_eff``, and ``rep_plan_devices`` (one representative plan id per
real device) from which ``Engine.realized_plan`` rebuilds a plan the
cost model can price at the *realized* parallelization.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import numpy as np

from repro.core.plan import Plan
from repro.parallel import sharding as sh


@dataclasses.dataclass(frozen=True)
class DeviceFolding:
    """Plan-wide folding decision: plan device id -> local device index.

    ``collisions`` lists real devices shared by *distinct* plan groups
    (only possible when ``oversubscribed``): tuples of
    ``(local_index, group_indices)``.  Within-group many-to-one folding
    is not a collision — it shrinks dp/tp, which placement accounts for.
    """
    mapping: Dict[int, int]
    oversubscribed: bool
    collisions: Tuple[Tuple[int, Tuple[int, ...]], ...]
    colliding_groups: frozenset

    @property
    def n_collisions(self) -> int:
        return len(self.collisions)


@dataclasses.dataclass
class TaskPlacement:
    task: int
    dp: int                       # plan-level parallelization
    pp: int
    tp: int
    plan_devices: Tuple[int, ...]   # plan device ids, tasklet order
    local_devices: Tuple            # distinct folded jax devices
    mesh: Mesh                      # ("data", "model") over local_devices
    rep_plan_devices: Tuple[int, ...] = ()  # plan id per distinct device
    collision: bool = False       # group shares a real device with another

    @property
    def mesh_shape(self) -> Tuple[int, int]:
        return tuple(self.mesh.devices.shape)

    @property
    def n_devices(self) -> int:
        return len(self.local_devices)

    @property
    def tp_eff(self) -> int:
        """Realized tensor parallelism after folding (mesh model dim)."""
        return self.mesh.devices.shape[1]

    @property
    def dp_eff(self) -> int:
        """Realized data parallelism after folding (mesh data dim)."""
        return self.mesh.devices.shape[0]

    @property
    def sharded(self) -> bool:
        """True when this task actually spans several real devices."""
        return len(self.local_devices) > 1

    def activation_rules(self):
        """Activation-sharding rules for this placement's (dp, tp).

        Sequence sharding stays off: RL batches are short and ragged, and
        the decode path keeps B on ``data`` only."""
        return sh.default_activation_rules(seq_shard=False)

    def param_shardings(self, params):
        """NamedShardings for a parameter pytree under this placement
        (specs sanitized so non-dividing axes drop to replication)."""
        specs = sh.param_tree_specs(params)
        return sh.named_shardings(self.mesh, specs, params)

    def replicated_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def batch_shardings(self, tree):
        """Leading-dim ``data`` sharding for a batch pytree (replicated
        when the batch dim does not divide the data axis)."""
        return jax.tree_util.tree_map(
            lambda x: NamedSharding(
                self.mesh,
                sh.sanitize_spec(P("data"), np.shape(x), self.mesh)),
            tree)

    def shard_batch(self, tree):
        """Commit a batch pytree onto this mesh, split over ``data``."""
        return jax.device_put(tree, self.batch_shardings(tree))


def fold_plan(plan: Plan, local_devices) -> DeviceFolding:
    """Group-aware folding for a whole plan.

    Injective (rank-ordered) when every distinct plan device id fits on
    the host; ``d % L`` with a collision report otherwise."""
    L = len(local_devices)
    ids = sorted({int(d) for g in plan.groups for d in g.devices}
                 | {int(d) for a in plan.assignment.values()
                    for d in np.asarray(a).reshape(-1)})
    oversubscribed = len(ids) > L
    if oversubscribed:
        mapping = {d: d % L for d in ids}
    else:
        mapping = {d: i for i, d in enumerate(ids)}

    # collision report: real devices claimed by >= 2 distinct groups
    claims: Dict[int, set] = {}
    for gi, g in enumerate(plan.groups):
        for d in g.devices:
            if int(d) in mapping:
                claims.setdefault(mapping[int(d)], set()).add(gi)
    collisions = tuple(
        (li, tuple(sorted(gs))) for li, gs in sorted(claims.items())
        if len(gs) > 1)
    colliding = frozenset(g for _, gs in collisions for g in gs)
    return DeviceFolding(mapping, oversubscribed, collisions, colliding)


def fold_devices(plan_devices: Sequence[int], local_devices,
                 mapping: Optional[Dict[int, int]] = None) -> List:
    """Fold one task's plan device ids onto distinct local devices.

    With ``mapping`` (from :func:`fold_plan`) the plan-wide group-aware
    rule applies; without it, the legacy ``d % L`` rule — kept for
    callers folding a device list with no plan in hand."""
    L = len(local_devices)
    if mapping is None:
        folded = [local_devices[int(d) % L] for d in plan_devices]
    else:
        folded = [local_devices[mapping[int(d)]] for d in plan_devices]
    distinct, seen = [], set()
    for dev in folded:
        if id(dev) not in seen:
            seen.add(id(dev))
            distinct.append(dev)
    return distinct


def build_placement(plan: Plan, t: int,
                    devices: Optional[Sequence] = None,
                    folding: Optional[DeviceFolding] = None
                    ) -> TaskPlacement:
    devices = list(devices) if devices is not None else jax.devices()
    if folding is None:
        folding = fold_plan(plan, devices)
    dp, pp, tp = plan.parallel[t]
    plan_devs = tuple(int(d) for d in plan.assignment[t].reshape(-1))
    distinct = fold_devices(plan_devs, devices, folding.mapping)
    # representative plan id per distinct real device (first claimant)
    reps, seen = [], set()
    for d in plan_devs:
        dev = devices[folding.mapping[int(d)]]
        if id(dev) not in seen:
            seen.add(id(dev))
            reps.append(int(d))
    n = len(distinct)
    tp_eff = math.gcd(tp, n)
    mesh = Mesh(np.array(distinct).reshape(n // tp_eff, tp_eff),
                ("data", "model"))
    group = plan.group_of(t)
    gi = plan.groups.index(group) if group in plan.groups else -1
    return TaskPlacement(t, dp, pp, tp, plan_devs, tuple(distinct), mesh,
                         rep_plan_devices=tuple(reps),
                         collision=gi in folding.colliding_groups)


def build_placements(plan: Plan, tasks: Sequence[int],
                     devices: Optional[Sequence] = None
                     ) -> Dict[int, TaskPlacement]:
    devices = list(devices) if devices is not None else jax.devices()
    folding = fold_plan(plan, devices)
    return {t: build_placement(plan, t, devices, folding) for t in tasks}
