"""Per-task executors for the RL workflow graph.

Each workflow task (GEN / INF / TRAIN) has a registered executor that runs
the real JAX computation for that task against the shared execution state
``st`` (the trainer's parameters, optimizers and jitted functions) and the
iteration blackboard ``bb``.  The engine dispatches them stage by stage as
the plan dictates; executors return the JAX value the engine should block
on when timing the task.

Registration is by (TaskKind, task name), with a (TaskKind, None) fallback
so custom workflows can reuse the generic executor of a kind.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_mod
from repro.core.workflow import Task, TaskKind
from repro.genserve import adapter as genserve
from repro.rl import gae, losses
from repro.rl import rewards as rewards_mod

_EXECUTORS: Dict[Tuple[TaskKind, Optional[str]], Callable] = {}


def register(kind: TaskKind, name: Optional[str] = None):
    def deco(fn):
        _EXECUTORS[(kind, name)] = fn
        return fn
    return deco


def executor_for(task: Task) -> Callable:
    fn = _EXECUTORS.get((task.kind, task.name)) \
        or _EXECUTORS.get((task.kind, None))
    if fn is None:
        raise KeyError(f"no executor registered for task "
                       f"{task.name!r} ({task.kind})")
    return fn


# ---------------------------------------------------------------------------
# GEN
# ---------------------------------------------------------------------------

def _draft_model(st):
    """Resolve the speculative-decoding draft model for this trainer,
    once (cached on ``st``): ``rl.draft_arch`` names a configs.archs
    entry (vocab/dtype overridden to the target's so logits align), ""
    derives a scaled-down full-attention copy of the target.  Weights
    are freshly initialized — a deployment would load a trained draft
    checkpoint; acceptance rate, not weight quality, is what the
    repro's benchmarks vary."""
    cached = getattr(st, "_spec_draft", None)
    if cached is not None:
        return cached
    from repro.configs import archs
    from repro.models import transformer as T
    name = getattr(st.rl, "draft_arch", "")
    cfg = st.cfg
    if name:
        dcfg = dataclasses.replace(
            archs.get(name, smoke=cfg.n_layers <= 4),
            vocab_size=cfg.vocab_size, dtype=cfg.dtype)
    else:
        pattern = tuple(dataclasses.replace(s, window=None)
                        for s in cfg.pattern)
        dcfg = dataclasses.replace(
            cfg, name=f"{cfg.name}-draft", pattern=pattern,
            n_layers=len(pattern) * max(cfg.n_pattern_repeats // 4, 1))
    dparams = T.init_params(jax.random.PRNGKey(7), dcfg)
    st._spec_draft = (dcfg, dparams)
    return st._spec_draft


@register(TaskKind.GEN)
def run_generation(st, bb, placement):
    """Actor generation on the generation replica (pre-sync weights).

    Routed through the continuous-batching engine (``repro.genserve``)
    when the rollout batch exceeds the plan's decode wave, so
    ``MAX_DECODE_WAVE`` semantics from the cost model are enforced at
    execution time; a batch that fits in one wave takes the trainer's
    jitted single-wave path (the genserve fast path).  Either way the
    wave stats land in ``bb["gen_stats"]`` for the engine's per-wave
    Event timeline."""
    prompts = bb["prompts_rep"]
    B = int(prompts.shape[0])
    wave = plan_mod.decode_wave(B)
    mode = getattr(st.rl, "gen_engine", "auto")
    # scheduled decode-slot failures (repro.faults) only exist on the
    # engine path — their presence forces it
    injector = bb.get("fault")
    slot_failures = injector.gen_slot_failures() \
        if injector is not None else None
    spec_k = int(getattr(st.rl, "spec_k", 0))
    draft_params, draft_cfg = None, None
    if spec_k > 0:
        draft_cfg, draft_params = _draft_model(st)
    use_engine = mode == "genserve" or (mode == "auto" and B > wave) \
        or slot_failures is not None or spec_k > 0
    with placement.mesh:
        if use_engine:
            ro, stats = genserve.generate(
                st.gen_params, st.cfg, prompts, bb["rng"], st.sampler,
                wave=wave, decode_chunk=getattr(st.rl, "decode_chunk", 1),
                prefill_chunk=getattr(st.rl, "prefill_chunk", 0),
                fast_path=False, slot_failures=slot_failures,
                spec_k=spec_k, draft_params=draft_params,
                draft_cfg=draft_cfg,
                mesh=placement.mesh if placement.sharded else None)
        elif placement.sharded:
            # single-wave decode jitted on the gen mesh: prompts split
            # over data, params TP-sharded, hints active
            prompts_d = placement.shard_batch(prompts)
            ro = st.sharded_generate(placement, prompts_d)(
                st.gen_params, prompts_d, bb["rng"])
            stats = genserve.wave_stats_from_mask(ro["mask"])
        else:
            if bb.get("multidev"):
                prompts = jax.device_put(prompts,
                                         placement.local_devices[0])
            ro = st._generate(st.gen_params, prompts=prompts,
                              rng=bb["rng"])
            # the single-wave path decodes all B rows at once — its wave
            # width is B, whatever the cost model's bound says
            stats = genserve.wave_stats_from_mask(ro["mask"])
    bb["gen_stats"] = stats
    bb["fresh"] = {"rollout": ro, "answers_rep": bb["answers_rep"],
                   "gen_start": bb["gen_start"],
                   "gen_version": st.weight_version}
    return ro["sequences"]


# ---------------------------------------------------------------------------
# INF
# ---------------------------------------------------------------------------

@register(TaskKind.INF, "reward_inference")
def run_reward(st, bb, placement):
    b = bb["bundle"]
    gen_np = np.asarray(b["rollout"]["gen_tokens"])
    scores = st.task.reward_batch(b["answers_rep"], gen_np)
    with bb["lock"]:
        bb["scores"] = scores
    return None


@register(TaskKind.INF, "reference_inference")
def run_reference(st, bb, placement):
    b = bb["bundle"]
    seqs = b["rollout"]["sequences"]
    with placement.mesh:
        if placement.sharded:
            seqs = placement.shard_batch(seqs)
            lp_ref = st.sharded_ref_logp(placement, seqs)(
                st.ref, seqs, int(b["gen_start"]))
        else:
            if bb.get("multidev"):
                # rollout tensors live on the gen group's devices; pull
                # them onto this task's device before the jitted call
                seqs = jax.device_put(seqs, placement.local_devices[0])
            lp_ref = st._ref_logp(st.ref, seqs, gen_start=b["gen_start"])
    with bb["lock"]:
        # host copy under multi-device: the advantage prep mixes tensors
        # from several meshes, which eager ops refuse to colocate
        bb["lp_ref"] = np.asarray(lp_ref) if bb.get("multidev") else lp_ref
    return lp_ref


@register(TaskKind.INF, "critic_inference")
def run_critic_inference(st, bb, placement):
    b = bb["bundle"]
    seqs = b["rollout"]["sequences"]
    with placement.mesh:
        if placement.sharded:
            seqs = placement.shard_batch(seqs)
            values = st.sharded_critic_vals(placement, seqs)(
                st.critic, st.value_head, seqs, int(b["gen_start"]))
        else:
            if bb.get("multidev"):
                seqs = jax.device_put(seqs, placement.local_devices[0])
            values = st._critic_vals(st.critic, st.value_head, seqs,
                                     gen_start=b["gen_start"])
    with bb["lock"]:
        bb["values"] = np.asarray(values) if bb.get("multidev") else values
    return values


# ---------------------------------------------------------------------------
# TRAIN (shared advantage preparation, then per-model update)
# ---------------------------------------------------------------------------

def ensure_train_batch(st, bb):
    """KL-penalised rewards + advantages, computed once per iteration.

    Both training executors may run concurrently on disjoint GPU groups;
    the first through the lock materializes the batch."""
    with bb["lock"]:
        if "batch" in bb:
            return
        rl = st.rl
        b = bb["bundle"]
        ro = b["rollout"]
        # under multi-device execution the rollout, reference and critic
        # tensors are committed to different meshes; eager ops refuse to
        # mix them, so the (tiny) advantage prep runs from host copies —
        # the training executors re-commit the batch onto their meshes
        multidev = bool(bb.get("multidev"))
        # round-trip through host memory drops the device commitment
        # while keeping jnp semantics downstream
        pull = (lambda x: jnp.asarray(np.asarray(x))) if multidev \
            else (lambda x: x)
        mask = pull(ro["mask"])
        lp_old = pull(ro["logprobs"])
        lp_ref = pull(bb["lp_ref"])
        tok_rewards, kl = losses.kl_penalised_rewards(
            jnp.asarray(bb["scores"]), lp_old, lp_ref, mask,
            kl_beta=rl.kl_beta)
        bb["metrics"].update({
            "reward_mean": float(bb["scores"].mean()),
            "kl": float(kl),
            "gen_len": float(np.asarray(mask).sum(1).mean()),
        })
        if rl.algorithm == "ppo":
            values = pull(bb["values"])
            adv, returns = gae.gae_advantages(
                tok_rewards, values * mask, mask,
                gamma=rl.gamma, lam=rl.lam)
            bb["returns"] = returns
        else:
            seq_reward = np.asarray(tok_rewards).sum(1)
            adv = gae.grpo_advantages(jnp.asarray(seq_reward),
                                      rl.n_rollouts, mask)
        if rl.whiten_advantages:
            adv = gae.whiten(adv, mask)
        bb["batch"] = {"sequences": pull(ro["sequences"]),
                       "logp_old": lp_old,
                       "advantages": adv, "mask": mask}


@register(TaskKind.TRAIN, "actor_training")
def run_actor_training(st, bb, placement):
    ensure_train_batch(st, bb)
    b = bb["bundle"]
    with placement.mesh:
        if placement.sharded:
            batch = placement.shard_batch(bb["batch"])
            st.actor, st.actor_opt, am = st.sharded_actor_step(
                placement, batch)(st.actor, st.actor_opt, batch,
                                  int(b["gen_start"]))
        else:
            batch = bb["batch"]
            if bb.get("multidev"):
                batch = jax.device_put(batch, placement.local_devices[0])
            st.actor, st.actor_opt, am = st._actor_step(
                st.actor, st.actor_opt, batch, gen_start=b["gen_start"])
    with bb["lock"]:
        bb["metrics"].update({k: float(v) for k, v in am.items()})
    return st.actor


@register(TaskKind.TRAIN, "critic_training")
def run_critic_training(st, bb, placement):
    ensure_train_batch(st, bb)
    b = bb["bundle"]
    mask = bb["batch"]["mask"]
    values = bb["values"]
    if bb.get("multidev"):
        values = np.asarray(values)
    cbatch = dict(bb["batch"], values_old=values * mask,
                  returns=bb["returns"])
    with placement.mesh:
        if placement.sharded:
            cbatch = placement.shard_batch(cbatch)
            (st.critic, st.value_head), st.critic_opt, closs = \
                st.sharded_critic_step(placement, cbatch)(
                    (st.critic, st.value_head), st.critic_opt, cbatch,
                    int(b["gen_start"]))
        else:
            if bb.get("multidev"):
                cbatch = jax.device_put(cbatch,
                                        placement.local_devices[0])
            (st.critic, st.value_head), st.critic_opt, closs = \
                st._critic_step((st.critic, st.value_head),
                                st.critic_opt, cbatch,
                                gen_start=b["gen_start"])
    with bb["lock"]:
        bb["metrics"]["critic_loss"] = float(closs)
    return closs
