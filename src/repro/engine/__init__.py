"""Plan-driven execution engine.

Consumes ``(RLWorkflow, Plan, Topology)`` and executes the real JAX RL
iteration as the scheduler's plan dictates: per-task executors dispatched
stage by stage, colocated tasks serialized, disjoint GPU groups run
concurrently, async one-step off-policy double-buffering, and a measured
``Event`` timeline that shares dataclasses with ``core.simulator`` so
measured-vs-predicted comparison is one function call.

The plan-epoch model (§6 online redeployment): everything derived from
the ``Plan`` — placements, lane partitioning, replay device availability,
gen/train task ids — lives in a swappable ``PlanContext``, so a running
session is a sequence of *plan epochs*.  ``Engine.apply_plan`` retires
the live context at an iteration boundary: it replays a weight-migration
event priced by ``core.redeploy.transition_cost``, re-seeds device
availability at the migration end, drains or carries the async pipeline's
pending bundle (the one-step-staleness invariant survives either way),
and stamps every subsequent ``Event`` with the new epoch.  Trainer and
optimizer state are owned by the trainer facade and cross the swap
untouched; ``engine.elastic.ElasticController`` builds the §6 loop on
top — watch a topology feed, ``reschedule`` with a warm-start budget,
checkpoint through ``checkpoint.io``, and apply or reject the decision.
"""
from repro.engine.executor import (Engine, EngineResult,  # noqa: F401
                                   MIGRATION_TASK, PlanContext)
from repro.engine.pipeline import AsyncPipeline  # noqa: F401
from repro.engine.placement import TaskPlacement, build_placements  # noqa: F401
