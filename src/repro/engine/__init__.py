"""Plan-driven execution engine.

Consumes ``(RLWorkflow, Plan, Topology)`` and executes the real JAX RL
iteration as the scheduler's plan dictates: per-task executors dispatched
stage by stage, colocated tasks serialized, disjoint GPU groups run
concurrently, async one-step off-policy double-buffering, and a measured
``Event`` timeline that shares dataclasses with ``core.simulator`` so
measured-vs-predicted comparison is one function call.
"""
from repro.engine.executor import Engine, EngineResult  # noqa: F401
from repro.engine.pipeline import AsyncPipeline  # noqa: F401
from repro.engine.placement import TaskPlacement, build_placements  # noqa: F401
