"""Async one-step off-policy pipeline (§2.1) + weight-sync routing.

``AsyncPipeline`` owns the generation/training double buffer: generation
for iteration t+1 overlaps training on iteration t's rollouts.  ``push``
swaps the freshly-generated bundle for the previous one (synchronous mode
is a pass-through); the first asynchronous call returns ``None`` — the
pipeline-fill iteration with nothing to train on yet.

Each bundle carries the ``gen_version`` (weight-sync counter at generation
time), so staleness is observable: in steady-state async execution the
bundle being trained is exactly one sync behind the current weights.

``sync_actor_weights`` routes the trained actor to the generation replica
through ``rl.sync`` with the target sharding taken from the generation
task's placement, and accounts both actual bytes moved and the plan's
predicted reshard/sync seconds from the cost model.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.rl.sync import sync_weights


@dataclasses.dataclass
class PipelineRecord:
    iteration: int
    gen_version: int       # weight version the trained rollouts came from
    weight_version: int    # current version when training started


class AsyncPipeline:
    def __init__(self, asynchronous: bool):
        self.asynchronous = asynchronous
        self._pending: Optional[Dict[str, Any]] = None
        self.records: List[PipelineRecord] = []

    def push(self, fresh: Dict[str, Any]) -> Optional[Dict[str, Any]]:
        """Submit this iteration's rollouts; get back the bundle to train
        on (the previous one when asynchronous, the same one when not)."""
        if not self.asynchronous:
            return fresh
        pending, self._pending = self._pending, fresh
        return pending

    def drain(self) -> Optional[Dict[str, Any]]:
        """Explicitly give up the pending bundle (plan swap with drain
        semantics): the in-flight rollouts generated under the outgoing
        plan are discarded and the pipeline refills, so the first
        iteration after the swap is a fill iteration.  Returns the
        dropped bundle (None when nothing was pending)."""
        pending, self._pending = self._pending, None
        return pending

    def record(self, iteration: int, bundle: Dict[str, Any],
               weight_version: int) -> None:
        self.records.append(PipelineRecord(
            iteration, bundle["gen_version"], weight_version))

    @property
    def filling(self) -> bool:
        return self.asynchronous and self._pending is None


def sync_actor_weights(st, gen_placement,
                       train_placement=None) -> float:
    """Trained actor -> generation replica through the plan's reshard path.

    Weight publication is an explicit ``device_put`` onto the generation
    placement's shardings whenever the gen group is sharded *or* lives on
    different real devices than the training group — the cross-group
    reshard the cost model prices (``c_sync`` / the redeploy transition
    term).  Identity handoff (zero copy) only when both tasks fold to the
    same device set and no sharding is involved.  Bumps the weight
    version the pipeline uses to verify one-step staleness.  Returns
    bytes moved."""
    target = None
    if gen_placement is not None:
        same_devices = train_placement is not None and \
            tuple(id(d) for d in gen_placement.local_devices) == \
            tuple(id(d) for d in train_placement.local_devices)
        if len(gen_placement.local_devices) > 1 or \
                (train_placement is not None and not same_devices):
            target = gen_placement.param_shardings(st.actor)
    st.gen_params, nbytes = sync_weights(st.actor, target)
    st.sync_bytes += nbytes
    st.weight_version += 1
    return nbytes
