"""Elasticity controller: the §6 online-redeployment loop over a live
engine.

``ElasticController`` watches a topology feed (a
``core.topology.DriftSchedule`` or any ``iteration -> Topology``
callable) and, at iteration boundaries, reacts to drift exactly as the
paper prescribes: re-run the scheduler with a short warm-start budget
(``core.redeploy.reschedule``), checkpoint the live trainer state through
``checkpoint.io`` (the paper's "during model checkpointing"), and apply
or reject the plan per the ``RedeployDecision`` — a ``switch=True``
decision swaps the engine's plan context through ``Engine.apply_plan``
with trainer/optimizer state untouched, a ``switch=False`` decision keeps
the incumbent but still adopts the drifted topology for predictions.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional, Union

from repro.core.redeploy import RedeployDecision, reschedule
from repro.core.topology import DriftSchedule, Topology, topo_equal
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    budget: int = 150              # reschedule's warm-started eval budget
    amortization_iters: int = 20   # horizon a new plan must pay back over
    ckpt_dir: Optional[str] = None  # checkpoint around the switch when set
    carry_pending: bool = True     # carry vs drain the async bundle
    seed: int = 0


@dataclasses.dataclass
class AdaptRecord:
    """One reaction to observed drift (whether or not a swap happened)."""
    iteration: int
    decision: RedeployDecision
    applied: bool                  # True when the engine swapped plans
    epoch: int                     # engine plan epoch after the reaction
    reschedule_s: float            # wall-clock spent in the scheduler
    ckpt_path: Optional[str] = None
    ckpt_bytes: int = 0
    transition: Dict[str, float] = dataclasses.field(default_factory=dict)
    reactive: bool = False         # fired by the divergence monitor, not
    #                                an observed topology change


class ElasticController:
    """Drive ``poll(iteration)`` once per finished iteration (the
    boundary where §6 allows a swap).  Drift is detected structurally —
    the feed may return the same object every call."""

    def __init__(self, trainer,
                 feed: Union[DriftSchedule, Callable[[int], Topology]],
                 cfg: Optional[ElasticConfig] = None,
                 monitor=None):
        self.trainer = trainer
        self.feed = feed
        self.cfg = cfg or ElasticConfig()
        self.records: List[AdaptRecord] = []
        self._topo = trainer.engine.topo
        # optional obs.calibrate.DivergenceMonitor: sustained measured/
        # predicted drift fires a reschedule against the *current*
        # topology even when the feed reports no structural change —
        # the reactive half of "calibrated cost model + reactive
        # elasticity".  The engine must be feeding the monitor
        # (Engine.attach_divergence_monitor) for it to ever fire.
        self.monitor = monitor

    def _observe(self, iteration: int) -> Optional[Topology]:
        if hasattr(self.feed, "topo_at"):
            return self.feed.topo_at(iteration)
        return self.feed(iteration)

    def poll(self, iteration: int) -> Optional[AdaptRecord]:
        """Check the feed (and the divergence monitor, when attached);
        on drift, reschedule / checkpoint / apply.  Returns the record
        when drift was handled, None when quiet."""
        topo = self._observe(iteration)
        drifted = topo is not None and not topo_equal(topo, self._topo)
        reactive = (not drifted and self.monitor is not None
                    and self.monitor.consume())
        if not drifted and not reactive:
            return None
        if reactive:
            # no structural change observed: replan against the
            # environment we believe we are in — the point is that
            # measurements say the belief is wrong
            topo = self._topo
        topo_old, self._topo = self._topo, topo
        trainer, cfg = self.trainer, self.cfg
        with obs_trace.span("elastic.poll", iteration=iteration,
                            reactive=reactive):
            t0 = time.monotonic()
            with obs_trace.span("elastic.reschedule"):
                decision = reschedule(
                    topo, trainer.wf, trainer.plan, budget=cfg.budget,
                    amortization_iters=cfg.amortization_iters,
                    seed=cfg.seed, topo_old=topo_old)
            resched_s = time.monotonic() - t0
            obs_metrics.histogram("elastic.reschedule_s").observe(
                resched_s)

            # checkpoint the live state before touching the execution
            # plan — §6 applies the new plan "immediately after
            # checkpointing", and a failed migration can restore from
            # here
            ckpt_path, ckpt_bytes = None, 0
            if cfg.ckpt_dir:
                from repro.checkpoint import io as ckpt_io
                ckpt_path = os.path.join(
                    cfg.ckpt_dir, f"elastic_iter{iteration:05d}.msgpack")
                with obs_trace.span("elastic.checkpoint"):
                    ckpt_bytes = ckpt_io.save(ckpt_path,
                                              trainer.state_tree())
                obs_metrics.counter("elastic.checkpoint_bytes").inc(
                    ckpt_bytes)

            transition: Dict[str, float] = {}
            if decision.switch:
                transition = trainer.engine.apply_plan(
                    decision.plan, topo=topo,
                    carry_pending=cfg.carry_pending)
            else:
                # stay on the incumbent, but predictions must price the
                # drifted environment; when the incumbent no longer fits
                # the drifted device list (no feasible challenger after
                # a drop) the engine keeps the old topology and flags
                # ``topology_stale`` instead of adopting an inconsistent
                # (plan, topo) pair that would crash prediction
                trainer.engine.update_topology(topo)
        rec = AdaptRecord(iteration, decision, decision.switch,
                          trainer.engine.epoch, resched_s,
                          ckpt_path, ckpt_bytes, transition,
                          reactive=reactive)
        self.records.append(rec)
        return rec

    @property
    def swaps(self) -> List[AdaptRecord]:
        return [r for r in self.records if r.applied]
