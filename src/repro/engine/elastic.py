"""Elasticity controller: the §6 online-redeployment loop over a live
engine.

``ElasticController`` watches a topology feed (a
``core.topology.DriftSchedule`` or any ``iteration -> Topology``
callable) and, at iteration boundaries, reacts to drift exactly as the
paper prescribes: re-run the scheduler with a short warm-start budget
(``core.redeploy.reschedule``), checkpoint the live trainer state through
``checkpoint.io`` (the paper's "during model checkpointing"), and apply
or reject the plan per the ``RedeployDecision`` — a ``switch=True``
decision swaps the engine's plan context through ``Engine.apply_plan``
with trainer/optimizer state untouched, a ``switch=False`` decision keeps
the incumbent but still adopts the drifted topology for predictions.

Reactive drift (no feed change, the ``DivergenceMonitor`` fired): the
controller replans against the *measured* topology —
``obs.calibrate.infer_drifted_topology`` turns the monitor's per-task
ratios into the environment the measurements describe — so the scheduler
can actually route around an undeclared degradation instead of
rediscovering the incumbent on the pristine believed topology.

Failure escalation (``handle_failure``): a ``TaskExecutionError`` whose
retries are exhausted or that is permanent drops the dead devices from
the believed topology and forces a replan+swap onto the survivors.

Checkpoint writes go through ``core.retry`` with bounded backoff; a
persistently failing checkpoint path degrades to warn-and-continue
(metric ``checkpoint.failures``) instead of killing the training loop.
"""
from __future__ import annotations

import dataclasses
import os
import time
import warnings
from typing import Callable, Dict, List, Optional, Union

from repro.core import retry as retry_mod
from repro.core.redeploy import RedeployDecision, reschedule
from repro.core.topology import (DriftSchedule, Topology, drop_devices,
                                 topo_equal)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    budget: int = 150              # reschedule's warm-started eval budget
    amortization_iters: int = 20   # horizon a new plan must pay back over
    ckpt_dir: Optional[str] = None  # checkpoint around the switch when set
    ckpt_retain: int = 3           # keep the newest K checkpoints (0 = all)
    ckpt_retry: retry_mod.RetryPolicy = retry_mod.RetryPolicy(
        max_attempts=3, base_delay_s=0.05)
    carry_pending: bool = True     # carry vs drain the async bundle
    cooldown_iters: int = 3        # iterations to ignore reactive fires
    #                                after any swap (let the EWMA settle)
    seed: int = 0


@dataclasses.dataclass
class AdaptRecord:
    """One reaction to observed drift (whether or not a swap happened)."""
    iteration: int
    decision: RedeployDecision
    applied: bool                  # True when the engine swapped plans
    epoch: int                     # engine plan epoch after the reaction
    reschedule_s: float            # wall-clock spent in the scheduler
    ckpt_path: Optional[str] = None
    ckpt_bytes: int = 0
    transition: Dict[str, float] = dataclasses.field(default_factory=dict)
    reactive: bool = False         # fired by the divergence monitor, not
    #                                an observed topology change
    forced: bool = False           # failure escalation (handle_failure)


class ElasticController:
    """Drive ``poll(iteration)`` once per finished iteration (the
    boundary where §6 allows a swap).  Drift is detected structurally —
    the feed may return the same object every call."""

    def __init__(self, trainer,
                 feed: Union[DriftSchedule, Callable[[int], Topology]],
                 cfg: Optional[ElasticConfig] = None,
                 monitor=None):
        self.trainer = trainer
        self.feed = feed
        self.cfg = cfg or ElasticConfig()
        self.records: List[AdaptRecord] = []
        # _topo: the environment we currently believe in (may be an
        # inferred measured topology after a reactive replan);
        # _observed: the feed's last value — feed drift is detected
        # against this, so adopting an inferred topology does not make
        # the unchanged feed look like fresh structural drift forever.
        self._topo = trainer.engine.topo
        self._observed = trainer.engine.topo
        self._cooldown_until = -1
        # optional obs.calibrate.DivergenceMonitor: sustained measured/
        # predicted drift fires a reschedule against the *measured*
        # topology even when the feed reports no structural change —
        # the reactive half of "calibrated cost model + reactive
        # elasticity".  The engine must be feeding the monitor
        # (Engine.attach_divergence_monitor) for it to ever fire.
        self.monitor = monitor

    def _observe(self, iteration: int) -> Optional[Topology]:
        if hasattr(self.feed, "topo_at"):
            return self.feed.topo_at(iteration)
        return self.feed(iteration)

    # -- checkpointing ---------------------------------------------------
    def _checkpoint(self, iteration: int) -> tuple:
        """Write the trainer state tree with bounded retry; a
        persistently failing path warns and continues (the §6 swap is
        still applied — recovery just has an older restore point).
        Returns (path, bytes) — (None, 0) when disabled or failed."""
        cfg = self.cfg
        if not cfg.ckpt_dir:
            return None, 0
        from repro.checkpoint import io as ckpt_io
        path = os.path.join(cfg.ckpt_dir,
                            f"elastic_iter{iteration:05d}.msgpack")
        tree = self.trainer.state_tree()
        injector = getattr(self.trainer.engine, "fault_injector", None)

        def write(attempt: int) -> int:
            if injector is not None:
                injector.maybe_fail_checkpoint(attempt)
            return ckpt_io.save(path, tree, retain=cfg.ckpt_retain)

        def on_retry(attempt: int, exc: BaseException) -> None:
            obs_metrics.counter("checkpoint.retries").inc()

        try:
            with obs_trace.span("elastic.checkpoint"):
                nbytes = retry_mod.retry_call(
                    write, policy=cfg.ckpt_retry,
                    retry_on=(retry_mod.TransientError, OSError),
                    on_retry=on_retry)
        except (retry_mod.RetryExhausted, OSError) as e:
            obs_metrics.counter("checkpoint.failures").inc()
            warnings.warn(f"checkpoint write failed, continuing without: "
                          f"{e}", RuntimeWarning, stacklevel=2)
            return None, 0
        if injector is not None:
            injector.maybe_corrupt_checkpoint(path)
        obs_metrics.counter("elastic.checkpoint_bytes").inc(nbytes)
        return path, nbytes

    def checkpoint_now(self, iteration: int) -> tuple:
        """Out-of-band periodic checkpoint through the same retry- and
        corruption-hardened path the drift reaction uses.  Returns
        (path, bytes) — (None, 0) when disabled or abandoned."""
        return self._checkpoint(iteration)

    # -- drift reaction --------------------------------------------------
    def poll(self, iteration: int) -> Optional[AdaptRecord]:
        """Check the feed (and the divergence monitor, when attached);
        on drift, reschedule / checkpoint / apply.  Returns the record
        when drift was handled, None when quiet."""
        observed = self._observe(iteration)
        drifted = observed is not None \
            and not topo_equal(observed, self._observed)
        fired = self.monitor is not None and self.monitor.consume()
        reactive = (not drifted and fired
                    and iteration >= self._cooldown_until)
        if not drifted and not reactive:
            return None
        topo_old = self._topo
        if drifted:
            topo = observed
            self._observed = observed
        else:
            # no structural change observed, but measurements say the
            # belief is wrong: replan against the topology the
            # measurements describe, not the pristine believed one — a
            # warm-started search on the believed topology would only
            # rediscover the incumbent
            topo = self._infer_measured_topology() or self._topo
        self._topo = topo
        trainer, cfg = self.trainer, self.cfg
        with obs_trace.span("elastic.poll", iteration=iteration,
                            reactive=reactive):
            t0 = time.monotonic()
            with obs_trace.span("elastic.reschedule"):
                decision = reschedule(
                    topo, trainer.wf, trainer.plan, budget=cfg.budget,
                    amortization_iters=cfg.amortization_iters,
                    seed=cfg.seed, topo_old=topo_old)
            resched_s = time.monotonic() - t0
            obs_metrics.histogram("elastic.reschedule_s").observe(
                resched_s)

            # checkpoint the live state before touching the execution
            # plan — §6 applies the new plan "immediately after
            # checkpointing", and a failed migration can restore from
            # here
            ckpt_path, ckpt_bytes = self._checkpoint(iteration)

            transition: Dict[str, float] = {}
            if decision.switch:
                transition = trainer.engine.apply_plan(
                    decision.plan, topo=topo,
                    carry_pending=cfg.carry_pending)
                self._cooldown_until = iteration + cfg.cooldown_iters
            else:
                # stay on the incumbent, but predictions must price the
                # drifted environment; when the incumbent no longer fits
                # the drifted device list (no feasible challenger after
                # a drop) the engine keeps the old topology and flags
                # ``topology_stale`` instead of adopting an inconsistent
                # (plan, topo) pair that would crash prediction
                trainer.engine.update_topology(topo)
        rec = AdaptRecord(iteration, decision, decision.switch,
                          trainer.engine.epoch, resched_s,
                          ckpt_path, ckpt_bytes, transition,
                          reactive=reactive)
        self.records.append(rec)
        return rec

    def _infer_measured_topology(self) -> Optional[Topology]:
        from repro.obs.calibrate import infer_drifted_topology
        trainer = self.trainer
        if self.monitor is None or self._topo is None:
            return None
        return infer_drifted_topology(self._topo, trainer.wf,
                                      trainer.plan, self.monitor)

    # -- failure escalation ----------------------------------------------
    def handle_failure(self, iteration: int, failure) -> AdaptRecord:
        """Escalate a task failure the retry budget could not absorb:
        drop the devices presumed dead, force a replan onto the
        survivors, checkpoint, and swap.  ``failure`` is the engine's
        ``TaskExecutionError`` (its ``dead_devices`` — or, lacking an
        attribution, the failed task's highest-id device — leave the
        fleet)."""
        dead = list(getattr(failure, "dead_devices", ()) or ())
        if not dead:
            devs = list(getattr(failure, "devices", ()) or ())
            dead = [max(devs)] if devs else []
        if not dead:
            raise ValueError(
                f"cannot escalate {failure!r}: no device attribution")
        trainer, cfg = self.trainer, self.cfg
        base = self._topo if self._topo is not None else trainer.engine.topo
        topo = drop_devices(base, dead)
        obs_metrics.counter("elastic.forced_replans").inc()
        with obs_trace.span("elastic.poll", iteration=iteration,
                            forced=True, dead=str(dead)):
            t0 = time.monotonic()
            with obs_trace.span("elastic.reschedule"):
                decision = reschedule(
                    topo, trainer.wf, trainer.plan, budget=cfg.budget,
                    amortization_iters=cfg.amortization_iters,
                    seed=cfg.seed, topo_old=base)
            resched_s = time.monotonic() - t0
            ckpt_path, ckpt_bytes = self._checkpoint(iteration)
            # the incumbent references dead devices (old_cost = inf), so
            # any feasible challenger switches; reschedule only declines
            # when the search found no plan that fits the survivors
            if not decision.switch \
                    or not decision.plan.fits_topology(topo):
                raise RuntimeError(
                    f"no feasible plan on the surviving devices "
                    f"(dropped {dead}): {failure}")
            transition = trainer.engine.apply_plan(
                decision.plan, topo=topo, carry_pending=cfg.carry_pending)
        self._topo = topo
        self._observed = topo
        self._cooldown_until = iteration + cfg.cooldown_iters
        rec = AdaptRecord(iteration, decision, True,
                          trainer.engine.epoch, resched_s, ckpt_path,
                          ckpt_bytes, transition, forced=True)
        self.records.append(rec)
        return rec

    @property
    def swaps(self) -> List[AdaptRecord]:
        return [r for r in self.records if r.applied]
