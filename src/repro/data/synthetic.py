"""Synthetic RL task + data pipeline.

Task: integer addition.  Prompts are "a+b=" over a small digit vocabulary;
the programmatic reward scores generated completions by digit-level
correctness of the sum (1.0 for exact, partial credit per digit).  This
gives PPO/GRPO a real, verifiable reward signal (GSM8k stand-in) that a
~1-10M model can visibly learn in a few hundred steps on CPU.

Also provides a generic random-token LM stream for throughput benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# vocabulary: 0-9 digits, '+', '=', PAD, BOS, EOS
PAD, BOS, EOS, PLUS, EQ = 10, 11, 12, 13, 14
VOCAB_SIZE = 16


def encode_number(n: int) -> List[int]:
    return [int(c) for c in str(n)]


@dataclasses.dataclass
class AdditionTask:
    max_operand: int = 99
    seed: int = 0

    @property
    def prompt_len(self) -> int:
        return 2 * len(str(self.max_operand)) + 3  # BOS a + b =

    @property
    def max_answer_len(self) -> int:
        return len(str(2 * self.max_operand)) + 1  # digits + EOS

    def sample_batch(self, rng: np.random.Generator,
                     batch: int) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (prompts [B, P] int32 padded-left, answers list)."""
        P = self.prompt_len
        prompts = np.full((batch, P), PAD, np.int32)
        answers = np.zeros(batch, np.int64)
        for i in range(batch):
            a = int(rng.integers(0, self.max_operand + 1))
            b = int(rng.integers(0, self.max_operand + 1))
            toks = [BOS] + encode_number(a) + [PLUS] + encode_number(b) + [EQ]
            prompts[i, -len(toks):] = toks
            answers[i] = a + b
        return prompts, answers

    def reward(self, answer: int, generated: np.ndarray) -> float:
        """Digit-level partial credit; 1.0 iff exact answer then EOS."""
        want = encode_number(int(answer)) + [EOS]
        got = list(generated[:len(want)])
        if len(got) < len(want):
            got = got + [PAD] * (len(want) - len(got))
        hits = sum(1 for w, g in zip(want, got) if w == g)
        return hits / len(want)

    def reward_batch(self, answers: np.ndarray,
                     generated: np.ndarray) -> np.ndarray:
        return np.array([self.reward(a, g)
                         for a, g in zip(answers, generated)], np.float32)


class PromptDataset:
    """Iterable prompt stream with epoch shuffling."""

    def __init__(self, task: AdditionTask, batch: int, seed: int = 0):
        self.task = task
        self.batch = batch
        self.rng = np.random.default_rng(seed)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            yield self.task.sample_batch(self.rng, self.batch)


def random_lm_batch(rng_key, batch: int, seq: int, vocab: int) -> Dict:
    """Generic LM batch for throughput/dry-run style benchmarks."""
    k1, k2 = jax.random.split(rng_key)
    tokens = jax.random.randint(k1, (batch, seq), 0, vocab, jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones((batch, seq), jnp.float32)
    return {"tokens": tokens, "labels": labels, "loss_mask": mask}
