"""Backend-aware Pallas execution mode.

Every kernel wrapper defaults its ``interpret`` flag to
``default_interpret()``: compiled Pallas on TPU (the TARGET
configuration), interpreter mode everywhere else (CPU CI, tests).  The
``REPRO_PALLAS_INTERPRET`` environment variable overrides in both
directions ("1"/"true" forces interpret, "0"/"false" forces compiled —
e.g. to smoke-test lowering on a TPU-less build host).
"""
from __future__ import annotations

import os
from typing import Optional

import jax

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def default_interpret() -> bool:
    """True when Pallas kernels should run in interpreter mode."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        low = env.strip().lower()
        if low in _TRUTHY:
            return True
        if low in _FALSY:
            return False
        raise ValueError(
            f"REPRO_PALLAS_INTERPRET={env!r}: expected one of "
            f"{_TRUTHY + _FALSY}")
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Per-call override (tests) or the backend-aware default."""
    return default_interpret() if interpret is None else bool(interpret)
