"""Jitted wrapper: pads S to the chunk multiple with zero k/v (decay of the
padding does not disturb y for real positions since they precede the pad)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_kernel


def rwkv6_scan(r, k, v, logw, u, *, chunk: int = 64,
               interpret: Optional[bool] = None):
    """``interpret=None`` resolves backend-aware outside the jit
    boundary (repro.kernels.backend)."""
    return _rwkv6_scan(r, k, v, logw, u, chunk=chunk,
                       interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _rwkv6_scan(r, k, v, logw, u, *, chunk: int, interpret: bool):
    B, S, H, N = r.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        padt = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r2, k2, v2 = padt(r), padt(k), padt(v)
        logw2 = padt(logw)
    else:
        r2, k2, v2, logw2 = r, k, v, logw
    # padded positions have logw = 0 (decay 1) and k = v = 0, so the state
    # passes through padding untouched — no correction needed.
    y, state = rwkv6_scan_kernel(r2, k2, v2, logw2, u, chunk=c,
                                 interpret=interpret)
    return y[:, :S], state
