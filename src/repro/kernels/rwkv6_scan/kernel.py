"""RWKV6 chunked linear-attention Pallas kernel.

Grid (B, H, n_chunks): for each (batch, head), time chunks iterate
sequentially with the [N, N] wkv state held in VMEM scratch — the
cross-chunk recurrence never touches HBM.  Within a chunk the quadratic
form with cumulative-decay ratios runs on the MXU.

Inputs r,k,v,logw: [B, S, H, N] (fp32, S padded to chunk multiple),
bonus u: [H, N].  Outputs y: [B, S, H, N] and the final state [B, H, N, N].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref,
                 state_scr, *, chunk: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, :, 0, :].astype(jnp.float32)      # [c, N]
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    logw = w_ref[0, :, 0, :].astype(jnp.float32)   # [c, N] (< 0)
    u = u_ref[0, :].astype(jnp.float32)            # [N]
    S = state_scr[...]                             # [N, N]

    cum = jnp.cumsum(logw, axis=0)
    cum_ex = cum - logw
    total = cum[-1]

    # inter-chunk
    y_inter = (r * jnp.exp(cum_ex)) @ S            # [c, N]

    # intra-chunk: att[t, s] = sum_n r[t,n] k[s,n] exp(cum_ex[t,n]-cum[s,n])
    ratio = cum_ex[:, None, :] - cum[None, :, :]   # [c, c, N]
    e = jnp.exp(jnp.minimum(ratio, 0.0))
    att = jnp.sum(r[:, None, :] * k[None, :, :] * e, axis=-1)
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(mask, att, 0.0)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)    # [c]
    y = y_inter + att @ v + diag[:, None] * v
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update
    k_dec = k * jnp.exp(total[None, :] - cum)
    S_new = jnp.exp(total)[:, None] * S + \
        jax.lax.dot_general(k_dec, v, (((0,), (0,)), ((), ())))
    state_scr[...] = S_new

    @pl.when(ci == nc - 1)
    def _finish():
        s_out_ref[0, 0, :, :] = S_new.astype(s_out_ref.dtype)


def rwkv6_scan_kernel(r, k, v, logw, u, *, chunk: int = 64,
                      interpret: bool = True):
    """r/k/v/logw: [B, S, H, N] (S % chunk == 0); u: [H, N].

    Returns (y [B, S, H, N], state [B, H, N, N])."""
    B, S, H, N = r.shape
    assert S % chunk == 0
    nc = S // chunk
    kernel = functools.partial(_rwkv_kernel, chunk=chunk, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, N), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, N), r.dtype),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
