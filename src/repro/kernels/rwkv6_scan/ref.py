"""Pure-jnp oracle: explicit per-token RWKV6 recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, logw, u):
    """r/k/v/logw: [B, S, H, N]; u: [H, N].
    S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  y_t = r_t (diag(u) k_t v_t^T + S_{t-1}).
    Returns (y [B,S,H,N], state [B,H,N,N])."""
    B, S, H, N = r.shape
    f32 = lambda t: t.astype(jnp.float32)
    r, k, v, logw = f32(r), f32(k), f32(v), f32(logw)
    u = u.astype(jnp.float32)

    def step(state, xs):
        r_t, k_t, v_t, w_t = xs                     # [B, H, N]
        kv = k_t[..., :, None] * v_t[..., None, :]  # [B, H, N, N]
        y = jnp.einsum("bhn,bhnm->bhm", r_t,
                       u[None, :, :, None] * kv + state)
        state = jnp.exp(w_t)[..., None] * state + kv
        return state, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, logw))
    state0 = jnp.zeros((B, H, N, N), jnp.float32)
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), state
