"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        cap: Optional[float] = None):
    """q: [B, S, H, hd], k/v: [B, S, KV, hd] -> [B, S, H, hd]."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    Sk = k.shape[1]
    g = H // KV
    qg = q.reshape(B, Sq, KV, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(float(hd))
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= qpos >= kpos
    if window is not None:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)
