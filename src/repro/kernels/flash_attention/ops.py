"""Jitted wrapper: pads (seq -> block multiple, hd -> 128 for MXU
alignment), dispatches to the Pallas kernel, unpads."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _pad_axis(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    cap: Optional[float] = None,
                    bq: int = 128, bk: int = 512,
                    interpret: Optional[bool] = None):
    """``interpret=None`` resolves backend-aware outside the jit
    boundary (repro.kernels.backend)."""
    return _flash_attention(q, k, v, causal=causal, window=window,
                            cap=cap, bq=bq, bk=bk,
                            interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "cap", "bq", "bk", "interpret"))
def _flash_attention(q, k, v, *, causal: bool, window: Optional[int],
                     cap: Optional[float], bq: int, bk: int,
                     interpret: bool):
    B, S, H, hd = q.shape
    Sk = k.shape[1]
    scale_fix = 1.0
    hd_pad = max(-hd % 128 + hd, 128)
    if hd_pad != hd:
        # zero-pad head dim: dot products are unchanged, but the kernel's
        # 1/sqrt(hd_pad) scale must be corrected back to 1/sqrt(hd)
        q = _pad_axis(q, 3, 128) * jnp.asarray(
            (hd_pad / hd) ** 0.5, q.dtype)
        k = _pad_axis(k, 3, 128)
        v = _pad_axis(v, 3, 128)
    qp = _pad_axis(q, 1, bq if S > bq else S)
    kp = _pad_axis(k, 1, bk if Sk > bk else Sk)
    vp = _pad_axis(v, 1, bk if Sk > bk else Sk)
    out = flash_attention_kernel(
        qp, kp, vp, causal=causal, window=window, cap=cap, bq=bq, bk=bk,
        seq_len=Sk, interpret=interpret)
    return out[:, :S, :, :hd]
