"""Flash attention Pallas TPU kernel (prefill / training forward).

Grid (B, H, nq, nk) with the kv-block dimension innermost/sequential;
running max / denominator / accumulator live in VMEM scratch and persist
across kv blocks (the standard TPU flash pattern).  Supports causal
masking, sliding windows, logit soft-capping and GQA (kv head = h // g).

Block shapes are (1, bq, 1, hd) / (1, bk, 1, hd): hd is padded to a
multiple of 128 by ops.py so the MXU matmul dims stay hardware-aligned,
and bq/bk default to 128/512 to keep the working set
(bq*hd + 2*bk*hd + bq*bk floats) inside VMEM (~16 MB/core on v5e).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: Optional[int],
                 cap: Optional[float], bq: int, bk: int, nk: int,
                 seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # [bq, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # [bk, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = k_pos < seq_len                                # padding mask
    if causal:
        ok &= q_pos >= k_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(p, v)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, :, 0, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           cap: Optional[float] = None,
                           bq: int = 128, bk: int = 512,
                           seq_len: Optional[int] = None,
                           interpret: bool = True):
    """q: [B, Sp, H, hd], k/v: [B, Sp, KV, hd] — Sp pre-padded to block
    multiples, hd padded to 128, by ops.py.  seq_len = true length."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    Sk = k.shape[1]
    g = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    nq, nk = Sq // bq, Sk // bk
    seq_len = seq_len or Sk

    kernel = functools.partial(
        _attn_kernel, scale=1.0 / float(hd) ** 0.5, causal=causal,
        window=window, cap=cap, bq=bq, bk=bk, nk=nk, seq_len=seq_len)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h // g, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
