"""Top-level kernel dispatch used by the model layer when
`set_attention_impl("pallas")` is active.  Execution mode is
backend-aware (``repro.kernels.backend``): compiled Pallas on TPU,
interpret mode elsewhere, overridable via REPRO_PALLAS_INTERPRET."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.backend import default_interpret, resolve_interpret
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.flash_attention.ops import flash_attention as _flash
from repro.kernels.mamba_scan.ops import mamba_scan
from repro.kernels.rwkv6_scan.ops import rwkv6_scan


def flash_attention(q, k, v, q_pos, k_pos, *, causal=True, window=None,
                    cap=None, k_valid=None):
    """Adapter matching models.attention.multihead_attention's contract.

    The Pallas kernel assumes contiguous arange positions (training /
    prefill self-attention); anything else falls back to the jnp path."""
    Sq, Sk = q.shape[1], k.shape[1]
    contiguous = (Sq == Sk and q_pos.ndim == 1 and k_valid is None)
    if not contiguous:
        from repro.models import attention as attn
        per_row = (q_pos.ndim > 1 or k_pos.ndim > 1
                   or (k_valid is not None and k_valid.ndim > 1))
        if per_row:
            # ragged per-row positions (chunked prefill): the blockwise
            # jnp path shares bias across rows — use the plain oracle
            return attn.plain_attention(q, k, v, q_pos, k_pos,
                                        causal=causal, window=window,
                                        cap=cap, k_valid=k_valid)
        return attn.chunked_attention(q, k, v, q_pos, k_pos, causal=causal,
                                      window=window, cap=cap,
                                      k_valid=k_valid)
    return _flash(q, k, v, causal=causal, window=window, cap=cap)


def flash_decode_attention(q, k, v, bias, *, cap=None):
    """Single-token (Sq == 1) decode attention over a slot-validity bias.

    q: [B, H, hd]; k/v: [B, L, KV, hd] (GQA via H // KV); bias: [B, L]
    additive (0 = attend, -inf = masked).  Returns [B, H, hd]."""
    return decode_attention(q, k, v, bias, cap=cap)
