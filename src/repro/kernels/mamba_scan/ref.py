"""Pure-jnp oracle: explicit sequential selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(dt, x, Bm, Cm, A):
    """dt/x: [B,S,di]; Bm/Cm: [B,S,ds]; A: [di,ds].
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = h_t . C_t.
    Returns (y [B,S,di], h_end [B,di,ds])."""
    B, S, di = x.shape
    ds = Bm.shape[-1]
    f32 = lambda t: t.astype(jnp.float32)
    dt, x, Bm, Cm = f32(dt), f32(x), f32(Bm), f32(Cm)
    A = A.astype(jnp.float32)

    def step(h, xs):
        dt_t, x_t, b_t, c_t = xs
        da = jnp.exp(dt_t[..., None] * A)              # [B, di, ds]
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = (dt.transpose(1, 0, 2), x.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    h0 = jnp.zeros((B, di, ds), jnp.float32)
    h_end, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype), h_end
