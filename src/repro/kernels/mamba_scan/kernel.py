"""Mamba selective-scan Pallas kernel.

Grid (B, n_di_blocks, n_chunks): the SSM hidden state h [bd, ds] stays in
VMEM scratch across time chunks (chunks iterate innermost); the channel
dimension d_inner is blocked to bd so arbitrarily wide models fit VMEM.
The recurrence is elementwise in d_inner — blocking it is embarrassingly
parallel (this is also why the model shards d_inner over the mesh `model`
axis; DESIGN.md §4).

Inputs: dt, x [B, S, di]; Bm, Cm [B, S, ds]; A [di, ds].
Output: y [B, S, di] = sum_s(h * C) (the D*x skip is applied by ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, y_ref, h_out_ref,
                  h_scr, *, chunk: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    dt = dt_ref[0].astype(jnp.float32)      # [c, bd]
    x = x_ref[0].astype(jnp.float32)        # [c, bd]
    Bm = b_ref[0].astype(jnp.float32)       # [c, ds]
    Cm = c_ref[0].astype(jnp.float32)       # [c, ds]
    A = a_ref[...].astype(jnp.float32)      # [bd, ds]

    def step(t, carry):
        h, ys = carry
        da = jnp.exp(dt[t][:, None] * A)                  # [bd, ds]
        h = da * h + (dt[t] * x[t])[:, None] * Bm[t][None, :]
        y_t = jnp.sum(h * Cm[t][None, :], axis=1)          # [bd]
        ys = jax.lax.dynamic_update_index_in_dim(ys, y_t, t, 0)
        return h, ys

    h0 = h_scr[...]
    ys0 = jnp.zeros((chunk, dt.shape[1]), jnp.float32)
    h_end, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    y_ref[0] = ys.astype(y_ref.dtype)
    h_scr[...] = h_end

    @pl.when(ci == nc - 1)
    def _finish():
        h_out_ref[0] = h_end.astype(h_out_ref.dtype)


def mamba_scan_kernel(dt, x, Bm, Cm, A, *, chunk: int = 64,
                      bd: int = 256, interpret: bool = True):
    """dt/x: [B, S, di]; Bm/Cm: [B, S, ds]; A: [di, ds].

    Returns (y [B, S, di], h_end [B, di, ds])."""
    B, S, di = x.shape
    ds = Bm.shape[-1]
    assert S % chunk == 0 and di % bd == 0
    nc, nd = S // chunk, di // bd
    kernel = functools.partial(_mamba_kernel, chunk=chunk, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, ds), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((bd, ds), lambda b, d, c: (d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, bd, ds), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), x.dtype),
            jax.ShapeDtypeStruct((B, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, ds), jnp.float32)],
        interpret=interpret,
    )(dt, x, Bm, Cm, A)
