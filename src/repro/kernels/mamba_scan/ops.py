"""Jitted wrapper: pads S to chunk multiple (dt=0 padding is exact: decay
exp(0*A)=1 and contribution dt*x=0) and di to the block multiple."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.mamba_scan.kernel import mamba_scan_kernel


def mamba_scan(dt, x, Bm, Cm, A, *, chunk: int = 64, bd: int = 256,
               interpret: Optional[bool] = None):
    """``interpret=None`` resolves backend-aware outside the jit
    boundary (repro.kernels.backend)."""
    return _mamba_scan(dt, x, Bm, Cm, A, chunk=chunk, bd=bd,
                       interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def _mamba_scan(dt, x, Bm, Cm, A, *, chunk: int, bd: int,
                interpret: bool):
    B, S, di = x.shape
    c = min(chunk, S)
    pad_s = (-S) % c
    bd = min(bd, di)
    pad_d = (-di) % bd
    pt = lambda t, ps, pd: jnp.pad(t, ((0, 0), (0, ps), (0, pd)))
    dt2 = pt(dt, pad_s, pad_d)
    x2 = pt(x, pad_s, pad_d)
    Bm2 = pt(Bm, pad_s, 0)
    Cm2 = pt(Cm, pad_s, 0)
    A2 = jnp.pad(A, ((0, pad_d), (0, 0)))
    y, h = mamba_scan_kernel(dt2, x2, Bm2, Cm2, A2, chunk=c, bd=bd,
                             interpret=interpret)
    return y[:, :S, :di], h[:, :di]
