"""Flash-decode Pallas kernel: one query token vs a (possibly ring-buffer)
KV cache, K-blocked online softmax with an explicit slot-validity mask
(the caller derives it from ring positions / causal window).

Grid (B, H, nk); kv blocks iterate innermost with running stats in VMEM
scratch.  GQA via kv head = h // g.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float,
                   cap: Optional[float], nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :].astype(jnp.float32)             # [hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # [bk, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    bias = bias_ref[0, :].astype(jnp.float32)          # [bk] additive mask

    s = (k @ q) * scale                                # [bk]
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    s = s + bias
    s2 = s[None, :]                                    # [1, bk]

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s2, axis=1, keepdims=True))
    p = jnp.exp(s2 - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + p @ v
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0, :] = (
            acc_scr[0] / jnp.maximum(l_scr[0, 0], 1e-30)
        ).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, bias, *, cap: Optional[float] = None,
                            bk: int = 512, interpret: bool = True):
    """q: [B, H, hd]; k/v: [B, L, KV, hd]; bias: [B, L] additive
    (0 = attend, NEG_INF = masked).  Returns [B, H, hd]."""
    B, H, hd = q.shape
    L, KV = k.shape[1], k.shape[2]
    g = H // KV
    bk = min(bk, L)
    assert L % bk == 0
    nk = L // bk
    kernel = functools.partial(_decode_kernel,
                               scale=1.0 / float(hd) ** 0.5,
                               cap=cap, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, j: (b, j, h // g, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, j: (b, j, h // g, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, j: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, bias)
