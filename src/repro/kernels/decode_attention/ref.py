"""Pure-jnp oracle for flash-decode."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, bias, *, cap: Optional[float] = None):
    """q: [B,H,hd]; k/v: [B,L,KV,hd]; bias: [B,L]."""
    B, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, KV, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,blkh->bkgl", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(float(hd))
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    s = s + bias[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgl,blkh->bkgh", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
