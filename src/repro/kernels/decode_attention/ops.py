"""Jitted wrapper for flash-decode (pads hd -> 128, L -> block multiple)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.decode_attention.kernel import decode_attention_kernel

NEG_INF = -1e30


def _pad_axis(x, axis, mult, value=0.0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def decode_attention(q, k, v, bias, *, cap: Optional[float] = None,
                     bk: int = 512, interpret: Optional[bool] = None):
    """q: [B,H,hd]; k/v: [B,L,KV,hd]; bias: [B,L] additive mask.

    ``interpret=None`` resolves backend-aware outside the jit boundary
    (compiled on TPU, interpreter elsewhere; REPRO_PALLAS_INTERPRET
    overrides per call)."""
    return _decode_attention(q, k, v, bias, cap=cap, bk=bk,
                             interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("cap", "bk", "interpret"))
def _decode_attention(q, k, v, bias, *, cap: Optional[float],
                      bk: int, interpret: bool):
    B, H, hd = q.shape
    L = k.shape[1]
    hd_pad = max(hd + (-hd % 128), 128)
    if hd_pad != hd:
        q = _pad_axis(q, 2, 128) * jnp.asarray((hd_pad / hd) ** 0.5, q.dtype)
        k = _pad_axis(k, 3, 128)
        v = _pad_axis(v, 3, 128)
    bk = min(bk, L)
    kp = _pad_axis(k, 1, bk)
    vp = _pad_axis(v, 1, bk)
    biasp = _pad_axis(bias, 1, bk, value=NEG_INF)
    out = decode_attention_kernel(q, kp, vp, biasp, cap=cap, bk=bk,
                                  interpret=interpret)
    return out[:, :, :hd]
