"""Checkpointing: msgpack-serialized pytrees (no orbax offline).

Supports periodic saves during RL training — the paper leans on this for
online redeployment (§6: reschedule at checkpoint boundaries).

Integrity: every checkpoint wraps the packed payload with a crc32
content checksum, verified on restore.  ``restore`` raises a clear
``CheckpointError`` for truncated / corrupt / mismatched files instead
of a msgpack stack trace; ``load_latest`` walks a directory newest-first
and falls back past damaged files.  Pre-checksum checkpoints (payload
packed directly, no wrapper) still load.
"""
from __future__ import annotations

import os
import warnings
import zlib
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


class CheckpointError(Exception):
    """A checkpoint file is corrupt, truncated, or shape-mismatched."""


def _pack_leaf(x):
    x = np.asarray(x)
    return {b"dtype": str(x.dtype).encode(),
            b"shape": list(x.shape),
            b"data": x.tobytes()}


def _unpack_leaf(d):
    arr = np.frombuffer(d[b"data"], dtype=np.dtype(d[b"dtype"].decode()))
    return jnp.asarray(arr.reshape(d[b"shape"]))


def save(path: str, tree: Any, *, retain: int = 0) -> int:
    """Write ``tree`` atomically (tmp + rename) with a crc32 checksum
    over the packed payload.  ``retain > 0`` additionally prunes the
    checkpoint directory down to the ``retain`` newest files sharing
    this checkpoint's prefix-up-to-digits naming. Returns bytes written.
    """
    flat, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        b"treedef": str(treedef).encode(),
        b"leaves": [_pack_leaf(x) for x in flat],
    }
    inner = msgpack.packb(payload)
    blob = msgpack.packb({b"crc32": zlib.crc32(inner), b"payload": inner})
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
    os.replace(tmp, path)
    if retain > 0:
        retain_last(os.path.dirname(os.path.abspath(path)), retain)
    return len(blob)


def _read_payload(path: str) -> dict:
    """Read + verify a checkpoint file, returning the unpacked payload
    dict.  Raises CheckpointError on any damage."""
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except OSError as e:
        raise CheckpointError(f"{path}: unreadable ({e})") from e
    if not raw:
        raise CheckpointError(f"{path}: empty file")
    try:
        outer = msgpack.unpackb(raw)
    except Exception as e:
        raise CheckpointError(f"{path}: truncated or not msgpack "
                              f"({type(e).__name__})") from e
    if isinstance(outer, dict) and b"payload" in outer:
        inner = outer[b"payload"]
        crc = zlib.crc32(inner)
        if crc != outer.get(b"crc32"):
            raise CheckpointError(
                f"{path}: checksum mismatch (stored "
                f"{outer.get(b'crc32')}, computed {crc}) — corrupt")
        try:
            payload = msgpack.unpackb(inner)
        except Exception as e:
            raise CheckpointError(f"{path}: corrupt payload "
                                  f"({type(e).__name__})") from e
    elif isinstance(outer, dict) and b"leaves" in outer:
        payload = outer  # legacy pre-checksum format
    else:
        raise CheckpointError(f"{path}: unrecognized checkpoint format")
    if b"leaves" not in payload:
        raise CheckpointError(f"{path}: payload missing leaves")
    return payload


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (treedef source of truth)."""
    payload = _read_payload(path)
    try:
        leaves = [_unpack_leaf(d) for d in payload[b"leaves"]]
    except Exception as e:
        raise CheckpointError(f"{path}: corrupt leaf encoding "
                              f"({type(e).__name__})") from e
    flat, treedef = jax.tree_util.tree_flatten(like)
    if len(flat) != len(leaves):
        raise CheckpointError(
            f"{path}: checkpoint has {len(leaves)} leaves, "
            f"model has {len(flat)}")
    restored = [l.astype(x.dtype).reshape(x.shape)
                for l, x in zip(leaves, flat)]
    return jax.tree_util.tree_unflatten(treedef, restored)


def _checkpoint_files(dirpath: str) -> List[str]:
    """Checkpoint files in ``dirpath``, oldest→newest.  Zero-padded
    iteration numbers in the names make lexicographic == chronological;
    mtime breaks ties for mixed naming schemes."""
    try:
        names = [n for n in os.listdir(dirpath)
                 if n.endswith(".msgpack") and not n.endswith(".tmp")]
    except OSError:
        return []
    paths = [os.path.join(dirpath, n) for n in names]
    return sorted(paths, key=lambda p: (os.path.basename(p),))


def retain_last(dirpath: str, keep: int) -> List[str]:
    """Delete all but the ``keep`` newest checkpoints in ``dirpath``.
    Returns the paths removed."""
    files = _checkpoint_files(dirpath)
    removed = []
    for p in files[:max(0, len(files) - keep)]:
        try:
            os.remove(p)
            removed.append(p)
        except OSError:
            pass
    return removed


def load_latest(dirpath: str, like: Any) -> Tuple[Any, str]:
    """Restore the newest loadable checkpoint in ``dirpath``, skipping
    corrupt / truncated files with a warning.  Returns ``(tree, path)``;
    raises CheckpointError listing every file tried if none loads."""
    files = _checkpoint_files(dirpath)
    if not files:
        raise CheckpointError(f"{dirpath}: no checkpoint files")
    errors: List[str] = []
    for path in reversed(files):
        try:
            return restore(path, like), path
        except CheckpointError as e:
            warnings.warn(f"skipping checkpoint: {e}", RuntimeWarning,
                          stacklevel=2)
            errors.append(str(e))
    raise CheckpointError(
        f"{dirpath}: no loadable checkpoint among {len(files)} files:\n  "
        + "\n  ".join(errors))
