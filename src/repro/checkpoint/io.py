"""Checkpointing: msgpack-serialized pytrees (no orbax offline).

Supports periodic saves during RL training — the paper leans on this for
online redeployment (§6: reschedule at checkpoint boundaries)."""
from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x):
    x = np.asarray(x)
    return {b"dtype": str(x.dtype).encode(),
            b"shape": list(x.shape),
            b"data": x.tobytes()}


def _unpack_leaf(d):
    arr = np.frombuffer(d[b"data"], dtype=np.dtype(d[b"dtype"].decode()))
    return jnp.asarray(arr.reshape(d[b"shape"]))


def save(path: str, tree: Any) -> int:
    """Returns bytes written."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        b"treedef": str(treedef).encode(),
        b"leaves": [_pack_leaf(x) for x in flat],
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    blob = msgpack.packb(payload)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
    os.replace(tmp, path)
    return len(blob)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (treedef source of truth)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    leaves = [_unpack_leaf(d) for d in payload[b"leaves"]]
    flat, treedef = jax.tree_util.tree_flatten(like)
    assert len(flat) == len(leaves), \
        f"checkpoint has {len(leaves)} leaves, model has {len(flat)}"
    restored = [l.astype(x.dtype).reshape(x.shape)
                for l, x in zip(leaves, flat)]
    return jax.tree_util.tree_unflatten(treedef, restored)
