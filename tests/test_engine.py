"""Plan-driven execution engine tests.

- engine-vs-simulator parity: same (workflow, plan) → the engine's
  measured timeline has the same stage ordering and colocation
  serialization as ``simulate``'s predicted timeline;
- plan-driven serialization: colocate-all plans serialize every task,
  disaggregated plans start disjoint groups simultaneously;
- async pipeline: iteration-t+1 generation uses pre-sync weights
  (one-step off-policy staleness is exactly one weight version).
"""
import jax
import numpy as np
import pytest

from repro.core import enumerate as enum_mod, simulator, topology, workflow
from repro.core.costmodel import CostModel
from repro.core.plan import check_constraints
from repro.data.synthetic import AdditionTask, VOCAB_SIZE
from repro.engine import placement as placement_mod
from repro.rl.trainer import RLConfig, RLTrainer

KEY = jax.random.PRNGKey(0)


def tiny_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="eng-tiny", n_layers=2, d_model=64, n_heads=2,
                       n_kv_heads=2, head_dim=32, d_ff=128,
                       vocab_size=VOCAB_SIZE, dtype="float32")


def disaggregated_setup(algorithm="grpo", asynchronous=False):
    """Trainer driven by a gen|rest plan on the 8-GPU reference pool."""
    cfg = tiny_cfg()
    task = AdditionTask(max_operand=9)
    rl = RLConfig(algorithm=algorithm, n_rollouts=4, max_new_tokens=4,
                  asynchronous=asynchronous)
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})
    spec = workflow.LLMSpec.from_model_config(cfg)
    wf = workflow.make_workflow(algorithm, spec,
                                synchronous=not asynchronous,
                                n_rollouts=rl.n_rollouts,
                                seq_in=task.prompt_len,
                                seq_out=rl.max_new_tokens, global_batch=1)
    grouping = next(g for g in enum_mod.priority_groupings(wf)
                    if len(g) == 2 and any(
                        wf.task(t).kind == workflow.TaskKind.GEN
                        for t in min(g, key=len)))
    sizes = enum_mod.proportional_sizes(wf, grouping, topo.n)
    plan = enum_mod.build_plan(topo, wf, grouping, sizes,
                               list(range(topo.n)))
    ok, msg = check_constraints(topo, wf, plan)
    assert ok, msg
    trainer = RLTrainer(cfg, rl, task, KEY, plan=plan, topo=topo, wf=wf)
    return trainer, topo, plan


def run_iters(trainer, n, batch=4, seed=0):
    task = trainer.task
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(7)
    out = []
    for _ in range(n):
        prompts, answers = task.sample_batch(rng, batch)
        key, k = jax.random.split(key)
        out.append(trainer.iteration(prompts, answers, k))
    return out


def device_sequences(timeline, plan):
    """Per plan-device sequence of (iteration, task) starts, time order."""
    seq = {}
    for e in sorted(timeline, key=lambda e: (e.time, e.iteration, e.task)):
        if e.kind != "start":
            continue
        for d in plan.assignment[e.task].reshape(-1):
            seq.setdefault(int(d), []).append((e.iteration, e.task))
    return seq


def intervals(timeline):
    """(iteration, task) -> (start, end)."""
    out = {}
    for e in timeline:
        k = (e.iteration, e.task)
        s, t = out.get(k, (None, None))
        out[k] = (e.time, t) if e.kind == "start" else (s, e.time)
    return out


def test_engine_simulator_parity():
    trainer, topo, plan = disaggregated_setup()
    n_iters = 4
    run_iters(trainer, n_iters)
    engine = trainer.engine
    sim = simulator.simulate(topo, trainer.wf, plan, n_iterations=n_iters)

    # same event set
    meas = engine.measured_result()
    assert {(e.iteration, e.task, e.kind) for e in meas.timeline} == \
        {(e.iteration, e.task, e.kind) for e in sim.timeline}

    # per-device start ordering identical (colocation serialization and
    # stage ordering match the simulator's schedule exactly)
    assert device_sequences(meas.timeline, plan) == \
        device_sequences(sim.timeline, plan)

    # dependencies respected in the measured timeline
    iv = intervals(meas.timeline)
    for it in range(n_iters):
        for t in range(trainer.wf.n_tasks):
            for d in trainer.wf.task(t).depends_on:
                assert iv[(it, t)][0] >= iv[(it, d)][1] - 1e-12

    # disjoint groups start concurrently in BOTH timelines: reward and
    # reference inference depend only on generation and sit in different
    # scheduling lanes than... (reward shares the non-gen group here, so
    # compare generation of iter t+1 overlapping nothing in sync mode)
    siv = intervals(sim.timeline)
    for it in range(n_iters):
        # both inference tasks become ready when generation ends
        assert iv[(it, 1)][0] >= iv[(it, 0)][1] - 1e-12
        assert siv[(it, 1)][0] >= siv[(it, 0)][1] - 1e-12


def test_colocated_plan_serializes_everything():
    cfg = tiny_cfg()
    task = AdditionTask(max_operand=9)
    rl = RLConfig(algorithm="grpo", n_rollouts=4, max_new_tokens=4)
    trainer = RLTrainer(cfg, rl, task, KEY)   # default colocate-all plan
    run_iters(trainer, 2)
    iv = intervals(trainer.engine.measured_result().timeline)
    keys = sorted(iv)
    for a in keys:
        for b in keys:
            if a >= b:
                continue
            s1, e1 = iv[a]
            s2, e2 = iv[b]
            assert e1 <= s2 + 1e-12 or e2 <= s1 + 1e-12, \
                f"colocated tasks {a} and {b} overlap"


def test_disaggregated_plan_overlaps_groups():
    """The plan demonstrably changes execution: with gen | rest groups,
    inference starts exactly when generation ends (its only dependency),
    not later — there is no whole-pool barrier between the groups — and
    the colocated reward/reference pair serializes back-to-back inside
    its lane."""
    trainer, topo, plan = disaggregated_setup()
    run_iters(trainer, 3)
    iv = intervals(trainer.engine.measured_result().timeline)
    gen_devs = {int(d) for d in plan.assignment[0].reshape(-1)}
    inf_devs = {int(d) for d in plan.assignment[1].reshape(-1)}
    assert not gen_devs & inf_devs
    for it in range(3):
        s1, e1 = iv[(it, 1)]
        s2, e2 = iv[(it, 2)]
        # reward becomes ready at gen end and its (disjoint) device
        # group is idle by then: any later start would be a barrier
        assert s1 == pytest.approx(iv[(it, 0)][1], abs=1e-9)
        # colocated lane: reference starts exactly at reward end
        assert s2 == pytest.approx(e1, abs=1e-9)
        assert e1 <= s2 + 1e-12 or e2 <= s1 + 1e-12


def test_async_generation_uses_pre_sync_weights():
    trainer, topo, plan = disaggregated_setup(asynchronous=True)
    metrics = run_iters(trainer, 4)
    assert metrics[0].get("pipeline_fill") == 1.0
    assert all("pipeline_fill" not in m for m in metrics[1:])
    recs = trainer.engine.pipeline.records
    assert len(recs) == 3          # fill iteration trains nothing
    # iteration 1 trains the fill rollouts (version 0, no sync yet)
    assert (recs[0].gen_version, recs[0].weight_version) == (0, 0)
    # steady state: the trained rollouts are exactly one sync behind
    for r in recs[1:]:
        assert r.weight_version - r.gen_version == 1, r
    # and the weight version advances once per trained iteration
    assert trainer.weight_version == 3


def test_sync_mode_trains_fresh_rollouts():
    trainer, topo, plan = disaggregated_setup(asynchronous=False)
    run_iters(trainer, 2)
    for r in trainer.engine.pipeline.records:
        assert r.gen_version == r.weight_version


def test_measured_vs_predicted_comparison():
    trainer, topo, plan = disaggregated_setup()
    run_iters(trainer, 4)
    cmp = trainer.engine.compare_with_simulator()
    assert cmp["measured_iter_s"] > 0
    assert cmp["predicted_iter_s"] > 0
    assert np.isfinite(cmp["ratio"])


def test_device_folding_deterministic():
    local = ["devA", "devB", "devC"]
    folded = placement_mod.fold_devices([0, 1, 2, 3, 4, 5, 6, 7], local)
    assert folded == ["devA", "devB", "devC"]
    assert placement_mod.fold_devices([5, 1], local) == ["devC", "devB"]
    # colliding plan ids collapse to one real device
    assert placement_mod.fold_devices([5, 2], local) == ["devC"]
    # stable: same plan devices -> same folding
    assert placement_mod.fold_devices([5, 1], local) == \
        placement_mod.fold_devices([5, 1], local)


def test_placement_mesh_axes():
    trainer, topo, plan = disaggregated_setup()
    for t, pl in trainer.engine.placements.items():
        assert pl.mesh.axis_names == ("data", "model")
        n = len(pl.local_devices)
        assert int(np.prod(pl.mesh_shape)) == n
        assert n <= jax.device_count()


def test_trainer_rejects_mismatched_plan():
    cfg = tiny_cfg()
    task = AdditionTask(max_operand=9)
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})
    spec = workflow.LLMSpec.from_model_config(cfg)
    ppo_wf = workflow.make_ppo(spec, global_batch=1)
    grouping = (tuple(range(ppo_wf.n_tasks)),)
    plan = enum_mod.build_plan(topo, ppo_wf, grouping, [topo.n],
                               list(range(topo.n)))
    rl = RLConfig(algorithm="grpo", n_rollouts=4, max_new_tokens=4)
    with pytest.raises(ValueError):
        RLTrainer(cfg, rl, task, KEY, plan=plan, topo=topo)
