"""Hypothesis property-based tests on system invariants."""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import enumerate as enum_mod, topology, workflow
from repro.core.costmodel import CostModel, ring_cost
from repro.core.plan import check_constraints
from repro.optim import adam
from repro.parallel.sharding import sanitize_spec
from repro.rl import gae

hp.settings.register_profile("ci", deadline=None, max_examples=25)
hp.settings.load_profile("ci")


@hp.given(st.integers(1, 7))
def test_set_partitions_are_partitions(n):
    parts = enum_mod.set_partitions(range(n))
    seen = set()
    for p in parts:
        assert p not in seen
        seen.add(p)
        flat = sorted(x for b in p for x in b)
        assert flat == list(range(n))


@hp.given(st.integers(2, 6), st.integers(6, 64))
def test_proportional_sizes_cover(n_groups, n_devices):
    hp.assume(n_devices >= n_groups)
    wf = workflow.make_ppo(workflow.QWEN_4B)
    groupings = [g for g in enum_mod.task_groupings(wf)
                 if len(g) == n_groups]
    hp.assume(groupings)
    sizes = enum_mod.proportional_sizes(wf, groupings[0], n_devices)
    assert sum(sizes) == n_devices
    assert all(s >= 1 for s in sizes)


@hp.given(st.lists(st.integers(1, 500), min_size=1, max_size=4),
          st.lists(st.sampled_from([None, "data", "model",
                                    ("data", "model")]),
                   min_size=0, max_size=4))
def test_sanitize_spec_always_divisible(shape, entries):
    mesh_sizes = {"data": 16, "model": 16}

    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)
    spec = sanitize_spec(P(*entries), tuple(shape), FakeMesh)
    for d, entry in enumerate(list(spec)[:len(shape)]):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            prod *= mesh_sizes[a]
        assert shape[d] % prod == 0


@hp.given(st.integers(2, 8), st.floats(1e3, 1e12))
def test_ring_cost_positive_and_bounded(n, cv):
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 8})
    devs = list(range(n))
    c = ring_cost(topo, devs, cv)
    assert c >= 0
    # bounded below by best single edge, above by worst edge cost * 1
    worst = max(topo.alpha(a, b) + cv / (topo.beta(a, b) * 1e9)
                for a in devs for b in devs if a != b)
    assert c <= worst + 1e-9


@hp.given(st.integers(0, 10_000))
def test_adam_lr_schedule_bounds(step):
    cfg = adam.AdamConfig(lr=1e-3, warmup_steps=100, total_steps=1000)
    lr = float(adam.schedule_lr(cfg, jnp.asarray(step)))
    assert 0.0 <= lr <= cfg.lr * (1 + 1e-5)  # f32 rounding headroom


@hp.given(st.integers(1, 4), st.integers(2, 10))
def test_gae_zero_rewards_zero_values(B, T):
    z = jnp.zeros((B, T))
    mask = jnp.ones((B, T))
    adv, ret = gae.gae_advantages(z, z, mask)
    assert float(jnp.abs(adv).max()) == 0.0
    assert float(jnp.abs(ret).max()) == 0.0


@hp.given(st.integers(0, 3))
def test_plans_from_seeds_satisfy_constraints_or_flag_oom(seed):
    """Any plan the EA decodes is either feasible or flagged OOM — never
    structurally invalid."""
    from repro.core.ea import EvolutionarySearch
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})
    wf = workflow.make_grpo(workflow.QWEN_1_7B, global_batch=64)
    grouping = enum_mod.priority_groupings(wf)[seed % 4]
    sizes = enum_mod.proportional_sizes(wf, grouping, topo.n)
    ea = EvolutionarySearch(topo, wf, grouping, sizes, seed=seed)
    for _ in range(3):
        ind = ea.mutate(ea._random_individual())
        plan = ea.decode(ea.local_search(ind))
        ok, msg = check_constraints(topo, wf, plan)
        assert ok or msg.startswith("OOM"), msg


@hp.given(st.floats(0.0, 1.0))
def test_eta_interpolates_phi(eta):
    """Scalar-η Φ must land between full-parallel (max) and sequential
    (sum) compositions."""
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})
    wf = workflow.make_grpo(workflow.QWEN_1_7B, global_batch=64)
    grouping = (tuple(range(wf.n_tasks)),)
    plan = enum_mod.build_plan(topo, wf, grouping, [topo.n],
                               list(range(topo.n)))
    c_par = CostModel(topo, wf, eta=1.0).cost(plan)
    c_seq = CostModel(topo, wf, eta=0.0).cost(plan)
    c_mid = CostModel(topo, wf, eta=eta).cost(plan)
    assert c_par <= c_mid + 1e-9
    assert c_mid <= c_seq + 1e-9
