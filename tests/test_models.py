"""Model-component unit tests: attention paths agree, MoE conservation,
chunked scans are chunk-size invariant, caches, rope, norms."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models import cache as cache_mod
from repro.models import layers, mamba, moe, rwkv
from repro.models.config import FFN, LayerSpec, Mixer, ModelConfig

KEY = jax.random.PRNGKey(3)


def test_chunked_attention_matches_plain():
    B, S, H, KV, hd = 2, 200, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    pos = jnp.arange(S)
    for kwargs in [dict(causal=True), dict(causal=True, window=32),
                   dict(causal=False), dict(causal=True, cap=20.0)]:
        plain = attn.plain_attention(q, k, v, pos, pos,
                                     window=kwargs.get("window"),
                                     causal=kwargs.get("causal", True),
                                     cap=kwargs.get("cap"))
        chunked = attn.chunked_attention(q, k, v, pos, pos,
                                         window=kwargs.get("window"),
                                         causal=kwargs.get("causal", True),
                                         cap=kwargs.get("cap"),
                                         q_chunk=64, k_chunk=64)
        np.testing.assert_allclose(np.asarray(plain), np.asarray(chunked),
                                   rtol=1e-4, atol=1e-4)


def test_rope_preserves_norm_and_relativity():
    B, S, H, hd = 1, 16, 2, 64
    x = jax.random.normal(KEY, (B, S, H, hd))
    pos = jnp.arange(S)
    r = layers.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(r), axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i - j
    q = jax.random.normal(jax.random.PRNGKey(5), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(6), (1, 1, 1, hd))
    def dot_at(i, j):
        qi = layers.apply_rope(q, jnp.asarray([i]), 10_000.0)
        kj = layers.apply_rope(k, jnp.asarray([j]), 10_000.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-3


def test_rmsnorm_scale_invariance():
    x = jax.random.normal(KEY, (2, 8, 32))
    p = layers.init_rmsnorm(32)
    y1 = layers.rmsnorm(p, x)
    y2 = layers.rmsnorm(p, x * 100.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = layers.softcap(x, 50.0)
    assert float(jnp.max(jnp.abs(y))) <= 50.0
    np.testing.assert_allclose(np.asarray(layers.softcap(x, None)),
                               np.asarray(x))


def _moe_cfg(cf=1.25):
    return ModelConfig(name="m", n_layers=1, d_model=32, n_heads=2,
                       n_kv_heads=2, d_ff=64, vocab_size=64,
                       pattern=(LayerSpec(Mixer.ATTENTION, FFN.SWIGLU,
                                          moe=True),),
                       n_experts=4, top_k=2, capacity_factor=cf,
                       dtype="float32")


def test_moe_matches_dense_expert_sum_at_high_capacity():
    """With capacity high enough for zero drops, the sort/scatter dispatch
    must equal the dense (all-experts) weighted computation."""
    cfg = _moe_cfg(cf=8.0)
    p = moe.init_moe(KEY, cfg, FFN.SWIGLU, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 16, cfg.d_model))
    y, aux = moe.apply_moe(p, cfg, FFN.SWIGLU, x)
    # dense reference
    T = 2 * 16
    xt = x.reshape(T, cfg.d_model)
    gates = jax.nn.softmax((xt @ p["router"]).astype(jnp.float32), -1)
    top_w, top_e = jax.lax.top_k(gates, cfg.top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    expert_out = jnp.stack([
        layers.apply_ffn(jax.tree_util.tree_map(lambda w: w[e],
                                                p["experts"]),
                         cfg, FFN.SWIGLU, xt)
        for e in range(cfg.n_experts)], axis=1)        # [T, E, d]
    ref = jnp.zeros_like(xt)
    for kk in range(cfg.top_k):
        ref += top_w[:, kk:kk + 1] * jnp.take_along_axis(
            expert_out, top_e[:, kk][:, None, None].repeat(
                cfg.d_model, axis=2), axis=1)[:, 0]
    np.testing.assert_allclose(np.asarray(y.reshape(T, -1)),
                               np.asarray(ref), rtol=1e-4, atol=1e-4)
    assert float(aux) >= 0


def test_moe_capacity_drops_bounded():
    cfg = _moe_cfg(cf=0.5)  # forced drops
    p = moe.init_moe(KEY, cfg, FFN.SWIGLU, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, _ = moe.apply_moe(p, cfg, FFN.SWIGLU, x)
    assert bool(jnp.isfinite(y).all())


@pytest.mark.parametrize("chunks", [(8, 64), (16, 32)])
def test_mamba_scan_chunk_invariance(chunks):
    cfg = ModelConfig(name="mm", n_layers=1, d_model=64, n_heads=0,
                      n_kv_heads=0, d_ff=128, vocab_size=64,
                      pattern=(LayerSpec(Mixer.MAMBA, FFN.SWIGLU),),
                      dtype="float32")
    B, S, di, ds = 2, 96, cfg.mamba_d_inner, cfg.mamba_d_state
    ks = jax.random.split(KEY, 4)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di)))
    Bm = jax.random.normal(ks[1], (B, S, ds))
    Cm = jax.random.normal(ks[2], (B, S, ds))
    x = jax.random.normal(ks[3], (B, S, di))
    A = -jnp.exp(jax.random.normal(KEY, (di, ds)) * 0.3)
    D = jnp.ones((di,))
    y1, h1 = mamba.ssm_scan(dt, Bm, Cm, x, A, D, None, chunk=chunks[0])
    y2, h2 = mamba.ssm_scan(dt, Bm, Cm, x, A, D, None, chunk=chunks[1])
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4,
                               atol=1e-4)


def test_rwkv_scan_chunk_invariance():
    B, S, H, N = 2, 96, 2, 32
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, N))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) * 0.3 - 1)
    u = jax.random.normal(ks[4], (H, N)) * 0.5
    y1, s1 = rwkv.rwkv_scan(r, k, v, logw, u, None, chunk=16)
    y2, s2 = rwkv.rwkv_scan(r, k, v, logw, u, None, chunk=48)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-3,
                               atol=2e-3)


def test_ring_buffer_positions():
    # full cache
    k_pos, valid = cache_mod.ring_slot_positions(8, None, jnp.asarray(5))
    np.testing.assert_array_equal(np.asarray(k_pos), np.arange(8))
    np.testing.assert_array_equal(np.asarray(valid),
                                  np.arange(8) <= 5)
    # ring cache W=4 at pos=6: slots hold positions [4, 5, 6, 3]
    k_pos, valid = cache_mod.ring_slot_positions(4, 4, jnp.asarray(6))
    np.testing.assert_array_equal(np.asarray(k_pos), [4, 5, 6, 3])
    assert bool(valid.all())


def test_ring_buffer_positions_per_slot():
    """Vector pos -> per-row [B, L] positions/validity, row i equal to
    the scalar call at pos[i] (the batched wave-decode contract)."""
    pos = jnp.asarray([2, 6, 0])
    for window, L in ((None, 8), (4, 4)):
        k_pos, valid = cache_mod.ring_slot_positions(L, window, pos)
        assert k_pos.shape == valid.shape == (3, L)
        for i, p in enumerate([2, 6, 0]):
            kp_i, v_i = cache_mod.ring_slot_positions(L, window,
                                                      jnp.asarray(p))
            np.testing.assert_array_equal(np.asarray(k_pos[i]),
                                          np.asarray(kp_i))
            np.testing.assert_array_equal(np.asarray(valid[i]),
                                          np.asarray(v_i))


def test_write_kv_per_row_positions():
    """Batched write_kv: row i writes at its own slot (ring and full)."""
    B, L, KV, hd = 3, 4, 1, 2
    ks = jax.random.split(KEY, 2)
    k_new = jax.random.normal(ks[0], (B, 1, KV, hd))
    v_new = jax.random.normal(ks[1], (B, 1, KV, hd))
    pos = jnp.asarray([1, 6, 3])
    for window in (4, None):
        ck = jnp.zeros((B, L, KV, hd))
        cv = jnp.zeros((B, L, KV, hd))
        ck, cv = cache_mod.write_kv(ck, cv, k_new, v_new, pos, window)
        for i, p in enumerate([1, 6, 3]):
            ck_i, cv_i = cache_mod.write_kv(
                jnp.zeros((1, L, KV, hd)), jnp.zeros((1, L, KV, hd)),
                k_new[i:i + 1], v_new[i:i + 1], jnp.asarray(p), window)
            np.testing.assert_array_equal(np.asarray(ck[i]),
                                          np.asarray(ck_i[0]))
            np.testing.assert_array_equal(np.asarray(cv[i]),
                                          np.asarray(cv_i[0]))


@pytest.mark.parametrize("window", [None, 4])
def test_write_kv_chunk_at_per_row_positions(window):
    """Chunked write_kv (Sq > 1): a C-token chunk at per-row start
    positions equals C sequential single-token writes — including ring
    wraparound within a chunk and a partial validity mask."""
    B, L, C, KV, hd = 3, 4, 6, 1, 2
    ks = jax.random.split(KEY, 2)
    k_new = jax.random.normal(ks[0], (B, C, KV, hd))
    v_new = jax.random.normal(ks[1], (B, C, KV, hd))
    pos = np.array([0, 3, 5])
    n_valid = np.array([6, 4, 2])
    valid = jnp.asarray(np.arange(C)[None] < n_valid[:, None])
    ck = jax.random.normal(jax.random.PRNGKey(9), (B, L, KV, hd))
    cv = jax.random.normal(jax.random.PRNGKey(10), (B, L, KV, hd))
    got_k, got_v = cache_mod.write_kv(ck, cv, k_new, v_new,
                                      jnp.asarray(pos), window, valid=valid)
    # reference: token-by-token writes per row (ring: sequential, last
    # wins; full cache: first past-the-end token holds the final slot —
    # the keep-first-L prefill truncation)
    want_k, want_v = np.array(ck), np.array(cv)
    for b in range(B):
        wrote_end = False
        for j in range(int(n_valid[b])):
            p = int(pos[b]) + j
            if window is not None:
                slot = p % L
            else:
                slot = min(p, L - 1)
                if p >= L - 1:
                    if wrote_end:
                        continue
                    wrote_end = True
            want_k[b, slot] = np.asarray(k_new[b, j])
            want_v[b, slot] = np.asarray(v_new[b, j])
    np.testing.assert_array_equal(np.asarray(got_k), want_k)
    np.testing.assert_array_equal(np.asarray(got_v), want_v)


def test_prefill_kv_is_write_kv_chunk():
    """The one-shot prefill layout is the position-0 chunk write: full
    caches keep the first L tokens, ring caches the last L."""
    B, S, KV, hd = 2, 7, 1, 2
    k = jax.random.normal(KEY, (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(5), (B, S, KV, hd))
    for window, L in ((None, 5), (4, 4), (None, 9)):
        ck, cv = cache_mod.prefill_kv(jnp.zeros((B, L, KV, hd)),
                                      jnp.zeros((B, L, KV, hd)), k, v,
                                      window)
        if window is None:
            n = min(S, L)
            np.testing.assert_array_equal(np.asarray(ck[:, :n]),
                                          np.asarray(k[:, :n]))
            np.testing.assert_array_equal(np.asarray(cv[:, :n]),
                                          np.asarray(v[:, :n]))
        else:
            for p in range(max(S - L, 0), S):
                np.testing.assert_array_equal(np.asarray(ck[:, p % L]),
                                              np.asarray(k[:, p]))
                np.testing.assert_array_equal(np.asarray(cv[:, p % L]),
                                              np.asarray(v[:, p]))


@pytest.mark.parametrize("window,cap,KV", [
    (None, None, 2), (4, None, 2), (None, 30.0, 1), (6, 20.0, 4),
])
def test_sq1_flash_decode_dispatch_matches_jnp(window, cap, KV):
    """Sq == 1 under the pallas impl routes to the flash-decode kernel
    and agrees with the jnp reference path — including per-slot ragged
    positions, ring-window validity, GQA and softcap."""
    B, L, H, hd = 3, 8, 4, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, L, KV, hd))
    v = jax.random.normal(ks[2], (B, L, KV, hd))
    pos = jnp.asarray([2, 7, 5])
    q_pos = pos[:, None]
    k_pos, valid = cache_mod.ring_slot_positions(L, window, pos)
    kwargs = dict(causal=True, window=window, cap=cap, k_valid=valid)
    ref = attn.multihead_attention(q, k, v, q_pos, k_pos,
                                   force_impl="jnp", **kwargs)
    out = attn.multihead_attention(q, k, v, q_pos, k_pos,
                                   force_impl="pallas", **kwargs)
    assert out.shape == (B, 1, H, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_sq1_dispatch_invokes_decode_kernel(monkeypatch):
    """The Sq == 1 pallas dispatch must hit kernels.decode_attention,
    not the flash (prefill) kernel."""
    from repro.kernels import ops as kernel_ops
    calls = []
    real = kernel_ops.flash_decode_attention
    monkeypatch.setattr(
        kernel_ops, "flash_decode_attention",
        lambda *a, **kw: calls.append("decode") or real(*a, **kw))
    monkeypatch.setattr(
        kernel_ops, "flash_attention",
        lambda *a, **kw: calls.append("flash"))
    B, L, H, hd = 2, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, L, 2, hd))
    v = jax.random.normal(ks[2], (B, L, 2, hd))
    k_pos, valid = cache_mod.ring_slot_positions(L, None, jnp.asarray(3))
    out = attn.multihead_attention(q, k, v, jnp.full((1,), 3), k_pos,
                                   force_impl="pallas", k_valid=valid)
    assert calls == ["decode"] and out.shape == (B, 1, H, hd)


def test_effective_window_long_mode():
    cfg = ModelConfig(name="g", n_layers=2, d_model=64, n_heads=2,
                      n_kv_heads=2, d_ff=128, vocab_size=64,
                      pattern=(LayerSpec(window=16), LayerSpec(window=None)),
                      long_mode_window=32, dtype="float32")
    local, glob = cfg.pattern
    assert cache_mod.effective_window(cfg, local, False) == 16
    assert cache_mod.effective_window(cfg, glob, False) is None
    assert cache_mod.effective_window(cfg, glob, True) == 32
    assert cfg.supports_long_context()


def test_moe_dense_decode_matches_dispatch():
    """The S=1 dense-decode path must agree with the grouped dispatch
    (high capacity => no drops)."""
    import jax
    import jax.numpy as jnp
    cfg = _moe_cfg(cf=8.0)
    p = moe.init_moe(jax.random.PRNGKey(2), cfg, FFN.SWIGLU, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 1, cfg.d_model))
    y_dense, _ = moe._dense_decode_moe(p, cfg, FFN.SWIGLU, x)
    # grouped path on the same tokens laid out as one row of S=8
    y_grouped, _ = moe.apply_moe(p, cfg, FFN.SWIGLU,
                                 x.reshape(1, 8, cfg.d_model))
    np.testing.assert_allclose(np.asarray(y_dense.reshape(8, -1)),
                               np.asarray(y_grouped.reshape(8, -1)),
                               rtol=2e-4, atol=2e-4)
