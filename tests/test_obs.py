"""Observability layer tests (``repro.obs``).

- span tracer: nesting, exception safety, disabled no-op path;
- Chrome-trace export round-trip: valid JSON, per-thread monotonic
  timestamps, matched B/E nesting (``validate_chrome`` both accepts the
  export and rejects corrupted traces);
- metrics registry: counter/gauge/histogram snapshot correctness,
  quantiles on known data, type-collision detection;
- calibration: fitting a known scale factor back out of a synthetic
  Event timeline, CalibratedCostModel plugging into ``simulate``;
- divergence monitor: sustained drift fires the latch once, stable
  ratios never do;
- page-pool leak accounting: radix-held references are expected at
  teardown, anything else warns (or raises under REPRO_OBS_STRICT=1);
- hygiene: no bare ``print(`` in library code (launchers exempt);
- end-to-end: a traced engine run + plan swap + genserve generation
  emits a schema-valid trace covering iterations, decode waves and the
  swap.
"""
import json
import math
import os
import re
import warnings

import jax
import numpy as np
import pytest

from repro.core import enumerate as enum_mod, topology, workflow
from repro.core.costmodel import CostModel
from repro.core.simulator import Event, simulate
from repro.data.synthetic import AdditionTask, VOCAB_SIZE
from repro.genserve.pagepool import PagePool, RadixCache
from repro.models.config import ModelConfig
from repro.obs import calibrate as obs_cal
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.rl.trainer import RLConfig, RLTrainer

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with tracing off and empty stores."""
    obs_trace.disable()
    obs_trace.reset()
    obs_metrics.reset()
    yield
    obs_trace.disable()
    obs_trace.reset()
    obs_metrics.reset()


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_span_nesting_records_parent_ids():
    tr = obs_trace.Tracer(enabled=True)
    with tr.span("a") as a:
        with tr.span("a.b") as b:
            assert tr.current_span_id() == b.id
            with tr.span("a.b.c"):
                pass
        with tr.span("a.d"):
            pass
    events = tr.chrome_events()
    begins = {e["name"]: e for e in events if e["ph"] == "B"}
    assert set(begins) == {"a", "a.b", "a.b.c", "a.d"}
    assert "parent_id" not in begins["a"]["args"]
    assert begins["a.b"]["args"]["parent_id"] == a.id
    assert begins["a.b.c"]["args"]["parent_id"] == b.id
    assert begins["a.d"]["args"]["parent_id"] == a.id
    # B/E sequence ordering reproduces the push/pop interleaving
    order = [(e["name"], e["ph"]) for e in events]
    assert order == [("a", "B"), ("a.b", "B"), ("a.b.c", "B"),
                     ("a.b.c", "E"), ("a.b", "E"), ("a.d", "B"),
                     ("a.d", "E"), ("a", "E")]


def test_span_exception_safety():
    tr = obs_trace.Tracer(enabled=True)
    with pytest.raises(ValueError):
        with tr.span("outer"):
            with tr.span("inner"):
                raise ValueError("boom")
    events = tr.chrome_events()
    assert obs_trace.validate_chrome({"traceEvents": events}) == []
    begins = {e["name"]: e for e in events if e["ph"] == "B"}
    assert begins["inner"]["args"]["error"] == "ValueError"
    assert begins["outer"]["args"]["error"] == "ValueError"
    # the stack fully unwound: a new span nests under nothing
    with tr.span("later"):
        pass
    later = [e for e in tr.chrome_events()
             if e["name"] == "later" and e["ph"] == "B"]
    assert "parent_id" not in later[0]["args"]


def test_span_leaked_child_is_popped():
    """A child span abandoned without __exit__ (manual-open idiom gone
    wrong) must not corrupt the parent's stack."""
    tr = obs_trace.Tracer(enabled=True)
    with tr.span("parent"):
        leaked = tr.span("leaked")
        leaked.__enter__()             # never exited
    assert tr.current_span_id() == 0
    with tr.span("after"):
        pass
    after = [e for e in tr.chrome_events()
             if e["name"] == "after" and e["ph"] == "B"]
    assert "parent_id" not in after[0]["args"]


def test_disabled_tracer_is_noop():
    tr = obs_trace.Tracer(enabled=False)
    sp = tr.span("x", a=1)
    assert sp is tr.span("y")          # shared null span, no allocation
    with sp:
        sp.set("k", "v")
    assert tr.n_spans() == 0
    assert tr.current_span_id() == 0


def test_module_singleton_env_flag_roundtrip(tmp_path):
    obs_trace.enable()
    assert obs_trace.is_enabled()
    with obs_trace.span("m.root"):
        with obs_trace.span("m.leaf", n=3):
            pass
    path = obs_trace.export_chrome(str(tmp_path / "t.json"))
    assert obs_trace.validate_file(path) == []
    rep = obs_trace.report()
    assert "m.root" in rep and "m.leaf" in rep


# ---------------------------------------------------------------------------
# chrome export / validation
# ---------------------------------------------------------------------------

def test_chrome_export_roundtrip(tmp_path):
    tr = obs_trace.Tracer(enabled=True)
    for i in range(5):
        with tr.span("loop.iter", i=i):
            with tr.span("loop.work"):
                pass
    path = str(tmp_path / "trace.json")
    tr.export_chrome(path)
    with open(path) as f:
        obj = json.load(f)
    events = obj["traceEvents"]
    assert len(events) == 20           # 10 spans x B/E
    assert obs_trace.validate_chrome(obj) == []
    # timestamps non-decreasing (single thread) and microsecond-scaled
    ts = [e["ts"] for e in events]
    assert ts == sorted(ts)
    for e in events:
        assert e["ph"] in ("B", "E")
        assert {"name", "cat", "pid", "tid", "ts"} <= set(e)
    # CLI validator agrees
    from repro.obs.trace import _main
    assert _main([path]) == 0


def test_validate_chrome_rejects_corruption():
    tr = obs_trace.Tracer(enabled=True)
    with tr.span("ok"):
        pass
    good = tr.chrome_events()
    assert obs_trace.validate_chrome(good) == []
    # unmatched B
    assert obs_trace.validate_chrome(good[:1]) != []
    # E without B
    assert obs_trace.validate_chrome(good[1:]) != []
    # missing required field
    bad = [dict(good[0]), dict(good[1])]
    del bad[0]["ts"]
    assert obs_trace.validate_chrome(bad) != []
    # name mismatch on the close
    bad2 = [dict(good[0]), dict(good[1], name="other")]
    assert obs_trace.validate_chrome(bad2) != []
    # non-monotonic timestamps within a thread
    bad3 = [dict(good[0], ts=5.0), dict(good[1], ts=1.0)]
    assert obs_trace.validate_chrome(bad3) != []


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_snapshot_correctness():
    obs_metrics.counter("t.count").inc()
    obs_metrics.counter("t.count").inc(4)
    obs_metrics.gauge("t.gauge").set(2.5)
    h = obs_metrics.histogram("t.hist")
    for v in range(1, 101):
        h.observe(float(v))
    snap = obs_metrics.snapshot()
    assert snap["t.count"] == 5
    assert snap["t.gauge"] == 2.5
    hs = snap["t.hist"]
    assert hs["count"] == 100
    assert hs["sum"] == pytest.approx(5050.0)
    assert hs["min"] == 1.0 and hs["max"] == 100.0
    assert hs["mean"] == pytest.approx(50.5)
    assert hs["p50"] == pytest.approx(50.5, abs=1.0)
    assert hs["p95"] == pytest.approx(95.05, abs=1.0)
    assert hs["p99"] == pytest.approx(99.01, abs=1.0)


def test_metrics_type_collision_and_reset(tmp_path):
    obs_metrics.counter("t.name")
    with pytest.raises(TypeError):
        obs_metrics.gauge("t.name")
    path = str(tmp_path / "m.json")
    obs_metrics.counter("t.name").inc(3)
    obs_metrics.dump(path)
    with open(path) as f:
        assert json.load(f)["t.name"] == 3
    obs_metrics.reset()
    assert obs_metrics.snapshot() == {}


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def _tiny_setup():
    cfg = ModelConfig(name="obs-cal-t", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=VOCAB_SIZE,
                      dtype="float32")
    spec = workflow.LLMSpec.from_model_config(cfg)
    wf = workflow.make_workflow("grpo", spec, synchronous=True,
                                n_rollouts=2, seq_in=8, seq_out=4,
                                global_batch=1)
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 2, "L4": 2})
    grouping = (tuple(range(wf.n_tasks)),)
    plan = enum_mod.build_plan(topo, wf, grouping, [topo.n],
                               list(range(topo.n)))
    return topo, wf, plan


def _synthetic_timeline(cm, plan, wf, scale, n_iters):
    """Events whose durations are exactly scale x the predicted cost."""
    events, t = [], 0.0
    for it in range(n_iters):
        for task in range(wf.n_tasks):
            dur = cm.task_cost(plan, task).total * scale
            events.append(Event(t, "start", it, task))
            events.append(Event(t + dur, "end", it, task))
            t += dur
    return events


def test_calibration_recovers_known_scale():
    topo, wf, plan = _tiny_setup()
    cm = CostModel(topo, wf)
    scale = 250.0
    timeline = _synthetic_timeline(cm, plan, wf, scale, n_iters=3)
    cal = obs_cal.fit_calibration(topo, wf, plan, timeline,
                                  skip_iterations=1)
    assert cal.global_scale == pytest.approx(scale, rel=1e-6)
    assert cal.n_samples == 2 * wf.n_tasks
    for cls_scale in cal.class_scale.values():
        assert cls_scale == pytest.approx(scale, rel=1e-6)
    # unmeasured classes fall back to the global scale
    assert cal.scale_for("no-such-class") == pytest.approx(scale)
    # fit published the calib.* gauges
    snap = obs_metrics.snapshot()
    assert snap["calib.global_scale"] == pytest.approx(scale)


def test_calibrated_cost_model_scales_simulation():
    topo, wf, plan = _tiny_setup()
    cm = CostModel(topo, wf)
    scale = 100.0
    timeline = _synthetic_timeline(cm, plan, wf, scale, n_iters=3)
    cal = obs_cal.fit_calibration(topo, wf, plan, timeline,
                                  skip_iterations=1)
    base = simulate(topo, wf, plan, n_iterations=4)
    calibrated = simulate(topo, wf, plan, n_iterations=4,
                          cost_model=cal.cost_model(topo, wf))
    ratio = calibrated.iteration_time / base.iteration_time
    # tasks scale by exactly 100x; sync scales by sync_scale (the global
    # fallback here), so the end-to-end iteration scales ~100x
    assert ratio == pytest.approx(scale, rel=0.05)


def test_skip_iterations_drops_warmup():
    topo, wf, plan = _tiny_setup()
    cm = CostModel(topo, wf)
    # iteration 0 is 1000x (jit compile), the rest are 10x
    warm = _synthetic_timeline(cm, plan, wf, 1000.0, n_iters=1)
    rest = [Event(e.time, e.kind, e.iteration + 1, e.task)
            for e in _synthetic_timeline(cm, plan, wf, 10.0, n_iters=2)]
    cal = obs_cal.fit_calibration(topo, wf, plan, warm + rest,
                                  skip_iterations=1)
    assert cal.global_scale == pytest.approx(10.0, rel=1e-6)


def _component_timeline(cm, plan, wf, s, n_iters, tasks=None):
    """Durations with *different* scales per cost component — what a
    class with degraded links but healthy FLOPs produces."""
    events, t = [], 0.0
    for it in range(n_iters):
        for task in (tasks if tasks is not None else range(wf.n_tasks)):
            tc = cm.task_cost(plan, task)
            dur = (s["comp"] * (tc.comp + tc.bubble)
                   + s["comm"] * (tc.tp + tc.pp + tc.dp)
                   + s["hbm"] * tc.hbm)
            events.append(Event(t, "start", it, task))
            events.append(Event(t + dur, "end", it, task))
            t += dur
    return events


def test_per_coefficient_fit_recovers_component_scales():
    """A uniform scale cannot express comm 40x / hbm 7x / comp 3x; the
    per-coefficient fit separates them from the task mix (GEN carries
    the hbm term, TRAIN the dp term, INF neither)."""
    topo, wf, plan = _tiny_setup()
    cm = CostModel(topo, wf)
    s = {"comp": 3.0, "comm": 40.0, "hbm": 7.0}
    timeline = _component_timeline(cm, plan, wf, s, n_iters=3)
    cal = obs_cal.fit_calibration(topo, wf, plan, timeline,
                                  skip_iterations=1, per_coefficient=True)
    coeff = cal.coeff_for("A100")
    assert coeff["comm"] == pytest.approx(s["comm"], rel=1e-3)
    assert coeff["hbm"] == pytest.approx(s["hbm"], rel=1e-2)
    assert coeff["comp"] == pytest.approx(s["comp"], rel=1e-2)
    # never-measured classes fall back to the uniform scale triple
    fb = cal.coeff_for("no-such-class")
    assert fb["comp"] == fb["comm"] == fb["hbm"] == cal.global_scale
    # the fit published per-coefficient gauges
    snap = obs_metrics.snapshot()
    assert snap["calib.coeff.A100.comm"] == pytest.approx(coeff["comm"])


def test_per_coefficient_calibrated_model_matches_measured():
    """CalibratedCostModel with a per-coefficient fit reproduces the
    measured per-task durations, not just their geometric mean."""
    topo, wf, plan = _tiny_setup()
    cm = CostModel(topo, wf)
    s = {"comp": 2.0, "comm": 50.0, "hbm": 7.0}
    timeline = _component_timeline(cm, plan, wf, s, n_iters=3)
    cal = obs_cal.fit_calibration(topo, wf, plan, timeline,
                                  skip_iterations=1, per_coefficient=True)
    ccm = cal.cost_model(topo, wf)
    for t in range(wf.n_tasks):
        tc = cm.task_cost(plan, t)
        dur = (s["comp"] * (tc.comp + tc.bubble)
               + s["comm"] * (tc.tp + tc.pp + tc.dp)
               + s["hbm"] * tc.hbm)
        assert ccm.task_cost(plan, t).total == pytest.approx(dur, rel=1e-3)


def test_per_coefficient_unexercised_component_pinned():
    """Dropping the GEN task from the timeline leaves the hbm column
    all-zero — no signal, so the hbm coefficient is pinned to the
    uniform class scale while comm is still identified."""
    topo, wf, plan = _tiny_setup()
    cm = CostModel(topo, wf)
    s = {"comp": 3.0, "comm": 40.0, "hbm": 7.0}
    tasks = [t for t in range(wf.n_tasks)
             if cm.task_cost(plan, t).hbm == 0.0]
    timeline = _component_timeline(cm, plan, wf, s, n_iters=3, tasks=tasks)
    cal = obs_cal.fit_calibration(topo, wf, plan, timeline,
                                  skip_iterations=1, per_coefficient=True)
    coeff = cal.coeff_for("A100")
    assert coeff["comm"] == pytest.approx(s["comm"], rel=1e-3)
    assert coeff["hbm"] == pytest.approx(cal.scale_for("A100"))


def test_divergence_monitor_fires_on_sustained_drift():
    mon = obs_cal.DivergenceMonitor(threshold=3.0, sustain=3, alpha=1.0)
    # stable: ratios hover around 1 -> no fire
    for _ in range(10):
        mon.observe(0, 1.1, 1.0)
    assert not mon.consume() and mon.drifted_tasks() == []
    # drifted: 10x sustained -> fires exactly once at the sustain mark
    fired_at = None
    for i in range(6):
        if mon.observe(1, 10.0, 1.0):
            fired_at = i
            break
    assert fired_at == 2               # third consecutive observation
    assert mon.drifted_tasks() == [1]
    assert mon.ratio(1) == pytest.approx(10.0)
    assert mon.consume()               # latch reads once...
    assert not mon.consume()           # ...and clears
    # already-drifted tasks do not re-fire while they stay drifted
    assert not mon.observe(1, 10.0, 1.0)
    assert obs_metrics.snapshot()["elastic.drift_events"] == 1


def test_divergence_monitor_recovers():
    mon = obs_cal.DivergenceMonitor(threshold=2.0, sustain=2, alpha=1.0)
    for _ in range(2):
        mon.observe(0, 8.0, 1.0)
    assert mon.drifted_tasks() == [0]
    mon.observe(0, 1.0, 1.0)           # back in band -> streak resets
    assert mon.drifted_tasks() == []


# ---------------------------------------------------------------------------
# page-pool leak accounting
# ---------------------------------------------------------------------------

def test_pagepool_leak_check():
    pool = PagePool(n_pages=8, page_size=4)
    radix = RadixCache(pool)
    pages = pool.alloc(2)
    radix.insert(list(range(8)), pages)    # tree holds one ref per page
    pool.decref(pages)                     # slot retires its refs
    # everything accounted for: tree refs are expected at teardown
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert pool.leak_check(expected_refs=radix.page_refs()) == []
    # an unaccounted page -> warn + metric
    leak = pool.alloc(1)
    with pytest.warns(RuntimeWarning, match="leak"):
        leaked = pool.leak_check(expected_refs=radix.page_refs())
    assert leaked == leak
    assert obs_metrics.snapshot()["pagepool.leaked_pages"] == 1


def test_pagepool_leak_check_strict_raises(monkeypatch):
    monkeypatch.setenv("REPRO_OBS_STRICT", "1")
    pool = PagePool(n_pages=4, page_size=2)
    pool.alloc(1)
    with pytest.raises(RuntimeError, match="leak"):
        pool.leak_check()


def test_pagepool_stats_utilization():
    pool = PagePool(n_pages=10, page_size=2)
    assert pool.utilization() == 0.0
    pages = pool.alloc(3)
    s = pool.stats()
    assert s["live"] == 3 and s["free"] == 7
    assert s["utilization"] == pytest.approx(0.3)
    pool.decref(pages)
    assert pool.utilization() == 0.0


# ---------------------------------------------------------------------------
# hygiene: no bare print in library code
# ---------------------------------------------------------------------------

def test_no_bare_print_in_library_code():
    """Library modules report through ``repro.obs`` (or logging), never
    bare ``print`` — launchers are the human-facing CLI surface and are
    exempt."""
    root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    offenders = []
    pat = re.compile(r"(?<![\w.])print\(")
    for dirpath, _dirs, files in os.walk(root):
        if os.path.basename(dirpath) == "launch":
            continue
        for fname in files:
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            with open(path) as f:
                for lineno, line in enumerate(f, 1):
                    code = line.split("#", 1)[0]
                    if pat.search(code):
                        rel = os.path.relpath(path, root)
                        offenders.append(f"{rel}:{lineno}")
    assert not offenders, \
        f"bare print( in library code: {offenders}"


# ---------------------------------------------------------------------------
# end-to-end: traced engine + genserve + plan swap
# ---------------------------------------------------------------------------

def _e2e_trainer():
    cfg = ModelConfig(name="obs-e2e", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=VOCAB_SIZE,
                      dtype="float32")
    task = AdditionTask(max_operand=9)
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 2, "L4": 2})
    spec = workflow.LLMSpec.from_model_config(cfg)
    wf = workflow.make_workflow("grpo", spec, synchronous=True,
                                n_rollouts=2, seq_in=task.prompt_len,
                                seq_out=4, global_batch=1)
    grouping = (tuple(range(wf.n_tasks)),)
    plan = enum_mod.build_plan(topo, wf, grouping, [topo.n],
                               list(range(topo.n)))
    rl = RLConfig(algorithm="grpo", n_rollouts=2, max_new_tokens=4,
                  gen_engine="genserve")
    return RLTrainer(cfg, rl, task, KEY, plan=plan, topo=topo, wf=wf), \
        topo, wf, plan


def test_e2e_trace_covers_engine_waves_and_swap(tmp_path):
    obs_trace.enable()
    trainer, topo, wf, plan = _e2e_trainer()
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(7)
    for _ in range(2):
        prompts, answers = trainer.task.sample_batch(rng, 2)
        key, k = jax.random.split(key)
        trainer.iteration(prompts, answers, k)
    # swap to a structurally identical plan: epoch bumps, span records
    plan2 = enum_mod.build_plan(topo, wf, (tuple(range(wf.n_tasks)),),
                                [topo.n], list(range(topo.n)))
    trainer.engine.apply_plan(plan2, topo=topo)
    prompts, answers = trainer.task.sample_batch(rng, 2)
    trainer.iteration(prompts, answers, jax.random.PRNGKey(9))

    path = str(tmp_path / "e2e.json")
    obs_trace.export_chrome(path)
    assert obs_trace.validate_file(path) == []
    with open(path) as f:
        events = json.load(f)["traceEvents"]
    names = {e["name"] for e in events}
    assert {"train.step", "engine.iteration", "engine.stage",
            "engine.sync", "engine.swap",
            "task.actor_generation"} <= names
    assert any(n.startswith("gen.") for n in names), names

    # Events carry wall-clock + span ids; spans referenced exist
    span_ids = {e["args"]["span_id"] for e in events if e["ph"] == "B"}
    timeline = trainer.engine.timeline
    stamped = [e for e in timeline if e.span is not None]
    assert stamped, "no Event carries a span id"
    assert all(e.span in span_ids for e in stamped)
    assert all(e.t_wall is not None for e in stamped)

    # engine metrics populated alongside
    snap = obs_metrics.snapshot()
    assert snap["engine.iter_wall_s"]["count"] == 3
    assert snap["engine.plan_epoch"] == 1.0
    assert snap["engine.swaps"] == 1
    assert snap["gen.tokens"] > 0


def test_e2e_calibration_within_10x():
    trainer, _topo, _wf, _plan = _e2e_trainer()
    rng = np.random.default_rng(1)
    key = jax.random.PRNGKey(3)
    for _ in range(3):
        prompts, answers = trainer.task.sample_batch(rng, 2)
        key, k = jax.random.split(key)
        trainer.iteration(prompts, answers, k)
    cal = obs_cal.fit_from_engine(trainer.engine, skip_iterations=1)
    raw = trainer.engine.compare_with_simulator()
    fixed = trainer.engine.compare_with_simulator(
        cost_model=cal.cost_model(trainer.engine.topo, trainer.wf))
    # calibration moves the ratio toward unity (uncalibrated it is off
    # by whatever the local-host-vs-priced-GPU gap happens to be) and
    # lands it in the usable 10x band
    assert abs(math.log(fixed["ratio"])) < abs(math.log(raw["ratio"]))
    assert 0.1 < fixed["ratio"] < 10.0
