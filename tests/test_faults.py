"""Fault-injection + self-healing tests (repro.faults and the hardened
execution path).

- retry semantics: bounded backoff, permanent short-circuit, exhaustion;
- checkpoint integrity: crc32 detects in-place corruption, legacy
  (pre-checksum) files still load, ``load_latest`` falls back past
  damaged files, ``retain`` prunes to the newest K;
- fault determinism: the same seeded ``FaultPlan`` on two fresh engines
  produces an identical injected-event log and Event-timeline shape;
- transient crash: absorbed by the engine's bounded retry with zero
  lost iterations;
- permanent crash: escalates through ``ElasticController.handle_failure``
  to drop-devices + forced replan, and the injected fault heals once the
  plan epoch advances;
- checkpoint fault chain: injected write failures retry / degrade to
  warn-and-continue, an injected corruption is skipped by the
  ``load_latest`` fallback, and a fresh trainer restores the surviving
  checkpoint bitwise (``state_tree()`` round-trip);
- reactive replan: an undeclared link throttle detected purely via the
  ``DivergenceMonitor`` triggers a plan switch;
- genserve: slot failures requeue in-flight requests with zero page
  leaks under ``REPRO_OBS_STRICT=1`` (greedy decode makes the requeued
  rollout bit-identical to the undisturbed run), and explicit cancels
  retire requests as all-masked rows without disturbing the others.
"""
import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.core import enumerate as enum_mod, retry, topology, workflow
from repro.core.plan import check_constraints
from repro.core.workflow import TaskKind
from repro.data.synthetic import AdditionTask, EOS, VOCAB_SIZE
from repro.engine.elastic import ElasticConfig, ElasticController
from repro.engine.executor import TaskExecutionError
from repro.faults import (FAULT_SCENARIOS, FaultEvent, FaultInjector,
                          FaultPlan, fault_scenario)
from repro.genserve.decoder import GenServeConfig, serve
from repro.models import transformer as T
from repro.models.config import LayerSpec, ModelConfig
from repro.obs import calibrate as obs_cal
from repro.obs import metrics as obs_metrics
from repro.rl.trainer import RLConfig, RLTrainer

KEY = jax.random.PRNGKey(0)


def _nosleep(_s):
    pass


# ---------------------------------------------------------------------------
# harness (mirrors test_elastic)
# ---------------------------------------------------------------------------

def tiny_cfg():
    return ModelConfig(name="ft-tiny", n_layers=2, d_model=64, n_heads=2,
                       n_kv_heads=2, head_dim=32, d_ff=128,
                       vocab_size=VOCAB_SIZE, dtype="float32")


def reference_pool():
    return topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})


def make_trainer():
    cfg = tiny_cfg()
    task = AdditionTask(max_operand=9)
    topo = reference_pool()
    spec = workflow.LLMSpec.from_model_config(cfg)
    wf = workflow.make_workflow("grpo", spec, synchronous=True,
                                n_rollouts=4, seq_in=task.prompt_len,
                                seq_out=4, global_batch=1)
    g = tuple(sorted(((0,), tuple(range(1, wf.n_tasks)))))
    sizes = enum_mod.proportional_sizes(wf, g, topo.n)
    plan = enum_mod.build_plan(topo, wf, g, sizes, list(range(topo.n)))
    ok, msg = check_constraints(topo, wf, plan)
    assert ok, msg
    rl = RLConfig(algorithm="grpo", n_rollouts=4, max_new_tokens=4)
    trainer = RLTrainer(cfg, rl, task, KEY, plan=plan, topo=topo, wf=wf)
    return trainer, topo, wf


def run_iters(trainer, n, batch=4, seed=0):
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(7)
    out = []
    for _ in range(n):
        prompts, answers = trainer.task.sample_batch(rng, batch)
        key, k = jax.random.split(key)
        out.append(trainer.iteration(prompts, answers, k))
    return out


def train_task_id(wf):
    return next(t for t in range(wf.n_tasks)
                if wf.task(t).kind == TaskKind.TRAIN)


def attach(trainer, fault_plan):
    inj = FaultInjector(fault_plan)
    trainer.engine.attach_fault_injector(inj)
    trainer.engine.set_task_retry(
        retry.RetryPolicy(max_attempts=3, base_delay_s=0.0),
        sleep=_nosleep)
    return inj


# ---------------------------------------------------------------------------
# core/retry
# ---------------------------------------------------------------------------

def test_retry_backoff_and_exhaustion():
    pol = retry.RetryPolicy(max_attempts=3, base_delay_s=0.1, factor=2.0,
                            max_delay_s=0.15)
    assert pol.delay(0) == pytest.approx(0.1)
    assert pol.delay(1) == pytest.approx(0.15)   # capped

    calls, slept = [], []

    def flaky(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise retry.TransientError("boom")
        return "ok"

    assert retry.retry_call(flaky, policy=pol, sleep=slept.append) == "ok"
    assert calls == [0, 1, 2]
    assert slept == [pytest.approx(0.1), pytest.approx(0.15)]

    with pytest.raises(retry.RetryExhausted) as ei:
        retry.retry_call(lambda a: (_ for _ in ()).throw(
            retry.TransientError("x")), policy=pol, sleep=_nosleep)
    assert ei.value.attempts == 3
    assert isinstance(ei.value.last, retry.TransientError)

    perm_calls = []

    def perm(attempt):
        perm_calls.append(attempt)
        raise retry.PermanentError("dead")

    with pytest.raises(retry.PermanentError):
        retry.retry_call(perm, policy=pol, sleep=_nosleep)
    assert perm_calls == [0]          # never retried


# ---------------------------------------------------------------------------
# checkpoint integrity
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (4, 3)),
            "b": jnp.arange(5, dtype=jnp.int32) + seed}


def _assert_trees_bitwise(a, b):
    fa = jax.tree_util.tree_flatten(a)[0]
    fb = jax.tree_util.tree_flatten(b)[0]
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        xa, ya = np.asarray(x), np.asarray(y)
        assert xa.dtype == ya.dtype
        np.testing.assert_array_equal(xa, ya)


def test_checkpoint_crc_detects_corruption(tmp_path):
    p = str(tmp_path / "ck_00001.msgpack")
    tree = _tree()
    ckpt_io.save(p, tree)
    _assert_trees_bitwise(ckpt_io.restore(p, _tree(1)), tree)
    raw = bytearray(open(p, "rb").read())
    mid = len(raw) // 2
    raw[mid:mid + 8] = bytes(b ^ 0xFF for b in raw[mid:mid + 8])
    open(p, "wb").write(bytes(raw))
    with pytest.raises(ckpt_io.CheckpointError):
        ckpt_io.restore(p, _tree(1))


def test_checkpoint_legacy_format_loads(tmp_path):
    # pre-checksum files packed the payload directly, no crc wrapper
    p = str(tmp_path / "legacy.msgpack")
    tree = _tree()
    flat, treedef = jax.tree_util.tree_flatten(tree)
    payload = {b"treedef": str(treedef).encode(),
               b"leaves": [ckpt_io._pack_leaf(x) for x in flat]}
    open(p, "wb").write(msgpack.packb(payload))
    _assert_trees_bitwise(ckpt_io.restore(p, _tree(1)), tree)


def test_load_latest_fallback_and_retain(tmp_path):
    d = str(tmp_path)
    for i in range(4):
        ckpt_io.save(os.path.join(d, f"ck_{i:05d}.msgpack"), _tree(i),
                     retain=3)
    files = ckpt_io._checkpoint_files(d)
    assert [os.path.basename(p) for p in files] == \
        ["ck_00001.msgpack", "ck_00002.msgpack", "ck_00003.msgpack"]
    # damage the newest -> load_latest warns and falls back to ck_00002
    open(files[-1], "wb").write(b"\x00garbage")
    with pytest.warns(RuntimeWarning):
        tree, path = ckpt_io.load_latest(d, _tree(9))
    assert path == files[-2]
    _assert_trees_bitwise(tree, _tree(2))
    # nothing loadable -> CheckpointError listing every file tried
    for p in files:
        open(p, "wb").write(b"")
    with pytest.warns(RuntimeWarning), \
            pytest.raises(ckpt_io.CheckpointError, match="no loadable"):
        ckpt_io.load_latest(d, _tree(9))


# ---------------------------------------------------------------------------
# fault plan / event semantics
# ---------------------------------------------------------------------------

def test_fault_plan_generate_deterministic():
    a = FaultPlan.generate(5, n_events=4)
    b = FaultPlan.generate(5, n_events=4)
    assert a.describe() == b.describe()
    c = FaultPlan.generate(6, n_events=4)
    assert c.describe() != a.describe()


def test_fault_event_validation_and_scenarios():
    with pytest.raises(ValueError):
        FaultEvent("meteor_strike", 0)
    e = FaultEvent("straggler", 2, until=4, task=0)
    assert [e.active(i) for i in range(5)] == \
        [False, False, True, True, False]
    topo = reference_pool()
    for name in FAULT_SCENARIOS:
        plan = fault_scenario(name, at=3, topo=topo)
        assert plan.events, name
    with pytest.raises(ValueError):
        fault_scenario("nope")


# ---------------------------------------------------------------------------
# engine: determinism + transient retry
# ---------------------------------------------------------------------------

def test_fault_determinism_and_transient_retry():
    def run_once():
        trainer, topo, wf = make_trainer()
        tt = train_task_id(wf)
        inj = attach(trainer, FaultPlan([
            FaultEvent("straggler", 1, until=3, task=0, factor=2.5),
            FaultEvent("transient_crash", 2, until=3, task=tt,
                       n_failures=2),
        ], seed=0))
        outs = run_iters(trainer, 4)
        shape = [(e.iteration, e.task, e.kind)
                 for e in trainer.engine.timeline]
        return inj.log, shape, outs

    before = obs_metrics.counter("engine.task_retries").value
    log1, shape1, outs1 = run_once()
    log2, shape2, outs2 = run_once()
    # same seed, fresh engines -> identical injected-event sequence and
    # identical Event-timeline shape
    assert log1 == log2
    assert shape1 == shape2
    # the transient crash cost two retries and zero iterations: every
    # iteration produced metrics
    assert len(outs1) == 4
    assert all("reward_mean" in r for r in outs1)
    raises = [r for r in log1 if r["what"] == "raise_transient"]
    assert [r["attempt"] for r in raises] == [0, 1]
    assert obs_metrics.counter("engine.task_retries").value - before == 4
    # the straggler window still produced normal GEN timeline events
    assert any(i == 1 and t == 0 for (i, t, _k) in shape1)


# ---------------------------------------------------------------------------
# engine: permanent crash -> drop + forced replan
# ---------------------------------------------------------------------------

def test_permanent_crash_escalates_to_forced_replan(tmp_path):
    trainer, topo, wf = make_trainer()
    tt = train_task_id(wf)
    inj = attach(trainer, fault_scenario("permanent_crash", at=1,
                                         train_task=tt))
    ctrl = ElasticController(
        trainer, lambda it: topo,
        ElasticConfig(budget=80, amortization_iters=5,
                      ckpt_dir=str(tmp_path)))
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(7)
    done, step, forced, dead = 0, 0, None, set()
    while done < 3:
        prompts, answers = trainer.task.sample_batch(rng, 4)
        key, k = jax.random.split(key)
        try:
            trainer.iteration(prompts, answers, k)
        except TaskExecutionError as e:
            assert e.permanent and e.task == tt and e.dead_devices
            dead = set(e.dead_devices)
            forced = ctrl.handle_failure(step, e)
            continue
        done += 1
        step += 1
    assert forced is not None and forced.forced and forced.applied
    assert trainer.engine.epoch == 1
    # the survivors' plan references no dead device
    for t in range(wf.n_tasks):
        assigned = {int(d) for d in trainer.plan.assignment[t].reshape(-1)}
        assert not (assigned & dead)
    # state was checkpointed around the forced swap
    assert ckpt_io._checkpoint_files(str(tmp_path))
    # the epoch change healed the fault: exactly one permanent raise
    assert len(inj.fired("permanent_crash")) == 2  # activate + raise
    assert [r["what"] for r in inj.fired("permanent_crash")] == \
        ["activate", "raise_permanent"]


# ---------------------------------------------------------------------------
# elastic checkpointing under injected faults + bitwise crash-resume
# ---------------------------------------------------------------------------

def test_checkpoint_fault_chain_and_bitwise_resume(tmp_path):
    trainer, topo, wf = make_trainer()
    ctrl = ElasticController(
        trainer, lambda it: topo,
        ElasticConfig(ckpt_dir=str(tmp_path), ckpt_retain=0))
    run_iters(trainer, 2)
    path1, nbytes = ctrl.checkpoint_now(1)
    assert path1 and nbytes > 0
    want_a = trainer.state_tree()
    want_a = jax.tree_util.tree_map(lambda x: np.asarray(x).copy(), want_a)

    run_iters(trainer, 1, seed=1)      # diverge past the checkpoint
    want_b = jax.tree_util.tree_map(lambda x: np.asarray(x).copy(),
                                    trainer.state_tree())

    # (1) flaky write: two injected failures absorbed by retry
    inj = FaultInjector(FaultPlan([
        FaultEvent("ckpt_fail", 0, n_failures=2)]))
    trainer.engine.attach_fault_injector(inj)
    inj.begin_iteration(3)
    r0 = obs_metrics.counter("checkpoint.retries").value
    path3, _ = ctrl.checkpoint_now(3)
    assert path3 and os.path.exists(path3)
    assert obs_metrics.counter("checkpoint.retries").value - r0 == 2

    # (2) persistently broken path: warn-and-continue, no file
    inj = FaultInjector(FaultPlan([
        FaultEvent("ckpt_fail", 0, n_failures=-1)]))
    trainer.engine.attach_fault_injector(inj)
    inj.begin_iteration(4)
    f0 = obs_metrics.counter("checkpoint.failures").value
    with pytest.warns(RuntimeWarning, match="checkpoint write failed"):
        path4, nbytes4 = ctrl.checkpoint_now(4)
    assert path4 is None and nbytes4 == 0
    assert obs_metrics.counter("checkpoint.failures").value - f0 == 1

    # (3) corrupted-on-disk newest checkpoint
    inj = FaultInjector(FaultPlan([FaultEvent("ckpt_corrupt", 0)]))
    trainer.engine.attach_fault_injector(inj)
    inj.begin_iteration(5)
    path5, _ = ctrl.checkpoint_now(5)
    assert path5 and inj.fired("ckpt_corrupt")

    # crash: a fresh process restores the newest *loadable* checkpoint —
    # load_latest skips the corrupted iter-5 file and lands on iter-3
    fresh, _, _ = make_trainer()
    with pytest.warns(RuntimeWarning, match="skipping checkpoint"):
        tree, path = ckpt_io.load_latest(str(tmp_path), fresh.state_tree())
    assert path == path3
    fresh.load_state_tree(tree)
    _assert_trees_bitwise(fresh.state_tree(), want_b)
    # the older restore point survives too (retain=0 keeps everything)
    _assert_trees_bitwise(ckpt_io.restore(path1, fresh.state_tree()),
                          want_a)


# ---------------------------------------------------------------------------
# reactive replan: undeclared throttle detected via divergence only
# ---------------------------------------------------------------------------

def test_link_throttle_reactive_replan():
    trainer, topo, wf = make_trainer()
    inj = attach(trainer, fault_scenario("link_throttle", at=3))
    ctrl = ElasticController(
        trainer, lambda it: topo,
        ElasticConfig(budget=80, amortization_iters=5))
    run_iters(trainer, 3)              # clean warmup for calibration
    cal = obs_cal.fit_from_engine(trainer.engine)
    monitor = obs_cal.DivergenceMonitor(threshold=2.0, sustain=2)
    trainer.engine.attach_divergence_monitor(monitor, cal)
    ctrl.monitor = monitor

    reactive = None
    for step in range(3, 10):
        run_iters(trainer, 1, seed=step)
        rec = ctrl.poll(step)
        if rec is not None:
            reactive = rec
            break
    assert reactive is not None, "divergence monitor never fired"
    assert reactive.reactive and not reactive.forced
    assert reactive.applied and trainer.engine.epoch == 1
    assert inj.fired("link_throttle")
    # detection came purely from measurements: the feed never changed
    assert topology.topo_equal(ctrl._observed, topo)


# ---------------------------------------------------------------------------
# genserve: slot failure requeue + explicit cancel (strict leak checks)
# ---------------------------------------------------------------------------

P, N = 8, 6


def _gs_setup():
    cfg = ModelConfig(name="ft-gs", n_layers=2, d_model=64, n_heads=2,
                      n_kv_heads=2, head_dim=32, d_ff=128,
                      vocab_size=VOCAB_SIZE, dtype="float32",
                      pattern=(LayerSpec(window=None),))
    params = T.init_params(KEY, cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (6, P), 0,
                                 cfg.vocab_size, jnp.int32)
    kw = dict(wave=4, max_new_tokens=N, eos_token=EOS, prefill_chunk=4,
              greedy=True, page_size=4, prefix_cache=True)
    return cfg, params, prompts, kw


def test_slot_failure_requeues_without_leaks(monkeypatch):
    monkeypatch.setenv("REPRO_OBS_STRICT", "1")
    cfg, params, prompts, kw = _gs_setup()
    ref, _ = serve(params, cfg, prompts, jax.random.PRNGKey(7),
                   GenServeConfig(**kw))
    got, stats = serve(params, cfg, prompts, jax.random.PRNGKey(7),
                       GenServeConfig(**kw),
                       slot_failures={2: [0, 1]})
    assert stats["requeued"] == 2
    assert stats["retired"] == 6 and stats["cancelled"] == 0
    # greedy decode is deterministic per prompt: the requeued requests
    # regenerate the exact rollout the undisturbed run produced — and
    # strict mode already proved zero leaked pages at serve teardown
    np.testing.assert_array_equal(np.asarray(ref["mask"]),
                                  np.asarray(got["mask"]))
    m = np.asarray(ref["mask"]).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(ref["gen_tokens"]) * m,
                                  np.asarray(got["gen_tokens"]) * m)


def test_cancel_retires_requests_without_leaks(monkeypatch):
    monkeypatch.setenv("REPRO_OBS_STRICT", "1")
    cfg, params, prompts, kw = _gs_setup()
    ref, _ = serve(params, cfg, prompts, jax.random.PRNGKey(7),
                   GenServeConfig(**kw))
    # rid 2 is in flight (first wave), rid 5 is still queued (wave=4)
    got, stats = serve(params, cfg, prompts, jax.random.PRNGKey(7),
                       GenServeConfig(**kw),
                       cancels={3: [2, 5]})
    assert stats["cancelled"] == 2 and stats["requeued"] == 0
    mask = np.asarray(got["mask"])
    assert mask[2].sum() == 0 and mask[5].sum() == 0
    keep = [0, 1, 3, 4]
    np.testing.assert_array_equal(np.asarray(ref["mask"])[keep], mask[keep])
    m = np.asarray(ref["mask"])[keep].astype(np.int32)
    np.testing.assert_array_equal(
        np.asarray(ref["gen_tokens"])[keep] * m,
        np.asarray(got["gen_tokens"])[keep] * m)
