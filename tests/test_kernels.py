"""Per-kernel shape/dtype sweeps: assert_allclose vs the ref.py oracles
(interpret mode executes the kernel body in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mamba_scan.ops import mamba_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref

KEY = jax.random.PRNGKey(7)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (2, 256, 4, 2, 64),
    (1, 128, 4, 4, 80),     # hd padding path (hubert-style)
    (2, 300, 8, 2, 128),    # seq padding path
    (1, 96, 2, 1, 64),      # MQA
])
@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None), (True, 64, None), (True, None, 50.0),
    (False, None, None),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, H, KV, hd, causal, window, cap, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, cap=cap)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,H,KV,hd,L", [
    (2, 4, 2, 64, 300), (1, 8, 8, 80, 512), (3, 4, 1, 128, 1000),
])
@pytest.mark.parametrize("cap", [None, 30.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, H, KV, hd, L, cap, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, L, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, L, KV, hd), dtype)
    valid = jax.random.bernoulli(ks[3], 0.75, (B, L))
    bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)
    out = decode_attention(q, k, v, bias, cap=cap)
    ref = decode_attention_ref(q, k, v, bias, cap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,S,H,N,chunk", [
    (2, 128, 2, 32, 32), (1, 100, 4, 64, 64),   # padding path
    (2, 64, 1, 16, 16), (1, 64, 2, 128, 32),
])
def test_rwkv6_scan(B, S, H, N, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, N))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) * 0.5 - 1.0)
    u = jax.random.normal(ks[4], (H, N)) * 0.5
    y, st = rwkv6_scan(r, k, v, logw, u, chunk=chunk)
    yr, sr = rwkv6_scan_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("B,S,di,ds,chunk,bd", [
    (2, 64, 128, 16, 16, 64), (1, 100, 512, 16, 32, 256),  # padding
    (2, 32, 64, 8, 32, 64), (1, 48, 320, 16, 16, 64),      # di padding
])
def test_mamba_scan(B, S, di, ds, chunk, bd):
    ks = jax.random.split(KEY, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di)) - 1)
    x = jax.random.normal(ks[1], (B, S, di))
    Bm = jax.random.normal(ks[2], (B, S, ds))
    Cm = jax.random.normal(ks[3], (B, S, ds))
    A = -jnp.exp(jax.random.normal(ks[4], (di, ds)) * 0.3)
    y, h = mamba_scan(dt, x, Bm, Cm, A, chunk=chunk, bd=bd)
    yr, hr = mamba_scan_ref(dt, x, Bm, Cm, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-3, atol=1e-3)


def test_pallas_path_in_model_attention():
    """set_attention_impl('pallas') must agree with the jnp path."""
    from repro.models import attention as attn
    ks = jax.random.split(KEY, 3)
    B, S, H, KV, hd = 1, 128, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    pos = jnp.arange(S)
    jnp_out = attn.multihead_attention(q, k, v, pos, pos, causal=True)
    try:
        attn.set_attention_impl("pallas")
        pallas_out = attn.multihead_attention(q, k, v, pos, pos, causal=True)
    finally:
        attn.set_attention_impl("jnp")
    np.testing.assert_allclose(np.asarray(jnp_out), np.asarray(pallas_out),
                               rtol=2e-4, atol=2e-4)
