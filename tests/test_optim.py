"""Optimizer + checkpoint unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt
from repro.optim import adam


def test_adam_matches_reference_step():
    cfg = adam.AdamConfig(lr=1e-2, beta1=0.9, beta2=0.999, eps=1e-8,
                          grad_clip_norm=None)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    state = adam.init_adam_state(params, cfg)
    new_params, state, _ = adam.adam_update(params, grads, state, cfg)
    # reference: first step of Adam => update = lr * sign-ish expression
    g = np.array([0.1, 0.2, -0.3])
    m = 0.1 * g
    v = 0.001 * g ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    expect = np.array([1.0, -2.0, 3.0]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_params["w"]), expect,
                               rtol=1e-5)


def test_grad_clipping():
    grads = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, norm = adam.clip_by_global_norm(grads, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               rtol=1e-6)


def test_adam_bf16_moments():
    cfg = adam.AdamConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4))}
    state = adam.init_adam_state(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((4, 4))}
    new_params, state, _ = adam.adam_update(params, grads, state, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    assert bool(jnp.isfinite(new_params["w"]).all())


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2], jnp.int32)}}
    path = str(tmp_path / "ck.msgpack")
    n = ckpt.save(path, tree)
    assert n > 0
    restored = ckpt.restore(path, tree)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
