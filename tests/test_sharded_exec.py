"""End-to-end sharded execution smoke: run examples/sharded_exec.py in a
subprocess with 8 forced host devices (the parent's jax is already
initialized with this process's device count, so the multi-device run
needs its own interpreter) and check its machine-readable summary."""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXAMPLE = os.path.join(REPO, "examples", "sharded_exec.py")


def test_sharded_exec_example():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, EXAMPLE, "--iters", "3", "--json"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    summary = json.loads(r.stdout.strip().splitlines()[-1])
    assert summary["devices"] >= 8
    assert summary["folding_collisions"] == 0
    assert summary["overlap_active"] is True
    assert summary["tokens_identical"] is True
    assert summary["train_mesh"] == [2, 2]
    assert set(summary["gen_devices"]).isdisjoint(summary["train_devices"])
    assert summary["overlap_honest"] == 1.0
