"""Speculative decoding: draft-k propose, one batched verify.

- greedy spec is token-exact vs the non-speculative engine across the
  admission matrix (ring windows, GQA, paged, prefix sharing, recycled
  slots, ragged prompts, per-request budgets, EOS retirement) — greedy
  acceptance is RNG-free, so the parity is exact, not statistical;
- sampled spec preserves the target distribution (rejection-resampling
  unit test with a measured total-variation bound) and keeps the
  rollout-contract invariants (monotone masks, finite logprobs);
- a draft that agrees with the target (zeroed second layer) accepts
  every proposal and finishes in fewer verify rounds than plain wave
  decode takes steps;
- ``verify_chunk_step`` over a [B, C] candidate chunk scores exactly
  what C sequential ``decode_step`` calls score;
- satellite: ``cache.write_kv`` / ``cache.paged_update_chunk`` with
  *short* validity masks — accept < k mid-ring-wrap, accept 0, clamped
  full-cache tails;
- cost model: ``gen_speculative_wave`` pricing (k = 0 degenerates to
  the HBM decode bound, accept-rate monotonicity, task_cost switch)
  and the EA's deterministic best-response draft-k choice in decode().
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import enumerate as enum_mod
from repro.core import topology, workflow
from repro.core.costmodel import (CostModel, default_draft_spec,
                                  speculative_expected_tokens)
from repro.core.ea import EvolutionarySearch
from repro.core.workflow import TaskKind
from repro.data.synthetic import EOS, VOCAB_SIZE
from repro.genserve.decoder import GenServeConfig, serve
from repro.models import cache as cache_mod
from repro.models import sampling
from repro.models import transformer as T
from repro.models.config import LayerSpec, Mixer, ModelConfig

KEY = jax.random.PRNGKey(0)
P, N = 8, 6


def spec_cfg(window=None, kv=2, n_heads=2, softcap=None, n_layers=2):
    return ModelConfig(name=f"sp-w{window}-kv{kv}-h{n_heads}-sc{softcap}",
                       n_layers=n_layers, d_model=64, n_heads=n_heads,
                       n_kv_heads=kv, head_dim=32, d_ff=128,
                       vocab_size=VOCAB_SIZE, dtype="float32",
                       attn_softcap=softcap,
                       pattern=(LayerSpec(window=window),))


def draft_for(cfg, key=5):
    """A 1-layer full-attention draft sharing the target's vocab."""
    dcfg = dataclasses.replace(cfg, name=f"{cfg.name}-draft", n_layers=1,
                               pattern=(LayerSpec(window=None),))
    return dcfg, T.init_params(jax.random.PRNGKey(key), dcfg)


def prompts_for(n, key=3, cfg=None):
    return jax.random.randint(jax.random.PRNGKey(key), (n, P), 0,
                              (cfg or spec_cfg()).vocab_size, jnp.int32)


def assert_rollout_equal(ref, got, atol=1e-4):
    mr, mg = np.asarray(ref["mask"]), np.asarray(got["mask"])
    np.testing.assert_array_equal(mr, mg)
    np.testing.assert_array_equal(
        np.asarray(ref["gen_tokens"]) * mr.astype(np.int32),
        np.asarray(got["gen_tokens"]) * mg.astype(np.int32))
    np.testing.assert_allclose(np.asarray(ref["logprobs"]) * mr,
                               np.asarray(got["logprobs"]) * mg,
                               rtol=1e-4, atol=atol)
    np.testing.assert_array_equal(np.asarray(ref["sequences"])[:, :P],
                                  np.asarray(got["sequences"])[:, :P])


def run_pair(cfg, gcfg_kw, spec_k, *, n_reqs=8, pkey=11, gen_lens=None,
             prompt_lens=None, prompts=None):
    """(non-spec rollout+stats, spec rollout+stats) on the same inputs."""
    params = T.init_params(KEY, cfg)
    dcfg, dparams = draft_for(cfg)
    if prompts is None:
        prompts = prompts_for(n_reqs, key=pkey, cfg=cfg)
    ref = serve(params, cfg, prompts, jax.random.PRNGKey(7),
                GenServeConfig(**gcfg_kw), gen_lens=gen_lens,
                prompt_lens=prompt_lens)
    got = serve(params, cfg, prompts, jax.random.PRNGKey(7),
                GenServeConfig(**gcfg_kw, spec_k=spec_k),
                gen_lens=gen_lens, prompt_lens=prompt_lens,
                draft_params=dparams, draft_cfg=dcfg)
    return ref, got


# ---------------------------------------------------------------------------
# Greedy parity: speculative == non-speculative, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,kv,ps,k,C", [
    (None, 2, 0, 2, 0),   # one-shot admission, full attention
    (None, 2, 0, 4, 3),   # chunked ragged admission
    (6, 2, 0, 3, 3),      # ring-window target (wraps mid-run)
    (None, 1, 4, 2, 3),   # paged + GQA
    (6, 1, 4, 4, 3),      # ring + GQA + paged
])
def test_spec_greedy_parity_matrix(window, kv, ps, k, C):
    """Greedy speculative decode is exact vs the plain engine under
    recycled slots (B > W), per-request budgets, EOS retirement and —
    when chunked — ragged prompt lengths."""
    cfg = spec_cfg(window, kv)
    lens = [N, 1, N, 2, 1, N, 2, N]
    plens = [8, 5, 3, 8, 4, 8, 6, 3] if C else None
    kw = dict(wave=3, max_new_tokens=N, eos_token=EOS, temperature=0.0,
              greedy=True, prefill_chunk=C, page_size=ps)
    (ref, _), (got, stats) = run_pair(cfg, kw, k, gen_lens=lens,
                                      prompt_lens=plens)
    assert_rollout_equal(ref, got)
    assert stats["spec_k"] == k
    assert 0 <= stats["spec_accepted"] <= stats["spec_proposed"]
    assert 0.0 <= stats["accept_rate"] <= 1.0


def test_spec_greedy_parity_gqa_softcap():
    """Logit softcapping and 4:2 grouped-query heads ride through the
    verify step unchanged."""
    cfg = spec_cfg(None, kv=2, n_heads=4, softcap=30.0)
    kw = dict(wave=3, max_new_tokens=N, eos_token=EOS, temperature=0.0,
              greedy=True)
    (ref, _), (got, _) = run_pair(cfg, kw, 3)
    assert_rollout_equal(ref, got)


def test_spec_greedy_parity_prefix_sharing():
    """Radix prefix reuse composes with speculation: shared prompt
    prefixes still skip their prefill and the decode stays exact."""
    cfg = spec_cfg()
    prompts = np.array(prompts_for(6, key=13, cfg=cfg))
    prompts[:, :6] = prompts[0, :6]         # shared system prompt
    prompts = jnp.asarray(prompts)
    kw = dict(wave=3, max_new_tokens=N, eos_token=EOS, temperature=0.0,
              greedy=True, prefill_chunk=4, page_size=4,
              prefix_cache=True)
    (ref, rstats), (got, gstats) = run_pair(cfg, kw, 2, prompts=prompts)
    assert_rollout_equal(ref, got)
    assert rstats["prefix_hit_rate"] > 0.0
    assert gstats["prefix_hit_rate"] == rstats["prefix_hit_rate"]


def test_spec_high_accept_draft_accepts_everything():
    """A target whose second layer is zeroed (residual identity) agrees
    with the 1-layer draft built from its own first layer — acceptance
    is total and the wave finishes in fewer rounds than plain decode
    takes steps."""
    cfg = spec_cfg(n_layers=2)
    params = T.init_params(KEY, cfg)
    params = dict(params, blocks=jax.tree_util.tree_map(
        lambda x: x.at[1].set(0.0) if x.shape[0] == 2 else x,
        params["blocks"]))
    dcfg = dataclasses.replace(cfg, name=f"{cfg.name}-self", n_layers=1)
    dparams = {"embed": params["embed"], "final_norm": params["final_norm"],
               "blocks": jax.tree_util.tree_map(lambda x: x[:1],
                                                params["blocks"])}
    prompts = prompts_for(4, key=9, cfg=cfg)
    NN = 8
    kw = dict(wave=4, max_new_tokens=NN, temperature=0.0, greedy=True)
    ref, rstats = serve(params, cfg, prompts, jax.random.PRNGKey(7),
                        GenServeConfig(**kw))
    got, stats = serve(params, cfg, prompts, jax.random.PRNGKey(7),
                       GenServeConfig(**kw, spec_k=4),
                       draft_params=dparams, draft_cfg=dcfg)
    assert_rollout_equal(ref, got)
    # near-total acceptance: the two paths run different matmul shapes
    # ([W, 1] draft step vs [W, k+1] verify chunk), so float rounding
    # may flip an occasional argmax near-tie — but never below 90%
    assert stats["accept_rate"] >= 0.9
    assert stats["decode_steps"] < rstats["decode_steps"]
    # ~5 tokens land per verify round instead of 1 per decode step
    assert stats["decode_steps"] <= NN // 2


# ---------------------------------------------------------------------------
# Sampled mode: contract invariants + distribution preservation
# ---------------------------------------------------------------------------

def test_spec_sampled_mode_contract():
    """Sampled speculation keeps the rollout contract: monotone masks,
    the first EOS valid, finite logprobs on valid positions, prompt
    prefixes untouched."""
    cfg = spec_cfg()
    params = T.init_params(KEY, cfg)
    dcfg, dparams = draft_for(cfg)
    prompts = prompts_for(6, key=17, cfg=cfg)
    ro, stats = serve(params, cfg, prompts, jax.random.PRNGKey(7),
                      GenServeConfig(wave=3, max_new_tokens=N,
                                     eos_token=EOS, temperature=1.0,
                                     greedy=False, spec_k=4),
                      draft_params=dparams, draft_cfg=dcfg)
    mask = np.asarray(ro["mask"])
    assert np.all(np.diff(mask, axis=1) <= 0), "mask must be monotone"
    lp = np.asarray(ro["logprobs"])
    assert np.all(np.isfinite(lp[mask.astype(bool)]))
    np.testing.assert_array_equal(np.asarray(ro["sequences"])[:, :P],
                                  np.asarray(prompts))
    gen = np.asarray(ro["gen_tokens"])
    eos_rows = mask.astype(bool) & (gen == EOS)
    # an emitted EOS is the row's last valid token
    for b, t in zip(*np.nonzero(eos_rows)):
        assert mask[b, t + 1:].sum() == 0
    assert stats["spec_proposed"] > 0
    assert 0 <= stats["spec_accepted"] <= stats["spec_proposed"]
    assert 0.0 <= stats["accept_rate"] <= 1.0


def test_speculative_accept_greedy_unit():
    """Hand-built logits: longest-prefix match, bonus = target argmax
    at the first mismatch (or position k after a clean sweep)."""
    B, k, V = 3, 3, 6
    tgt = np.array([[1, 2, 3, 4],      # drafts match 2, then diverge
                    [0, 0, 0, 0],      # immediate mismatch
                    [5, 1, 2, 3]])     # full accept -> bonus from row k
    logits = np.full((B, k + 1, V), -10.0, np.float32)
    for b in range(B):
        for j in range(k + 1):
            logits[b, j, tgt[b, j]] = 10.0
    drafts = jnp.asarray([[1, 2, 0], [1, 0, 0], [5, 1, 2]], jnp.int32)
    a, cand = sampling.speculative_accept(
        jax.random.PRNGKey(0), jnp.asarray(logits), drafts,
        jnp.zeros((B, k, V), jnp.float32), temperature=0.0, greedy=True)
    np.testing.assert_array_equal(np.asarray(a), [2, 0, 3])
    cand = np.asarray(cand)
    np.testing.assert_array_equal(cand[0, :3], [1, 2, 3])
    assert cand[1, 0] == 0
    np.testing.assert_array_equal(cand[2], [5, 1, 2, 3])


def test_speculative_accept_sampled_preserves_target():
    """Rejection-resampling emits the *target* distribution at the first
    speculated position even though proposals come from a very
    different draft: total variation to softmax(p) stays small."""
    B, V = 20000, 4
    p_logits = jnp.asarray([0.5, -0.5, 1.5, 0.0], jnp.float32)
    q_logits = jnp.asarray([2.0, 0.0, -2.0, 1.0], jnp.float32)
    tl = jnp.broadcast_to(p_logits, (B, 2, V))
    ql = jnp.broadcast_to(q_logits, (B, 1, V))
    k_d, k_a = jax.random.split(jax.random.PRNGKey(42))
    drafts = jax.random.categorical(k_d, ql, axis=-1).astype(jnp.int32)
    a, cand = sampling.speculative_accept(k_a, tl, drafts, ql,
                                          temperature=1.0, greedy=False)
    emitted = np.asarray(cand)[:, 0]    # accepted draft or corrected t*
    emp = np.bincount(emitted, minlength=V) / B
    target = np.asarray(jax.nn.softmax(p_logits))
    assert 0.5 * np.abs(emp - target).sum() < 0.03
    assert np.asarray(a).min() >= 0 and np.asarray(a).max() <= 1


# ---------------------------------------------------------------------------
# verify_chunk_step == C sequential decode steps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 4])
def test_verify_chunk_step_matches_sequential(window):
    cfg = spec_cfg(window)
    params = T.init_params(KEY, cfg)
    B, C = 3, 4
    prompts = prompts_for(B, key=21, cfg=cfg)
    out = T.forward(params, cfg, {"tokens": prompts}, return_cache=True,
                    max_cache_len=P + C, remat=False)
    chunk = jax.random.randint(jax.random.PRNGKey(23), (B, C), 0,
                               cfg.vocab_size, jnp.int32)
    base = out["cache"]
    pos0 = jnp.full((B,), P, jnp.int32)
    vlogits, deltas = T.verify_chunk_step(
        params, cfg, chunk, {"blocks": base["blocks"], "pos": pos0})
    cache = {"blocks": base["blocks"], "pos": pos0}
    seq_logits = []
    for c in range(C):
        lg, cache = T.decode_step(params, cfg, chunk[:, c:c + 1], cache)
        seq_logits.append(lg)
    np.testing.assert_allclose(np.asarray(vlogits),
                               np.stack([np.asarray(l) for l in seq_logits],
                                        axis=1), rtol=1e-4, atol=1e-4)
    # verify computes fresh chunk k/v but never writes the cache
    for name, d in deltas.items():
        assert set(d) == {"k", "v"} and d["k"].shape[2] == C


# ---------------------------------------------------------------------------
# Gating: what can(not) speculate, and why
# ---------------------------------------------------------------------------

def test_spec_support_predicates():
    full = spec_cfg(None)
    ring = spec_cfg(6)
    mamba = dataclasses.replace(full, pattern=(LayerSpec(mixer=Mixer.MAMBA),))
    assert cache_mod.supports_speculative_target(full)
    assert cache_mod.supports_speculative_target(ring)   # fresh-chunk verify
    assert cache_mod.supports_speculative_draft(full)
    assert not cache_mod.supports_speculative_draft(ring)  # no ring rollback
    assert not cache_mod.supports_speculative_target(mamba)


def test_spec_gating_errors():
    cfg = spec_cfg()
    params = T.init_params(KEY, cfg)
    prompts = prompts_for(2, cfg=cfg)
    gcfg = GenServeConfig(wave=2, max_new_tokens=2, temperature=0.0,
                          greedy=True, spec_k=2)
    ring_draft = dataclasses.replace(cfg, name="rd",
                                     pattern=(LayerSpec(window=4),))
    with pytest.raises(AssertionError, match="full-window"):
        serve(params, cfg, prompts, KEY, gcfg,
              draft_params=T.init_params(KEY, ring_draft),
              draft_cfg=ring_draft)
    bad_vocab = dataclasses.replace(cfg, name="bv",
                                    vocab_size=cfg.vocab_size + 1)
    with pytest.raises(AssertionError, match="vocabulary"):
        serve(params, cfg, prompts, KEY, gcfg,
              draft_params=T.init_params(KEY, bad_vocab),
              draft_cfg=bad_vocab)
    with pytest.raises(AssertionError, match="draft_params"):
        serve(params, cfg, prompts, KEY, gcfg)
    with pytest.raises(AssertionError, match="batched"):
        GenServeConfig(wave=2, max_new_tokens=2, spec_k=2,
                       decode_path="vmapped").validate()


# ---------------------------------------------------------------------------
# Satellite: short-validity chunk writes (the machinery accepts ride on)
# ---------------------------------------------------------------------------

def _ref_write(cache, new, pos, window, valid):
    """Token-by-token reference for write_kv's scatter semantics."""
    B, C = new.shape[:2]
    L = cache.shape[1]
    ref = np.array(cache)
    for b in range(B):
        wrote_end = False
        for c in range(C):
            if not valid[b, c]:
                continue
            p = pos[b] + c
            if window is not None:
                ref[b, p % L] = new[b, c]
            elif p < L - 1:
                ref[b, p] = new[b, c]
            elif not wrote_end:
                ref[b, L - 1] = new[b, c]   # keep-first clamp
                wrote_end = True
    return ref


@pytest.mark.parametrize("window,pos,n_valid", [
    (4, [2, 3, 0], [3, 5, 0]),     # accept < k wrapping the ring mid-chunk
    (4, [0, 1, 2], [0, 0, 0]),     # accept 0 everywhere: cache untouched
    (None, [3, 6, 0], [4, 3, 2]),  # full cache, clamped tail keep-first
])
def test_write_kv_short_validity(window, pos, n_valid):
    B, C, L, KV, hd = 3, 5, 8 if window is None else window, 2, 4
    rng = np.random.default_rng(0)
    cache = rng.standard_normal((B, L, KV, hd)).astype(np.float32)
    k_new = rng.standard_normal((B, C, KV, hd)).astype(np.float32)
    v_new = rng.standard_normal((B, C, KV, hd)).astype(np.float32)
    valid = np.arange(C)[None, :] < np.asarray(n_valid)[:, None]
    ck, cv = cache_mod.write_kv(jnp.asarray(cache), jnp.asarray(cache),
                                jnp.asarray(k_new), jnp.asarray(v_new),
                                jnp.asarray(pos, jnp.int32), window,
                                valid=jnp.asarray(valid))
    np.testing.assert_allclose(np.asarray(ck),
                               _ref_write(cache, k_new, pos, window, valid))
    np.testing.assert_allclose(np.asarray(cv),
                               _ref_write(cache, v_new, pos, window, valid))
    if not valid.any():
        np.testing.assert_array_equal(np.asarray(ck), cache)


def test_paged_update_chunk_short_validity():
    """Direct unit: live chunk tokens land at btab[slot//ps] pages, a
    zero-valid row leaves its pool pages untouched."""
    cfg = ModelConfig(name="pgu", n_layers=1, d_model=16, n_heads=2,
                      n_kv_heads=2, head_dim=8, d_ff=32,
                      vocab_size=VOCAB_SIZE, dtype="float32",
                      pattern=(LayerSpec(window=None),))
    B, C, ps, max_seq = 3, 4, 2, 8
    spec = cfg.pattern[0]
    L = cache_mod.kv_cache_len(cfg, spec, max_seq, False)
    n_pool, KV, hd = B * (L // ps), 2, 8
    rng = np.random.default_rng(1)
    pool = np.zeros((1, n_pool, ps, KV, hd), np.float32)
    view = rng.standard_normal((1, B, L, KV, hd)).astype(np.float32)
    btab = np.arange(B * (L // ps), dtype=np.int32).reshape(B, L // ps)
    pcur, n_valid = [0, 3, 5], [4, 2, 0]
    out = cache_mod.paged_update_chunk(
        cfg, {"layer0": {"k": jnp.asarray(pool), "v": jnp.asarray(pool)}},
        {"layer0": {"k": jnp.asarray(view), "v": jnp.asarray(view)}},
        jnp.asarray(btab), jnp.asarray(pcur, jnp.int32),
        jnp.asarray(n_valid, jnp.int32), C, max_seq, page_size=ps)
    ref = pool.copy()
    for b in range(B):
        for c in range(n_valid[b]):
            slot = min(pcur[b] + c, L - 1)
            ref[0, btab[b, slot // ps], slot % ps] = view[0, b, slot]
    np.testing.assert_allclose(np.asarray(out["layer0"]["k"]), ref)
    np.testing.assert_allclose(np.asarray(out["layer0"]["v"]), ref)
    # the zero-valid row's pages are bitwise untouched
    np.testing.assert_array_equal(np.asarray(out["layer0"]["k"])[0, btab[2]],
                                  pool[0, btab[2]])


# ---------------------------------------------------------------------------
# Cost model + scheduler choice
# ---------------------------------------------------------------------------

def _sched_setup():
    cfg = ModelConfig(name="spec-cm-t", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=VOCAB_SIZE,
                      dtype="float32")
    spec = workflow.LLMSpec.from_model_config(cfg)
    wf = workflow.make_workflow("grpo", spec, synchronous=True,
                                n_rollouts=2, seq_in=8, seq_out=4,
                                global_batch=1)
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 2, "L4": 2})
    grouping = (tuple(range(wf.n_tasks)),)
    sizes = [topo.n]
    plan = enum_mod.build_plan(topo, wf, grouping, sizes,
                               list(range(topo.n)))
    return topo, wf, grouping, sizes, plan


def test_speculative_expected_tokens_bounds():
    assert speculative_expected_tokens(4, 0.0) == 1.0
    assert speculative_expected_tokens(4, 1.0) == 5.0
    rates = [speculative_expected_tokens(4, a)
             for a in (0.0, 0.3, 0.6, 0.9, 1.0)]
    assert all(b > a for a, b in zip(rates, rates[1:]))
    assert 1.0 < rates[1] < 5.0


def test_gen_speculative_wave_pricing():
    topo, wf, _, _, plan = _sched_setup()
    cm = CostModel(topo, wf)
    gen = [t for t in range(wf.n_tasks)
           if wf.task(t).kind == TaskKind.GEN][0]
    # k = 0 degenerates to the plain HBM decode bound
    assert cm.gen_speculative_wave(plan, gen, spec_k=0) == \
        pytest.approx(cm.c_hbm(plan, gen, 0, 0))
    # a better draft (higher acceptance) can only get cheaper
    costs = [cm.gen_speculative_wave(plan, gen, spec_k=4, accept_rate=a)
             for a in (0.0, 0.5, 0.9)]
    assert costs[0] > costs[1] > costs[2] > 0.0
    # at zero acceptance speculation only adds draft work
    assert costs[0] > cm.c_hbm(plan, gen, 0, 0)
    # task_cost swaps in the speculative bound when the plan opts in
    # (its hbm term is the one at the worst dp-replica / pp-stage)
    base = cm.task_cost(plan, gen).hbm
    plan.gen_spec[gen] = 4
    spec_hbm = cm.task_cost(plan, gen).hbm
    plan.gen_spec.pop(gen)
    dp, pp, _ = plan.parallel[gen]
    per_stage = [cm.gen_speculative_wave(plan, gen, i, j, spec_k=4)
                 for i in range(dp) for j in range(pp)]
    assert any(spec_hbm == pytest.approx(v) for v in per_stage)
    assert spec_hbm != pytest.approx(base)


def test_default_draft_spec_shrinks():
    m = workflow.QWEN_1_7B
    d = default_draft_spec(m)
    assert d.n_layers == max(m.n_layers // 4, 1)
    assert d.h1 < m.h1 and d.layer_weight_count < m.layer_weight_count
    assert d.vocab == m.vocab


def test_ea_decode_spec_best_response_deterministic():
    """decode() picks the cost-model-cheapest draft-k per GEN task — a
    deterministic best response, so re-decoding the same genome yields
    the same gen_spec (the incumbent-stability invariant)."""
    topo, wf, grouping, sizes, _ = _sched_setup()
    es = EvolutionarySearch(topo, wf, grouping, sizes, seed=0)
    ind = es._seeded_individual()
    plan = es.decode(ind)
    plan2 = es.decode(ind)
    assert plan.gen_spec == plan2.gen_spec
    for t in es._spec_tasks:
        best = min((0, 2, 4, 8),
                   key=lambda k: es.cm.gen_speculative_wave(plan, t, 0, 0,
                                                            spec_k=k))
        assert plan.gen_spec.get(t, 0) == best
