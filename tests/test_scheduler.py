"""Scheduler unit + integration tests: cost model, constraints, SHA-EA,
ILP optimality on tiny instances, baselines, simulator consistency."""
import math

import numpy as np
import pytest

from repro.core import baselines, enumerate as enum_mod, loadbalance, \
    simulator, topology, workflow
from repro.core.costmodel import CostModel, ring_cost
from repro.core.ilp import ilp_scheduler
from repro.core.plan import check_constraints, memory_overflow
from repro.core.sha import HybridScheduler


@pytest.fixture(scope="module")
def small_topo():
    return topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})


@pytest.fixture(scope="module")
def big_topo():
    return topology.build_testbed("multi_country")


@pytest.fixture(scope="module")
def grpo_wf():
    return workflow.make_grpo(workflow.QWEN_1_7B, global_batch=64)


@pytest.fixture(scope="module")
def ppo_wf():
    return workflow.make_ppo(workflow.QWEN_8B)


def test_set_partitions_bell_numbers():
    # B_1..B_6 = 1, 2, 5, 15, 52, 203
    for n, bell in [(1, 1), (2, 2), (3, 5), (4, 15), (5, 52), (6, 203)]:
        parts = enum_mod.set_partitions(range(n))
        assert len(parts) == bell
        for p in parts:
            flat = sorted(x for b in p for x in b)
            assert flat == list(range(n))


def test_workflow_structure():
    ppo = workflow.make_ppo(workflow.QWEN_4B)
    assert ppo.n_tasks == 6
    stages = ppo.stages()
    assert stages == [[0], [1, 2, 3], [4, 5]]
    grpo = workflow.make_grpo(workflow.QWEN_4B)
    assert grpo.n_tasks == 4
    assert grpo.stages() == [[0], [1, 2], [3]]


def test_ring_cost_properties(small_topo):
    devs = [0, 1, 2, 3]
    c1 = ring_cost(small_topo, devs, 1e6)
    c2 = ring_cost(small_topo, devs, 1e9)
    assert 0 < c1 < c2          # monotone in volume
    assert ring_cost(small_topo, [0], 1e9) == 0.0
    # exact two-device cost
    expected = small_topo.alpha(0, 1) + 1e9 / (small_topo.beta(0, 1) * 1e9)
    assert abs(ring_cost(small_topo, [0, 1], 1e9) - expected) < 1e-12


def test_plan_constraints_catch_violations(small_topo, grpo_wf):
    grouping = (tuple(range(grpo_wf.n_tasks)),)
    plan = enum_mod.build_plan(small_topo, grpo_wf, grouping, [8],
                               list(range(8)))
    ok, msg = check_constraints(small_topo, grpo_wf, plan)
    assert ok, msg
    # break it: duplicate device in a task's assignment
    t0 = list(plan.assignment)[0]
    plan.assignment[t0] = np.zeros_like(plan.assignment[t0])
    ok, msg = check_constraints(small_topo, grpo_wf, plan)
    assert not ok


def test_cost_model_monotonic_in_compute(small_topo, grpo_wf):
    grouping = (tuple(range(grpo_wf.n_tasks)),)
    plan = enum_mod.build_plan(small_topo, grpo_wf, grouping, [8],
                               list(range(8)))
    base = CostModel(small_topo, grpo_wf).cost(plan)
    # double every device's TFLOPS -> cost strictly decreases
    fast = topology.Topology(
        [topology.Device(d.id, topology.GPUSpec(
            d.spec.name, d.spec.fp16_tflops * 2, d.spec.mem_gb,
            d.spec.hbm_gbps, d.spec.intra_node_gbps),
            d.machine, d.zone, d.region) for d in small_topo.devices],
        small_topo.latency_s, small_topo.bandwidth_gbps)
    faster = CostModel(fast, grpo_wf).cost(plan)
    assert faster < base


def test_cost_model_monotonic_in_bandwidth(small_topo, grpo_wf):
    grouping = (tuple(range(grpo_wf.n_tasks)),)
    plan = enum_mod.build_plan(small_topo, grpo_wf, grouping, [8],
                               list(range(8)))
    base = CostModel(small_topo, grpo_wf).cost(plan)
    slow = topology.Topology(small_topo.devices, small_topo.latency_s,
                             small_topo.bandwidth_gbps * 0.1)
    slower = CostModel(slow, grpo_wf).cost(plan)
    assert slower >= base


def test_sha_ea_beats_baselines(big_topo, ppo_wf):
    sched = HybridScheduler(big_topo, ppo_wf, max_groupings=8,
                            max_sizes_per_grouping=4)
    r = sched.search(budget=120)
    assert r.plan is not None
    ok, msg = check_constraints(big_topo, ppo_wf, r.plan)
    assert ok, msg
    r_verl = baselines.verl_scheduler(big_topo, ppo_wf)
    assert r.cost <= r_verl.cost * 1.01


def test_sha_improves_with_budget(big_topo, ppo_wf):
    costs = []
    for budget in (40, 400):
        sched = HybridScheduler(big_topo, ppo_wf, max_groupings=8,
                                max_sizes_per_grouping=4, seed=3)
        costs.append(sched.search(budget=budget).cost)
    assert costs[1] <= costs[0]


def test_ilp_not_worse_than_sha(small_topo, grpo_wf):
    r_ilp = ilp_scheduler(small_topo, grpo_wf, max_seconds=90,
                          max_nodes=500_000)
    assert r_ilp.plan is not None
    sched = HybridScheduler(small_topo, grpo_wf, max_groupings=15,
                            max_sizes_per_grouping=6)
    r_sha = sched.search(budget=1200)
    assert r_ilp.cost <= r_sha.cost * 1.001
    # SHA-EA near-optimality (paper: within 1%; we allow 8% at this budget)
    assert r_sha.cost <= r_ilp.cost * 1.08


def test_streamrl_and_deap_run(big_topo, ppo_wf):
    r_srl = baselines.streamrl_scheduler(big_topo, ppo_wf, budget=512)
    assert r_srl.plan is not None and math.isfinite(r_srl.cost)
    r_deap = baselines.deap_scheduler(big_topo, ppo_wf, budget=60)
    assert math.isfinite(r_deap.cost)


def test_simulator_matches_costmodel_sync(big_topo, ppo_wf):
    sched = HybridScheduler(big_topo, ppo_wf, max_groupings=6,
                            max_sizes_per_grouping=3)
    r = sched.search(budget=60)
    sim = simulator.simulate(big_topo, ppo_wf, r.plan)
    # event-driven timeline vs closed-form composition: within 20%
    assert abs(sim.iteration_time - r.cost) / r.cost < 0.20


def test_async_faster_than_sync(big_topo):
    wf_sync = workflow.make_ppo(workflow.QWEN_8B, synchronous=True)
    wf_async = workflow.make_ppo(workflow.QWEN_8B, synchronous=False)
    grouping = enum_mod.priority_groupings(wf_sync)[2]  # gen | rest
    sizes = enum_mod.proportional_sizes(wf_sync, grouping, big_topo.n)
    plan = enum_mod.build_plan(big_topo, wf_sync, grouping, sizes,
                               list(range(big_topo.n)))
    ok, _ = check_constraints(big_topo, wf_sync, plan)
    if ok:
        c_sync = CostModel(big_topo, wf_sync).cost(plan)
        c_async = CostModel(big_topo, wf_async).cost(plan)
        assert c_async <= c_sync


def test_load_balancing_helps_or_neutral(big_topo, ppo_wf):
    grouping = (tuple(range(ppo_wf.n_tasks)),)
    plan = enum_mod.build_plan(big_topo, ppo_wf, grouping, [big_topo.n],
                               list(range(big_topo.n)))
    cm = CostModel(big_topo, ppo_wf)
    base = cm.cost(plan)
    balanced = loadbalance.balance(big_topo, ppo_wf, plan)
    assert cm.cost(balanced) <= base * 1.001


def test_memory_overflow_metric(small_topo, grpo_wf):
    grouping = (tuple(range(grpo_wf.n_tasks)),)
    plan = enum_mod.build_plan(small_topo, grpo_wf, grouping, [8],
                               list(range(8)))
    over = memory_overflow(small_topo, grpo_wf, plan)
    ok, msg = check_constraints(small_topo, grpo_wf, plan)
    assert (over == 0.0) == ok


def test_scenarios_build():
    for scen in topology.SCENARIOS:
        topo = topology.build_testbed(scen)
        assert topo.n == 64
        assert (topo.bandwidth_gbps > 0).all()
        assert (topo.latency_s >= 0).all()
    tpu = topology.build_tpu_pool()
    assert tpu.n == 48


def test_async_trainer_pipeline():
    """Async RL: first call fills the pipeline, later calls train on the
    previous rollouts; reward still climbs."""
    import jax
    from repro.data.synthetic import AdditionTask, PromptDataset, VOCAB_SIZE
    from repro.models.config import ModelConfig
    from repro.rl.trainer import RLConfig, RLTrainer

    cfg = ModelConfig(name="async-t", n_layers=2, d_model=96, n_heads=4,
                      n_kv_heads=2, head_dim=24, d_ff=192,
                      vocab_size=VOCAB_SIZE, dtype="float32")
    task = AdditionTask(max_operand=4)
    rl = RLConfig(algorithm="grpo", n_rollouts=8, max_new_tokens=3,
                  lr=5e-4, kl_beta=0.0, asynchronous=True)
    trainer = RLTrainer(cfg, rl, task, jax.random.PRNGKey(0))
    import numpy as np
    ds = iter(PromptDataset(task, batch=12, seed=1))
    key = jax.random.PRNGKey(9)
    rewards = []
    for it in range(10):
        prompts, answers = next(ds)
        key, k = jax.random.split(key)
        m = trainer.iteration(prompts, answers, k)
        if it == 0:
            assert m.get("pipeline_fill") == 1.0
        else:
            rewards.append(m["reward_mean"])
    assert np.mean(rewards[-3:]) >= np.mean(rewards[:3]) - 0.02


def test_online_redeployment():
    """§6: network degradation triggers a beneficial reschedule at the
    checkpoint boundary; a no-op change keeps the incumbent."""
    import numpy as np
    from repro.core import redeploy
    topo = topology.build_testbed("single_region")
    wf = workflow.make_grpo(workflow.QWEN_8B)
    sched = HybridScheduler(topo, wf, max_groupings=8,
                            max_sizes_per_grouping=4)
    r = sched.search(budget=120)

    # same topology: switching should not be (meaningfully) beneficial
    d_same = redeploy.reschedule(topo, wf, r.plan, budget=60)
    assert d_same.old_cost <= d_same.new_cost * 1.5

    # degrade half the cluster's links 20x: expect a valid decision with
    # finite costs either way
    topo2 = topology.Topology(topo.devices, topo.latency_s * 10,
                              topo.bandwidth_gbps * 0.05)
    d = redeploy.reschedule(topo2, wf, r.plan, budget=120)
    assert np.isfinite(d.new_cost)
    assert d.plan is not None
    ok, _ = check_constraints(topo2, wf, d.plan)
    assert ok
