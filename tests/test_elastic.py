"""Elastic plan-swap tests (§6 online redeployment).

- transition cost: moved bytes route over the bottleneck (min) link of
  each task's chosen paths, pinned on a two-region testbed;
- topology drift primitives: degrade_links / drop_devices / DriftSchedule;
- Engine.apply_plan: plan-epoch semantics, state preservation, epoch-aware
  measured-vs-predicted, async one-step staleness across the swap;
- swap-to-identical-plan == no-swap, bitwise, on training metrics;
- checkpoint round-trips of the full live trainer state tree;
- reschedule warm start: an undisturbed topology rediscovers the
  incumbent (switch=False, challenger never worse).
"""
import math
import os

import jax
import numpy as np
import pytest

from repro.core import enumerate as enum_mod, redeploy, topology, workflow
from repro.core.plan import BYTES_BF16, check_constraints
from repro.core.sha import HybridScheduler
from repro.data.synthetic import AdditionTask, VOCAB_SIZE
from repro.engine.elastic import ElasticConfig, ElasticController
from repro.engine.executor import MIGRATION_TASK
from repro.models.config import ModelConfig
from repro.rl.trainer import RLConfig, RLTrainer

KEY = jax.random.PRNGKey(0)


def tiny_cfg():
    return ModelConfig(name="el-tiny", n_layers=2, d_model=64, n_heads=2,
                       n_kv_heads=2, head_dim=32, d_ff=128,
                       vocab_size=VOCAB_SIZE, dtype="float32")


def reference_pool():
    return topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})


def make_wf(cfg, task, *, asynchronous=False, batch=1):
    spec = workflow.LLMSpec.from_model_config(cfg)
    return workflow.make_workflow("grpo", spec,
                                  synchronous=not asynchronous,
                                  n_rollouts=4, seq_in=task.prompt_len,
                                  seq_out=4, global_batch=batch)


def grouped_plan(topo, wf, grouping):
    sizes = enum_mod.proportional_sizes(wf, grouping, topo.n)
    plan = enum_mod.build_plan(topo, wf, grouping, sizes,
                               list(range(topo.n)))
    ok, msg = check_constraints(topo, wf, plan)
    assert ok, msg
    return plan


def make_trainer(asynchronous=False, grouping="gen|rest"):
    cfg = tiny_cfg()
    task = AdditionTask(max_operand=9)
    topo = reference_pool()
    wf = make_wf(cfg, task, asynchronous=asynchronous)
    if grouping == "gen|rest":
        g = tuple(sorted(((0,), tuple(range(1, wf.n_tasks)))))
    else:
        g = (tuple(range(wf.n_tasks)),)
    plan = grouped_plan(topo, wf, g)
    rl = RLConfig(algorithm="grpo", n_rollouts=4, max_new_tokens=4,
                  asynchronous=asynchronous)
    trainer = RLTrainer(cfg, rl, task, KEY, plan=plan, topo=topo, wf=wf)
    return trainer, topo, wf


def run_iters(trainer, n, batch=4, seed=0):
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(7)
    out = []
    for _ in range(n):
        prompts, answers = trainer.task.sample_batch(rng, batch)
        key, k = jax.random.split(key)
        out.append(trainer.iteration(prompts, answers, k))
    return out


# ---------------------------------------------------------------------------
# transition cost (satellite: bottleneck routing)
# ---------------------------------------------------------------------------

def two_region_topo():
    """4 devices, two regions; asymmetric cross-region bandwidths so the
    best-link-anywhere (old bug) and bottleneck routings disagree."""
    devs = [topology.Device(i, topology.A100, machine=i // 2, zone=0,
                            region="east" if i < 2 else "west")
            for i in range(4)]
    lat = np.full((4, 4), 1e-3)
    bw = np.array([
        #  0     1     2     3
        [2039., 100., 8.0, 1.0],   # 0 (east)
        [100., 2039., 4.0, 2.0],   # 1 (east)
        [8.0, 4.0, 2039., 100.],   # 2 (west)
        [1.0, 2.0, 100., 2039.],   # 3 (west)
    ])
    return topology.Topology(devs, lat, bw)


def plan_on(wf, devices_per_task):
    """Minimal plan stub: every task dp=|devs|, pp=tp=1."""
    from repro.core.plan import Plan, TaskGroup
    parallel, assignment = {}, {}
    for t, devs in devices_per_task.items():
        parallel[t] = (len(devs), 1, 1)
        assignment[t] = np.array(devs).reshape(len(devs), 1, 1)
    groups = (TaskGroup(tuple(devices_per_task),
                        tuple(sorted({d for ds in devices_per_task.values()
                                      for d in ds}))),)
    return Plan(groups, parallel, assignment)


def test_transition_cost_routes_over_bottleneck():
    topo = two_region_topo()
    cfg = tiny_cfg()
    task = AdditionTask(max_operand=9)
    wf = make_wf(cfg, task)
    n_tasks = wf.n_tasks
    # only task 0 moves: east {0, 1} -> west {2, 3}
    old = plan_on(wf, {t: [0, 1] for t in range(n_tasks)})
    new_assign = {t: [0, 1] for t in range(n_tasks)}
    new_assign[0] = [2, 3]
    new = plan_on(wf, new_assign)

    # chosen path per destination is its best source link (max over
    # sources); the task completes at the slowest chosen link (min over
    # destinations): dest 2 <- max(8, 4) = 8, dest 3 <- max(1, 2) = 2
    bottleneck = 2.0
    w = wf.task(0).model.total_weight_count
    expected = BYTES_BF16 * w * 2 / 2 / (bottleneck * 1e9)
    got = redeploy.transition_cost(topo, wf, old, new)
    assert got == pytest.approx(expected, rel=1e-12)
    # the old implementation took max beta over ALL (old, moved) pairs
    # (= 8.0 here) — ensure we are NOT doing that
    assert got > BYTES_BF16 * w / (8.0 * 1e9) * 0.99

    # unchanged plan moves nothing
    assert redeploy.transition_cost(topo, wf, old, old) == 0.0


def test_reindexed_ids_detected_against_old_topology():
    """drop_devices densely re-indexes survivors, so a surviving id can
    alias a different physical device after a non-suffix drop; with the
    old topology in hand both transition_cost and reschedule must trust
    identity, not raw ids."""
    topo = two_region_topo()
    cfg = tiny_cfg()
    task = AdditionTask(max_operand=9)
    wf = make_wf(cfg, task)
    small = topology.drop_devices(topo, [0])
    # new id space: 0 <- old 1 (east, unchanged identity), 1 <- old 2
    # (west — id 1 used to be east!), 2 <- old 3 (west, unchanged)
    old = plan_on(wf, {t: [1, 2] for t in range(wf.n_tasks)})
    new = plan_on(wf, {t: [0, 1] for t in range(wf.n_tasks)})
    naive = redeploy.transition_cost(small, wf, old, new)
    aware = redeploy.transition_cost(small, wf, old, new, topo_old=topo)
    # identity-aware: old id 1 no longer names the same device, so only
    # old id 2 (now west) can serve — different source set, different cost
    assert aware != naive
    # an incumbent whose ids changed identity is priced infeasible
    d = redeploy.reschedule(small, wf, old, budget=40, topo_old=topo)
    assert d.old_cost == math.inf


def test_transition_cost_ignores_dropped_sources():
    topo = two_region_topo()
    cfg = tiny_cfg()
    wf = make_wf(cfg, AdditionTask(max_operand=9))
    small = topology.drop_devices(topo, [2, 3])
    # old plan lived on now-dropped devices: no surviving source — the
    # move is priced as free (checkpoint restore covers it), not a crash
    old = plan_on(wf, {t: [2, 3] for t in range(wf.n_tasks)})
    new = plan_on(wf, {t: [0, 1] for t in range(wf.n_tasks)})
    assert redeploy.transition_cost(small, wf, old, new) == 0.0


# ---------------------------------------------------------------------------
# drift primitives
# ---------------------------------------------------------------------------

def test_degrade_links():
    topo = reference_pool()
    d = topology.degrade_links(topo, bw_factor=0.1, lat_factor=10.0)
    assert d is not topo and topo.n == d.n
    cross = [(i, j) for i in range(topo.n) for j in range(topo.n)
             if i != j and topo.devices[i].machine != topo.devices[j].machine]
    for i, j in cross:
        assert d.bandwidth_gbps[i, j] == pytest.approx(
            topo.bandwidth_gbps[i, j] * 0.1)
        assert d.latency_s[i, j] == pytest.approx(
            topo.latency_s[i, j] * 10.0)
    # intra-machine links and HBM diagonal untouched
    same = [(i, j) for i in range(topo.n) for j in range(topo.n)
            if topo.devices[i].machine == topo.devices[j].machine]
    for i, j in same:
        assert d.bandwidth_gbps[i, j] == topo.bandwidth_gbps[i, j]
    # input topology not mutated
    assert not topology.topo_equal(topo, d)
    # seeded subsampling is deterministic
    a = topology.degrade_links(topo, fraction=0.5, seed=3)
    b = topology.degrade_links(topo, fraction=0.5, seed=3)
    assert topology.topo_equal(a, b)


def test_drop_devices_reindexes():
    topo = reference_pool()
    d = topology.drop_devices(topo, [0, 5])
    assert d.n == topo.n - 2
    assert [dev.id for dev in d.devices] == list(range(d.n))
    kept = [i for i in range(topo.n) if i not in (0, 5)]
    for new_i, old_i in enumerate(kept):
        assert d.devices[new_i].spec == topo.devices[old_i].spec
        for new_j, old_j in enumerate(kept):
            assert d.bandwidth_gbps[new_i, new_j] == \
                topo.bandwidth_gbps[old_i, old_j]
    with pytest.raises(ValueError):
        topology.drop_devices(topo, list(range(topo.n)))


def test_drift_schedule():
    topo = reference_pool()
    deg = topology.degrade_links(topo, bw_factor=0.1)
    sch = topology.DriftSchedule(topo, [
        topology.DriftEvent(8, "recover", topo),
        topology.DriftEvent(3, "degrade", deg),
    ])
    assert sch.topo_at(0) is topo
    assert sch.topo_at(3) is deg
    assert sch.topo_at(7) is deg
    assert sch.topo_at(8) is topo
    gen = topology.DriftSchedule.generate(topo, seed=5, n_events=2)
    gen2 = topology.DriftSchedule.generate(topo, seed=5, n_events=2)
    assert len(gen.events) == 3           # 2 degradations + recovery
    for e1, e2 in zip(gen.events, gen2.events):
        assert topology.topo_equal(e1.topo, e2.topo)
    for name in topology.DRIFT_SCENARIOS:
        s = topology.drift_scenario(name, topo, at=2)
        assert not topology.topo_equal(s.topo_at(2), topo) or name == "flaky"


# ---------------------------------------------------------------------------
# engine plan swap
# ---------------------------------------------------------------------------

def test_apply_plan_swaps_context():
    trainer, topo, wf = make_trainer()
    run_iters(trainer, 2)
    old_plan = trainer.plan
    colocated = grouped_plan(topo, wf, (tuple(range(wf.n_tasks)),))
    info = trainer.engine.apply_plan(colocated, topo=topo)
    assert trainer.engine.epoch == 1
    assert trainer.plan is colocated          # trainer tracks the engine
    assert trainer.engine.ctx_history[0].plan is old_plan
    run_iters(trainer, 2)
    # events carry their epoch; the migration marker sits between them
    epochs = {e.epoch for e in trainer.engine.timeline}
    assert epochs == {0, 1}
    mig = [e for e in trainer.engine.timeline if e.task == MIGRATION_TASK]
    assert len(mig) == 2
    assert mig[1].time - mig[0].time == pytest.approx(
        info["transition_cost_s"])
    # post-swap replay runs on the new plan's devices, starting after the
    # migration window
    post = [e for e in trainer.engine.timeline
            if e.epoch == 1 and e.task != MIGRATION_TASK]
    assert post and min(e.time for e in post) >= info["migration_end_s"]


def test_apply_plan_preserves_trainer_state():
    trainer, topo, wf = make_trainer()
    metrics_before = run_iters(trainer, 2)
    wv = trainer.weight_version
    actor_before = trainer.actor
    colocated = grouped_plan(topo, wf, (tuple(range(wf.n_tasks)),))
    trainer.engine.apply_plan(colocated, topo=topo)
    # swap touches no training state
    assert trainer.weight_version == wv
    assert trainer.actor is actor_before
    metrics_after = run_iters(trainer, 2)
    assert trainer.weight_version == wv + 2   # monotone, no reset
    assert all(np.isfinite(m["loss"]) for m in metrics_before + metrics_after)


def test_swap_to_identical_plan_is_bitwise_noop():
    ms = {}
    for swap in (False, True):
        trainer, topo, wf = make_trainer()
        out = run_iters(trainer, 1)
        if swap:
            trainer.engine.apply_plan(trainer.plan, topo=topo)
        out += run_iters(trainer, 3, seed=1)
        ms[swap] = out
    assert [sorted(m) for m in ms[False]] == [sorted(m) for m in ms[True]]
    for m0, m1 in zip(ms[False], ms[True]):
        for k in m0:
            assert m0[k] == m1[k], f"metric {k} diverged across the swap"


def test_async_staleness_survives_swap():
    trainer, topo, wf = make_trainer(asynchronous=True)
    run_iters(trainer, 3)
    colocated = grouped_plan(topo, wf, (tuple(range(wf.n_tasks)),))
    info = trainer.engine.apply_plan(colocated, topo=topo)  # carry (default)
    assert info["dropped_bundles"] == 0.0
    run_iters(trainer, 3, seed=1)
    recs = trainer.engine.pipeline.records
    # fill iteration trains version-0 rollouts; from then on the bundle
    # being trained is exactly one sync behind — including across the swap
    assert (recs[0].gen_version, recs[0].weight_version) == (0, 0)
    for r in recs[1:]:
        assert r.weight_version - r.gen_version == 1, r


def test_drain_refills_pipeline():
    trainer, topo, wf = make_trainer(asynchronous=True)
    run_iters(trainer, 3)
    n_recs = len(trainer.engine.pipeline.records)
    info = trainer.engine.apply_plan(trainer.plan, topo=topo,
                                     carry_pending=False)
    assert info["dropped_bundles"] == 1.0
    metrics = run_iters(trainer, 2, seed=1)
    # first post-swap iteration refills the pipeline (nothing to train)
    assert metrics[0].get("pipeline_fill") == 1.0
    assert "pipeline_fill" not in metrics[1]
    assert len(trainer.engine.pipeline.records) == n_recs + 1


def test_measured_result_epoch_aware():
    trainer, topo, wf = make_trainer()
    run_iters(trainer, 3)
    # swap onto a severely degraded topology (every link, so the weight
    # migration is guaranteed slow): the migration window would corrupt a
    # gen-start delta that straddled the swap
    all_pairs = [(i, j) for i in range(topo.n) for j in range(i + 1, topo.n)]
    degraded = topology.degrade_links(topo, bw_factor=1e-6, pairs=all_pairs)
    colocated = grouped_plan(topo, wf, (tuple(range(wf.n_tasks)),))
    info = trainer.engine.apply_plan(colocated, topo=degraded)
    assert info["transition_cost_s"] > 10.0
    run_iters(trainer, 2, seed=1)
    meas = trainer.engine.measured_result()
    # the iteration-time estimate comes from within epoch 1 — it must not
    # include the (huge) migration window
    assert meas.iteration_time < info["transition_cost_s"]
    cmp = trainer.engine.compare_with_simulator()
    assert cmp["epoch"] == 1.0 and np.isfinite(cmp["ratio"])
    rows = trainer.engine.epoch_report()
    assert [r["epoch"] for r in rows] == [0, 1]
    assert rows[0]["iterations"] == 3 and rows[1]["iterations"] == 2
    assert all(np.isfinite(r["measured_iter_s"]) for r in rows)
    assert rows[1]["measured_iter_s"] < info["transition_cost_s"]


# ---------------------------------------------------------------------------
# checkpoint round-trips of live trainer state (satellite)
# ---------------------------------------------------------------------------

def tree_equal(a, b):
    fa, ta = jax.tree_util.tree_flatten(a)
    fb, tb = jax.tree_util.tree_flatten(b)
    return ta == tb and all(np.array_equal(x, y) for x, y in zip(fa, fb))


def test_checkpoint_roundtrip_live_trainer_state(tmp_path):
    from repro.checkpoint import io as ckpt_io
    trainer, topo, wf = make_trainer()
    run_iters(trainer, 2)
    path = os.path.join(tmp_path, "state.msgpack")
    saved = trainer.state_tree()
    assert int(saved["weight_version"]) == 2
    nbytes = ckpt_io.save(path, saved)
    assert nbytes > 0
    run_iters(trainer, 2, seed=1)          # diverge past the checkpoint
    assert not tree_equal(trainer.state_tree(), saved)
    restored = ckpt_io.restore(path, trainer.state_tree())
    trainer.load_state_tree(restored)
    assert trainer.weight_version == 2
    assert tree_equal(trainer.state_tree(), saved)
    # restored state trains on: loss finite, version advances
    m = run_iters(trainer, 1, seed=2)[0]
    assert np.isfinite(m["loss"]) and trainer.weight_version == 3


# ---------------------------------------------------------------------------
# reschedule warm start (satellite: incumbent rediscovery)
# ---------------------------------------------------------------------------

def test_reschedule_unchanged_topology_keeps_incumbent():
    topo = reference_pool()
    cfg = tiny_cfg()
    wf = make_wf(cfg, AdditionTask(max_operand=9), batch=8)
    sched = HybridScheduler(topo, wf, max_groupings=8,
                            max_sizes_per_grouping=4)
    r = sched.search(budget=120)
    d = redeploy.reschedule(topo, wf, r.plan, budget=150)
    assert not d.switch
    assert d.plan is r.plan
    # warm start re-evaluates the incumbent: never worse on the same topo
    assert d.new_cost <= d.old_cost + 1e-15
    assert math.isfinite(d.old_cost)


def test_reschedule_dropped_devices_forces_switch():
    topo = reference_pool()
    cfg = tiny_cfg()
    wf = make_wf(cfg, AdditionTask(max_operand=9), batch=8)
    sched = HybridScheduler(topo, wf, max_groupings=8,
                            max_sizes_per_grouping=4)
    r = sched.search(budget=120)
    small = topology.drop_devices(topo, [topo.n - 2, topo.n - 1])
    d = redeploy.reschedule(small, wf, r.plan, budget=150)
    assert d.old_cost == math.inf          # incumbent no longer fits
    assert d.switch and math.isfinite(d.new_cost)
    ok, msg = check_constraints(small, wf, d.plan)
    assert ok, msg


# ---------------------------------------------------------------------------
# elastic controller end to end
# ---------------------------------------------------------------------------

def test_elastic_controller_swaps_on_drop(tmp_path):
    trainer, topo, wf = make_trainer()
    schedule = topology.drift_scenario("drop_tail", topo, at=2)
    controller = ElasticController(
        trainer, schedule,
        ElasticConfig(budget=150, ckpt_dir=str(tmp_path)))
    wv = []
    for it in range(6):
        run_iters(trainer, 1, seed=it)
        wv.append(trainer.weight_version)
        rec = controller.poll(it)
        if rec is not None:
            assert rec.iteration == 2
            assert rec.decision.switch and rec.applied
            assert rec.ckpt_path and os.path.exists(rec.ckpt_path)
            assert rec.ckpt_bytes > 0
    assert len(controller.swaps) == 1
    assert trainer.engine.epoch == 1
    assert wv == sorted(wv) and wv[-1] == 6   # training never reset
    # quiet feed after the event: no further reactions
    assert len(controller.records) == 1


def test_elastic_controller_stays_on_mild_drift():
    # a *searched* incumbent plus a degradation it barely feels: the
    # warm-started reschedule rediscovers the incumbent and stays put
    cfg = tiny_cfg()
    task = AdditionTask(max_operand=9)
    topo = reference_pool()
    wf = make_wf(cfg, task, batch=8)
    sched = HybridScheduler(topo, wf, max_groupings=8,
                            max_sizes_per_grouping=4)
    r = sched.search(budget=120)
    rl = RLConfig(algorithm="grpo", n_rollouts=4, max_new_tokens=4)
    trainer = RLTrainer(cfg, rl, task, KEY, plan=r.plan, topo=topo, wf=wf)
    run_iters(trainer, 1)
    mild = topology.degrade_links(topo, bw_factor=0.5, lat_factor=2.0)
    controller = ElasticController(
        trainer, topology.DriftSchedule(topo, [
            topology.DriftEvent(1, "mild", mild)]),
        ElasticConfig(budget=120))
    rec = controller.poll(1)
    assert rec is not None and not rec.applied
    assert trainer.engine.epoch == 0
    # predictions now price the drifted topology even without a swap
    assert trainer.engine.topo is mild


def test_update_topology_unfit_incumbent_keeps_old_topo():
    """Regression: after a device drop with no feasible challenger
    (reschedule keeps the incumbent), ``update_topology`` used to adopt
    the shrunken, re-indexed device list under a plan that still
    addresses the dropped ids — ``compare_with_simulator`` /
    ``epoch_report`` then indexed out of range.  The engine now keeps
    the old topology for prediction and marks the epoch
    ``topology_stale`` instead."""
    trainer, topo, wf = make_trainer()
    run_iters(trainer, 2)
    eng = trainer.engine
    dropped = topology.drop_devices(topo, [topo.n - 1])
    assert not trainer.plan.fits_topology(dropped)
    eng.update_topology(dropped)
    assert eng.topology_stale
    assert eng.topo.n == topo.n            # old topology kept
    rep = eng.epoch_report()               # used to raise IndexError
    assert np.isfinite(rep[-1]["predicted_iter_s"])
    cmp_ = eng.compare_with_simulator()
    assert np.isfinite(cmp_["predicted_iter_s"])
    # a topology the incumbent fits is adopted and clears the flag
    eng.update_topology(topo)
    assert not eng.topology_stale
    assert eng.topo is topo
