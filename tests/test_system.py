"""End-to-end system behaviour: scheduler -> plan -> simulator -> RL
training all composed, mirroring the paper's execution overview (§4.1)."""
import jax
import numpy as np

from repro.core import simulator, topology, workflow
from repro.core.plan import check_constraints
from repro.core.sha import HybridScheduler
from repro.data.synthetic import AdditionTask, VOCAB_SIZE
from repro.models.config import ModelConfig
from repro.rl.trainer import RLConfig, RLTrainer


def test_full_pipeline():
    # 1. profile + schedule on the reference heterogeneous pool
    topo = topology.build_testbed("single_region",
                                  counts={"A100": 4, "L4": 4})
    cfg = ModelConfig(name="sys", n_layers=2, d_model=96, n_heads=4,
                      n_kv_heads=2, head_dim=24, d_ff=192,
                      vocab_size=VOCAB_SIZE, dtype="float32")
    spec = workflow.LLMSpec.from_model_config(cfg)
    wf = workflow.make_grpo(spec, global_batch=8, n_rollouts=4,
                            seq_in=16, seq_out=8)
    sched = HybridScheduler(topo, wf, max_groupings=8,
                            max_sizes_per_grouping=4)
    result = sched.search(budget=80)
    assert result.plan is not None
    ok, msg = check_constraints(topo, wf, result.plan)
    assert ok, msg

    # 2. the plan's simulated timeline is consistent
    sim = simulator.simulate(topo, wf, result.plan)
    assert sim.iteration_time > 0
    assert sim.throughput > 0

    # 3. execute the RL workflow for real (host device), annotated with
    # the plan; reward must be finite and weight sync accounted
    task = AdditionTask(max_operand=9)
    rl = RLConfig(algorithm="grpo", n_rollouts=4, max_new_tokens=4)
    trainer = RLTrainer(cfg, rl, task, jax.random.PRNGKey(0),
                        plan=result.plan)
    rng = np.random.default_rng(0)
    prompts, answers = task.sample_batch(rng, 8)
    for i in range(2):
        m = trainer.iteration(prompts, answers, jax.random.PRNGKey(i))
    assert np.isfinite(m["reward_mean"])
    assert trainer.sync_bytes > 0
