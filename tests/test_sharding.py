"""parallel.sharding + engine.placement unit tests.

Edge cases the sharded execution path leans on: spec sanitization for
non-dividing dims and tuple axis entries, stacked-block param specs,
``hint`` as identity outside a mesh/rules context, and group-aware
device folding (injective when the plan fits, collision-reported d%L
when oversubscribed).
"""
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import enumerate as enum_mod, topology, workflow
from repro.engine import placement as placement_mod
from repro.models.config import ModelConfig
from repro.parallel import sharding as sh


def fake_mesh(shape_by_axis):
    """Duck-typed mesh: sanitize_spec only reads axis_names/devices.shape."""
    names = tuple(shape_by_axis)
    return SimpleNamespace(
        axis_names=names,
        devices=np.empty(tuple(shape_by_axis[n] for n in names)))


# -- sanitize_spec ---------------------------------------------------------

def test_sanitize_spec_non_dividing_axis_replicates():
    mesh = fake_mesh({"data": 4, "model": 2})
    # dim 0 = 6 not divisible by data=4 -> replicated; dim 1 = 8 by 2 ok
    assert sh.sanitize_spec(P("data", "model"), (6, 8), mesh) \
        == P(None, "model")
    # both divide -> untouched
    assert sh.sanitize_spec(P("data", "model"), (8, 8), mesh) \
        == P("data", "model")


def test_sanitize_spec_tuple_entry_keeps_dividing_prefix():
    mesh = fake_mesh({"data": 4, "model": 2})
    # ("data","model") wants 8-way: dim 8 keeps both, dim 4 keeps only
    # data, dim 2 keeps... data=4 does not divide 2 -> drops, then model
    # alone is not attempted past a dropped prefix member (greedy prefix)
    assert sh.sanitize_spec(P(("data", "model"),), (8,), mesh) \
        == P(("data", "model"))
    assert sh.sanitize_spec(P(("data", "model"),), (4,), mesh) == P("data")
    assert sh.sanitize_spec(P(("data", "model"),), (3,), mesh) == P(None)


def test_sanitize_spec_trims_to_rank():
    mesh = fake_mesh({"data": 2, "model": 2})
    # spec longer than the shape's rank is trimmed, not an error
    assert sh.sanitize_spec(P("data", None, "model"), (4, 4), mesh) \
        == P("data", None)


# -- param_tree_specs ------------------------------------------------------

def tiny_cfg():
    from repro.data.synthetic import VOCAB_SIZE
    return ModelConfig(name="shard-tiny", n_layers=2, d_model=64,
                       n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                       vocab_size=VOCAB_SIZE, dtype="float32")


def test_param_tree_specs_stacked_blocks():
    from repro.models import transformer as T
    params = T.init_params(jax.random.PRNGKey(0), tiny_cfg())
    specs = sh.param_tree_specs(params)
    flat = {sh.path_str(p): (leaf, spec) for (p, leaf), (_, spec) in zip(
        jax.tree_util.tree_flatten_with_path(params)[0],
        jax.tree_util.tree_flatten_with_path(specs)[0])}
    saw_stacked = False
    for path, (leaf, spec) in flat.items():
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        if path.startswith("blocks/"):
            saw_stacked = True
            # stacked scan-over-blocks leaves: leading repeat dim is
            # never sharded
            assert len(spec) == 0 or spec[0] is None, (path, spec)
    assert saw_stacked
    # a known TP rule applies under the stacked prefix
    wq = next(v for k, v in flat.items() if k.endswith("wq"))
    assert tuple(wq[1]) == (None, "data", "model")


def test_param_specs_jit_roundtrip_on_mesh():
    """Sanitized specs are accepted by device_put + jit in_shardings."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices")
    from jax.sharding import Mesh
    from repro.models import transformer as T
    params = T.init_params(jax.random.PRNGKey(0), tiny_cfg())
    n = 2
    mesh = Mesh(np.array(jax.devices()[:n]).reshape(1, n),
                ("data", "model"))
    shardings = sh.named_shardings(mesh, sh.param_tree_specs(params),
                                   params)
    committed = jax.device_put(params, shardings)
    out = jax.jit(lambda p: jax.tree_util.tree_map(jnp.sum, p),
                  in_shardings=(shardings,))(committed)
    ref = jax.tree_util.tree_map(jnp.sum, params)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5)


# -- hint ------------------------------------------------------------------

def test_hint_identity_outside_rules():
    x = jnp.arange(8.0).reshape(2, 4)
    y = sh.hint(x, "residual")
    assert y is x


def test_hint_identity_for_unknown_name():
    x = jnp.arange(4.0)
    with sh.use_hints({"residual": P("data")}):
        assert sh.hint(x, "not-a-rule") is x


def test_use_hints_restores_previous_rules():
    with sh.use_hints({"a": P()}):
        with sh.use_hints({"b": P()}):
            pass
        x = jnp.ones(3)
        assert sh.hint(x, "b") is x  # inner rules popped


# -- fold_plan / fold_devices ---------------------------------------------

def gen_rest_plan(n_devices=8):
    cfg = tiny_cfg()
    topo = topology.build_testbed("single_region",
                                  counts={"A100": n_devices // 2,
                                          "L4": n_devices // 2})
    spec = workflow.LLMSpec.from_model_config(cfg)
    wf = workflow.make_workflow("grpo", spec, synchronous=True,
                                n_rollouts=4, seq_in=8, seq_out=4,
                                global_batch=1)
    grouping = next(g for g in enum_mod.priority_groupings(wf)
                    if len(g) == 2 and any(
                        wf.task(t).kind == workflow.TaskKind.GEN
                        for t in min(g, key=len)))
    sizes = enum_mod.proportional_sizes(wf, grouping, topo.n)
    plan = enum_mod.build_plan(topo, wf, grouping, sizes,
                               list(range(topo.n)))
    return wf, plan


def test_fold_plan_injective_when_plan_fits():
    wf, plan = gen_rest_plan()
    local = [f"dev{i}" for i in range(8)]
    f = placement_mod.fold_plan(plan, local)
    assert not f.oversubscribed
    assert f.n_collisions == 0
    # injective: distinct plan ids -> distinct local indices
    assert len(set(f.mapping.values())) == len(f.mapping)
    # plan over ids 0..7 on 8 local devices folds to the identity
    assert f.mapping == {i: i for i in range(8)}


def test_fold_plan_disjoint_groups_stay_disjoint():
    wf, plan = gen_rest_plan()
    local = [f"dev{i}" for i in range(8)]
    f = placement_mod.fold_plan(plan, local)
    locals_of = [
        {f.mapping[int(d)] for d in g.devices} for g in plan.groups]
    assert locals_of[0].isdisjoint(locals_of[1])


def test_fold_plan_rank_maps_sparse_ids():
    """Non-contiguous plan ids still fold injectively when they fit."""
    wf, plan = gen_rest_plan()
    # remap plan device ids 0..7 -> sparse ids (x*3 + 1)
    import dataclasses as dc
    remap = {i: i * 3 + 1 for i in range(8)}
    groups = tuple(
        dc.replace(g, devices=tuple(remap[int(d)] for d in g.devices))
        for g in plan.groups)
    assignment = {t: np.vectorize(remap.get)(a)
                  for t, a in plan.assignment.items()}
    sparse = dc.replace(plan, groups=groups, assignment=assignment)
    local = [f"dev{i}" for i in range(8)]
    f = placement_mod.fold_plan(sparse, local)
    assert not f.oversubscribed and f.n_collisions == 0
    # rank order: i-th smallest id -> local index i
    assert f.mapping == {i * 3 + 1: i for i in range(8)}


def test_fold_plan_oversubscribed_reports_collisions():
    wf, plan = gen_rest_plan()
    local = ["devA", "devB", "devC"]   # 8 plan ids on 3 real devices
    f = placement_mod.fold_plan(plan, local)
    assert f.oversubscribed
    # d % 3 folds ids from both groups onto shared devices
    assert f.n_collisions > 0
    for li, gis in f.collisions:
        assert 0 <= li < 3
        assert len(gis) >= 2
    assert f.colliding_groups  # some group flagged
    # deterministic
    f2 = placement_mod.fold_plan(plan, local)
    assert f2.mapping == f.mapping and f2.collisions == f.collisions


def test_fold_devices_legacy_vs_mapping():
    local = ["devA", "devB", "devC"]
    # legacy (no mapping): d % L
    assert placement_mod.fold_devices([5, 2], local) == ["devC"]
    # group-aware mapping overrides
    mapping = {5: 0, 2: 1}
    assert placement_mod.fold_devices([5, 2], local, mapping) \
        == ["devA", "devB"]


def test_build_placement_collision_flag():
    wf, plan = gen_rest_plan()
    local = ["devA", "devB", "devC"]
    folding = placement_mod.fold_plan(plan, local)
    pls = {t: placement_mod.build_placement(plan, t, local, folding)
           for t in range(wf.n_tasks)}
    # oversubscribed 3-device host: at least one task's group collides
    assert any(pl.collision for pl in pls.values())
    # and the mesh never exceeds the distinct folded devices
    for pl in pls.values():
        assert int(np.prod(pl.mesh_shape)) == len(pl.local_devices)
        assert pl.tp_eff == pl.mesh_shape[1]


def test_build_placement_full_host_no_collision():
    wf, plan = gen_rest_plan()
    local = [f"dev{i}" for i in range(8)]
    folding = placement_mod.fold_plan(plan, local)
    pls = {t: placement_mod.build_placement(plan, t, local, folding)
           for t in range(wf.n_tasks)}
    assert all(not pl.collision for pl in pls.values())
    gen_t = next(t for t in range(wf.n_tasks)
                 if wf.task(t).kind == workflow.TaskKind.GEN)
    other = [t for t in range(wf.n_tasks) if t != gen_t]
    gen_set = set(pls[gen_t].local_devices)
    for t in other:
        if plan.group_of(t) is not plan.group_of(gen_t):
            assert gen_set.isdisjoint(pls[t].local_devices)
